"""Benchmark reporting: BENCH_*.json artifacts, the regression-compare gate,
and the legacy EXPERIMENTS.md generator.

## BENCH_*.json artifact schema ("repro-bench/v1")

    {
      "schema": "repro-bench/v1",
      "created_unix": 1753...,          # seconds since epoch
      "backend": "cpu",                 # jax.default_backend()
      "tables": ["gpp_journey", ...],   # which tables produced the rows
      "rows": [
        {"name": "gpp_si214_v8",        # CSV row name (stable join key)
         "us_per_call": 1234.5,         # measured wall clock, or null
         "derived": "modeled_tflops=4.077;step_s=0.3585",   # raw CSV field
         "metrics": {"modeled_tflops": 4.077, "step_s": 0.3585},
         "kernel_config": {             # optional: config provenance
           "kernel": "gpp", "version": "v8",
           "config": {"blk_ig": 512, "blk_igp": 128, "blk_band": 32},
           "source": "static"}},        # static | model | measured | cache
        ...
      ]
    }

`metrics` is `derived` parsed into the numeric key=value pairs (non-numeric
values like `dominant=compute` are dropped). `kernel_config`, when present,
records which kernel version + config produced the row and whether the
config came from the tune cache — compare mode diffs it and reports
"config churn" notes (a tuned pick silently changing between artifacts),
separate from metric regressions. Artifacts are written by
`python -m benchmarks.run --json PATH` and live under runs/bench/ locally
(BENCH_<pr>.json by convention) or as CI artifacts.

## Compare mode (the CI regression gate)

    python -m benchmarks.report --compare OLD.json NEW.json [--threshold 0.1]

Joins rows by name and diffs every shared numeric metric. A metric is a
regression when it moves >threshold (default 10%) in its bad direction
(lower-is-better for times/bytes, higher-is-better for throughput — see
LOWER_BETTER/HIGHER_BETTER). Exits 1 if any regression is found (0 with
--warn-only). Wall-clock `us_per_call` is machine-dependent noise across CI
hosts, so it is excluded unless --include-wallclock is passed; the modeled
metrics are deterministic and gate cleanly.

## Legacy mode (no arguments)

Regenerates EXPERIMENTS.md §Dry-run/§Roofline from runs/dryrun/*.json +
the GPP journey (requires EXPERIMENTS.header.md).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

HERE = os.path.dirname(__file__)
ROOT = os.path.join(HERE, "..")
RUNS = os.path.join(ROOT, "runs", "dryrun")

SCHEMA = "repro-bench/v1"

# metric-name direction table for the regression gate. Substring match on
# the metric key; anything matching neither list is informational only.
LOWER_BETTER = ("us_per_call", "step_s", "modeled_s", "cpu_ms", "compute_s",
                "memory_s", "measured_us", "gib", "vmem_mib", "bytes",
                "ttft", "tpot", "queue_depth", "wasted_toks",
                "shed", "deadline_miss", "retries_per_request",
                "recovery_ticks", "brownout", "abs_err")
HIGHER_BETTER = ("tflops", "pct_vpu_peak", "roofline", "speedup",
                 "goodput", "tok_per_tick", "hit_rate", "saved",
                 "reduction", "bitexact", "agree_frac",
                 "acceptance_rate", "accepted_tokens_per_step",
                 "effective_tok_per_s")
# wall-clock metrics are machine-dependent noise across CI hosts: excluded
# from the gate unless --include-wallclock. The router's tick-denominated
# SLO metrics (ttft_ticks/tpot_ticks/queue_depth/goodput_toks) are
# deterministic functions of the trace seed and gate cleanly; their _s/_ms
# twins are wall-clock and land here.
WALLCLOCK = ("us_per_call", "measured_us", "cpu_ms",
             "ttft_s", "ttft_ms", "tpot_s", "tpot_ms", "tok_per_s")


# ---------------------------------------------------------------------------
# artifact write/read
# ---------------------------------------------------------------------------

def parse_derived(derived: str) -> Dict[str, float]:
    """`a=1;b=2.5;c=compute` -> {'a': 1.0, 'b': 2.5} (numeric pairs only)."""
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k.strip()] = float(v)
        except ValueError:
            continue
    return out


def make_artifact(rows: List[Dict], *, tables: Optional[List[str]] = None
                  ) -> Dict:
    try:
        import jax
        backend = jax.default_backend()
    except Exception:
        backend = "unknown"
    return {
        "schema": SCHEMA,
        "created_unix": int(time.time()),
        "backend": backend,
        "tables": list(tables or []),
        "rows": [{"name": r["name"],
                  "us_per_call": r.get("us_per_call"),
                  "derived": r.get("derived", ""),
                  "metrics": parse_derived(r.get("derived", "")),
                  **({"kernel_config": r["kernel_config"]}
                     if r.get("kernel_config") else {})}
                 for r in rows],
    }


def write_artifact(rows: List[Dict], path: str, *,
                   tables: Optional[List[str]] = None) -> Dict:
    art = make_artifact(rows, tables=tables)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as fh:
        json.dump(art, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return art


class ArtifactError(ValueError):
    """A BENCH_*.json artifact compare mode can't trust (bad schema, or a
    hand-edited row breaking the kernel_config provenance contract)."""


# every kernel_config must carry full provenance: which kernel + version
# produced the row, the concrete config, and where it came from
# (static | model | measured | cache). make_artifact always writes all
# four; a missing key means the baseline was edited by hand.
KERNEL_CONFIG_KEYS = ("kernel", "version", "config", "source")


def validate_artifact(art: Dict, path: str) -> None:
    """Raise ArtifactError if any row's kernel_config is malformed —
    compare mode must fail the gate LEGIBLY on a hand-edited baseline,
    not with a traceback out of the churn formatter."""
    for row in art.get("rows", []):
        name = row.get("name", "<unnamed>")
        kc = row.get("kernel_config")
        if kc is None:
            continue
        if not isinstance(kc, dict):
            raise ArtifactError(
                f"{path}: row {name!r}: kernel_config must be an object "
                f"with keys {list(KERNEL_CONFIG_KEYS)}, got "
                f"{type(kc).__name__} ({kc!r}) — hand-edited baseline?")
        missing = [k for k in KERNEL_CONFIG_KEYS if k not in kc]
        if missing:
            raise ArtifactError(
                f"{path}: row {name!r}: kernel_config is missing "
                f"provenance key(s) {missing} (needs all of "
                f"{list(KERNEL_CONFIG_KEYS)}) — hand-edited baseline? "
                f"Regenerate it with `python -m benchmarks.run --json`.")


def load_artifact(path: str) -> Dict:
    with open(path) as fh:
        art = json.load(fh)
    if art.get("schema") != SCHEMA:
        raise ArtifactError(f"{path}: unknown schema {art.get('schema')!r} "
                            f"(expected {SCHEMA})")
    return art


# ---------------------------------------------------------------------------
# compare (regression gate)
# ---------------------------------------------------------------------------

def _fmt_kc(kc: Dict) -> str:
    cfg = ",".join(f"{k}={v}" for k, v in sorted(kc.get("config", {}).items())
                   if k != "name")
    return (f"{kc.get('kernel')}/{kc.get('version')}[{cfg}]"
            f"({kc.get('source')})")


def _direction(metric: str) -> Optional[int]:
    """-1: lower is better, +1: higher is better, None: informational."""
    for s in HIGHER_BETTER:
        if s in metric:
            return +1
    for s in LOWER_BETTER:
        if s in metric:
            return -1
    return None


def compare(old: Dict, new: Dict, *, threshold: float = 0.10,
            include_wallclock: bool = False
            ) -> Tuple[List[str], List[str], List[str]]:
    """Diff two artifacts. Returns (regressions, improvements, notes) as
    human-readable lines; non-empty regressions is the gate failure."""
    old_rows = {r["name"]: r for r in old["rows"]}
    new_rows = {r["name"]: r for r in new["rows"]}
    regressions, improvements, notes = [], [], []

    for name in sorted(set(old_rows) - set(new_rows)):
        notes.append(f"row removed: {name}")
    for name in sorted(set(new_rows) - set(old_rows)):
        notes.append(f"row added: {name}")

    for name in sorted(set(old_rows) & set(new_rows)):
        o, n = old_rows[name], new_rows[name]
        kc_o, kc_n = o.get("kernel_config"), n.get("kernel_config")
        if kc_o and kc_n and kc_o != kc_n:
            # a selected version/config changing between artifacts is worth
            # eyes even when the modeled metrics moved inside the threshold
            notes.append(f"config churn: {name}: {_fmt_kc(kc_o)} -> "
                         f"{_fmt_kc(kc_n)}")
        om = dict(o.get("metrics", {}))
        nm = dict(n.get("metrics", {}))
        if include_wallclock:
            if o.get("us_per_call") is not None:
                om["us_per_call"] = o["us_per_call"]
            if n.get("us_per_call") is not None:
                nm["us_per_call"] = n["us_per_call"]
        for metric in sorted(set(om) & set(nm)):
            if not include_wallclock and any(w in metric for w in WALLCLOCK):
                continue
            direction = _direction(metric)
            ov, nv = om[metric], nm[metric]
            if direction is None or ov == 0:
                continue
            change = (nv - ov) / abs(ov)          # >0 means metric went up
            bad = change if direction == -1 else -change   # >0 means worse
            line = (f"{name}.{metric}: {ov:.6g} -> {nv:.6g} "
                    f"({change:+.1%})")
            if bad > threshold:
                regressions.append(line)
            elif -bad > threshold:
                improvements.append(line)
    return regressions, improvements, notes


def run_compare(old_path: str, new_path: str, *, threshold: float = 0.10,
                include_wallclock: bool = False, warn_only: bool = False
                ) -> int:
    """Exit codes: 0 clean (or --warn-only), 1 regression found, 2 an
    artifact itself is unusable (unreadable / bad schema / malformed
    kernel_config provenance) — a clear one-line error, not a traceback."""
    try:
        old, new = load_artifact(old_path), load_artifact(new_path)
        validate_artifact(old, old_path)
        validate_artifact(new, new_path)
    except (ArtifactError, OSError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    regressions, improvements, notes = compare(
        old, new, threshold=threshold, include_wallclock=include_wallclock)
    for line in notes:
        print(f"note: {line}")
    for line in improvements:
        print(f"improved: {line}")
    for line in regressions:
        print(f"REGRESSION: {line}")
    print(f"compare: {len(regressions)} regression(s), "
          f"{len(improvements)} improvement(s) "
          f"(threshold {threshold:.0%}, {old_path} -> {new_path})")
    if regressions and not warn_only:
        return 1
    return 0


# ---------------------------------------------------------------------------
# legacy EXPERIMENTS.md generator
# ---------------------------------------------------------------------------

def load(tag):
    rows = {}
    for f in sorted(glob.glob(os.path.join(RUNS, f"*__{tag}.json"))):
        r = json.load(open(f))
        rows[r["name"]] = r
    return rows


def cell_table(rows):
    hdr = ("| cell | kind | compute_s | memory_s | collective_s | dominant | "
           "step_s | roofline | MXU% | useful | GiB/chip | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    kinds = {"train_4k": "train", "prefill_32k": "prefill",
             "decode_32k": "decode", "long_500k": "decode"}
    for name, r in sorted(rows.items()):
        shape = name.split("/")[1]
        u = r.get("useful_ratio")
        out.append(
            f"| {name} | {kinds[shape]} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
            f"| {r['dominant']} | {r['step_s']:.3g} "
            f"| {r['roofline_frac']:.1%} | {r['mxu_frac']:.0%} "
            f"| {u and f'{u:.2f}' or '—'} "
            f"| {r.get('hbm_adjusted_gib', r['hbm_gib_per_chip']):.2f} "
            f"| {'✓' if r['fits_hbm'] else '✗'} |")
    return "\n".join(out)


def dryrun_summary(single, multi):
    lines = []
    n_ok = len(single)
    lines.append(f"* single-pod (16,16)=256 chips: **{n_ok} cells "
                 f"lowered+compiled**")
    lines.append(f"* multi-pod (2,16,16)=512 chips: **{len(multi)} cells "
                 f"lowered+compiled** (proves the `pod` axis shards)")
    fits = sum(1 for r in single.values() if r["fits_hbm"])
    lines.append(f"* {fits}/{n_ok} cells fit 16 GiB/chip after donation "
                 f"adjustment (per-cell numbers below)")
    coll = [(r["collective_s"], n) for n, r in multi.items()]
    coll.sort(reverse=True)
    lines.append("* most collective-bound multi-pod cells: "
                 + ", ".join(f"{n} ({c:.3g}s)" for c, n in coll[:3]))
    return "\n".join(lines)


def journey_section():
    from repro.core.journey import FLOP_PEAK, format_journey, run_journey
    out = []
    for size in ("si214", "si510"):
        rows = run_journey(size, measure_cpu=(size == "si214"),
                           verbose=False)
        out.append(format_journey(rows, size))
        v0, v8 = rows[0], next(r for r in rows if r.version == "v8")
        vbest = rows[-1]
        out.append(
            f"\nmodeled v8/v0 speedup **{v0.report.modeled_step_s/v8.report.modeled_step_s:.2f}×** "
            f"(paper wall-clock: {'2.36×' if size=='si214' else '3.27×'}); "
            f"v8 = {v8.modeled_tflops:.2f} TF/s = "
            f"{v8.modeled_tflops*1e12/FLOP_PEAK:.0%} of the VPU peak "
            f"(paper: 3.71 TF/s = 55% of FP64 peak). Beyond-paper "
            f"v10 = {vbest.modeled_tflops:.2f} TF/s "
            f"({v0.report.modeled_step_s/vbest.report.modeled_step_s:.2f}× v0).\n")
    return "\n".join(out)


def write_experiments():
    single = load("single")
    multi = load("multi")
    sections = {
        "DRYRUN_SUMMARY": dryrun_summary(single, multi),
        "SINGLE_TABLE": cell_table(single),
        "MULTI_TABLE": cell_table(multi),
        "JOURNEY": journey_section(),
    }
    header = os.path.join(ROOT, "EXPERIMENTS.header.md")
    if not os.path.exists(header):
        print("EXPERIMENTS.header.md missing — nothing to splice into "
              "(use --compare for the artifact gate)", file=sys.stderr)
        return 2
    tpl = open(header).read()
    for k, v in sections.items():
        tpl = tpl.replace("{{" + k + "}}", v)
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as fh:
        fh.write(tpl)
    print("EXPERIMENTS.md written "
          f"({len(single)} single + {len(multi)} multi cells)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--compare", nargs=2, metavar=("OLD", "NEW"),
                    help="diff two BENCH_*.json artifacts; exit 1 on a "
                         ">threshold regression")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="regression threshold as a fraction (default 0.10)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0 (CI soft-introduce)")
    ap.add_argument("--include-wallclock", action="store_true",
                    help="also gate us_per_call (noisy across machines)")
    args = ap.parse_args(argv)
    if args.compare:
        return run_compare(args.compare[0], args.compare[1],
                           threshold=args.threshold,
                           include_wallclock=args.include_wallclock,
                           warn_only=args.warn_only)
    return write_experiments()


if __name__ == "__main__":
    sys.exit(main())
