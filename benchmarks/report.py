"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the artifacts
in runs/dryrun/*.json + the GPP journey, splicing them into the hand-written
narrative (EXPERIMENTS.template.md is NOT used — the script owns the whole
file; §Perf iteration logs are embedded below as code since they narrate
measured befores/afters)."""

from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(__file__)
ROOT = os.path.join(HERE, "..")
RUNS = os.path.join(ROOT, "runs", "dryrun")


def load(tag):
    rows = {}
    for f in sorted(glob.glob(os.path.join(RUNS, f"*__{tag}.json"))):
        r = json.load(open(f))
        rows[r["name"]] = r
    return rows


def cell_table(rows):
    hdr = ("| cell | kind | compute_s | memory_s | collective_s | dominant | "
           "step_s | roofline | MXU% | useful | GiB/chip | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    kinds = {"train_4k": "train", "prefill_32k": "prefill",
             "decode_32k": "decode", "long_500k": "decode"}
    for name, r in sorted(rows.items()):
        shape = name.split("/")[1]
        u = r.get("useful_ratio")
        out.append(
            f"| {name} | {kinds[shape]} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
            f"| {r['dominant']} | {r['step_s']:.3g} "
            f"| {r['roofline_frac']:.1%} | {r['mxu_frac']:.0%} "
            f"| {u and f'{u:.2f}' or '—'} "
            f"| {r.get('hbm_adjusted_gib', r['hbm_gib_per_chip']):.2f} "
            f"| {'✓' if r['fits_hbm'] else '✗'} |")
    return "\n".join(out)


def dryrun_summary(single, multi):
    lines = []
    n_ok = len(single)
    lines.append(f"* single-pod (16,16)=256 chips: **{n_ok} cells "
                 f"lowered+compiled**")
    lines.append(f"* multi-pod (2,16,16)=512 chips: **{len(multi)} cells "
                 f"lowered+compiled** (proves the `pod` axis shards)")
    fits = sum(1 for r in single.values() if r["fits_hbm"])
    lines.append(f"* {fits}/{n_ok} cells fit 16 GiB/chip after donation "
                 f"adjustment (per-cell numbers below)")
    coll = [(r["collective_s"], n) for n, r in multi.items()]
    coll.sort(reverse=True)
    lines.append("* most collective-bound multi-pod cells: "
                 + ", ".join(f"{n} ({c:.3g}s)" for c, n in coll[:3]))
    return "\n".join(lines)


def journey_section():
    from repro.core.journey import FLOP_PEAK, format_journey, run_journey
    out = []
    for size in ("si214", "si510"):
        rows = run_journey(size, measure_cpu=(size == "si214"),
                           verbose=False)
        out.append(format_journey(rows, size))
        v0, v8 = rows[0], rows[-1]
        out.append(
            f"\nmodeled v8/v0 speedup **{v0.report.modeled_step_s/v8.report.modeled_step_s:.2f}×** "
            f"(paper wall-clock: {'2.36×' if size=='si214' else '3.27×'}); "
            f"v8 = {v8.modeled_tflops:.2f} TF/s = "
            f"{v8.modeled_tflops*1e12/FLOP_PEAK:.0%} of the VPU peak "
            f"(paper: 3.71 TF/s = 55% of FP64 peak).\n")
    return "\n".join(out)


def main():
    single = load("single")
    multi = load("multi")
    sections = {
        "DRYRUN_SUMMARY": dryrun_summary(single, multi),
        "SINGLE_TABLE": cell_table(single),
        "MULTI_TABLE": cell_table(multi),
        "JOURNEY": journey_section(),
    }
    tpl = open(os.path.join(ROOT, "EXPERIMENTS.header.md")).read()
    for k, v in sections.items():
        tpl = tpl.replace("{{" + k + "}}", v)
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as fh:
        fh.write(tpl)
    print("EXPERIMENTS.md written "
          f"({len(single)} single + {len(multi)} multi cells)")


if __name__ == "__main__":
    main()
