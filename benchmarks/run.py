"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run                   # all
    PYTHONPATH=src python -m benchmarks.run --only gpp_journey
    PYTHONPATH=src python -m benchmarks.run --only gpp_journey,gpp_tuner \
        --json runs/bench/BENCH_2.json                        # artifact

Prints `name,us_per_call,derived` CSV rows per the repo contract. With
--json PATH the same rows are also written as a BENCH_*.json artifact
(schema: benchmarks/report.py) so the perf trajectory persists PR-over-PR;
`python -m benchmarks.report --compare OLD NEW` diffs two artifacts and
flags >10% regressions (the CI gate).

Tables:
  table1_gpp_journey   — paper Table I: v0..v10 (CPU wall-clock at BENCH size
                         + modeled v5e TFLOP/s at Si-214/Si-510)
  fig_roofline_terms   — paper Figs 1/3/5/6: hierarchical terms per version
  fig8_locality        — paper Fig 8: HBM bytes per version (locality)
  v8_block_sweep       — the v8 tuning sweep (paper Sec. III-v8)
  gpp_tuner            — repro.tune winners per size (model-ranked; measured
                         where the size permits CPU timing)
  kernel_tuner         — tuned picks for the other registered kernels
                         (flash blk_q/blk_kv, ssm blk_c) via the same
                         generalized repro.tune flow
  model_cells          — the 40-cell dry-run roofline table (reads
                         runs/dryrun/*.json written by launch/dryrun.py)
  train_step_cpu       — measured wall-time of a reduced-config train step
                         per architecture (the CPU-executable signal)
  serve                — slot-scheduler serving stats on a reduced model
                         (decode steps / occupancy are deterministic;
                         latency/throughput fields are wall clock). Also
                         reachable via the --serve shortcut.
  router               — multi-replica DP router under a seeded bursty
                         trace: p50/p99 TTFT + time-per-output-token
                         (tick-denominated rows are deterministic and
                         gateable; _ms rows are wall clock), queue depth,
                         goodput-under-burst, per-replica rows. Shortcut:
                         --router [--replicas N] [--fault kill:R@T or
                         stall:R@T+D].
  kvcache              — paged K/V cache rows (serve/kvcache.py) under a
                         shared-system-prompt trace: prefix hit rate +
                         prefill tokens saved, measured bytes/slot vs the
                         static layout, paged-vs-static bit-exactness,
                         the int8 pool's pinned attention error, the
                         tuned paged_decode kernel pick, and the fleet
                         hit rate across router replicas. All token/page
                         counts are deterministic and gateable. Shortcut:
                         --kvcache (composable with --serve).
  spec                 — speculative decoding rows (serve/spec.py): the
                         acceptance rate and accepted-tokens-per-verify-
                         step of a layer-sliced draft, spec-vs-plain
                         bit-exactness at temperature 0, effective tok/s
                         for both engines (wall clock) and the tuned
                         multi-query paged_decode "verify" kernel pick.
                         Acceptance/parity rows are deterministic and
                         gateable. Shortcut: --spec (composable with
                         --serve/--kvcache).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import time

HERE = os.path.dirname(__file__)
RUNS = os.path.join(HERE, "..", "runs", "dryrun")

RESULTS = []          # rows emitted this run, for the --json artifact

# journey rows are expensive (jit + interpret-mode Pallas); compute them
# once per size and share across table1/roofline_terms/fig8. A cached row
# set without CPU timings is upgraded in place if a later table needs them.
_JOURNEY_CACHE = {}


def journey_rows(size: str, measure_cpu: bool = False):
    from repro.core.journey import run_journey
    rows = _JOURNEY_CACHE.get(size)
    if rows is None or (measure_cpu and rows[0].cpu_ms is None):
        rows = run_journey(size, measure_cpu=measure_cpu, verbose=False)
        _JOURNEY_CACHE[size] = rows
    return rows


def _csv(name, us, derived, kernel_config=None):
    """Emit one row. kernel_config (optional) records the selected kernel
    version + config and where it came from ({"kernel", "version",
    "config", "source"}) so report.py --compare can flag config churn —
    a tuned pick silently changing between artifacts — not just metric
    regressions."""
    print(f"{name},{us if us is not None else ''},{derived}")
    row = {"name": name, "us_per_call": us, "derived": derived}
    if kernel_config:
        row["kernel_config"] = kernel_config
    RESULTS.append(row)


def table1_gpp_journey():
    from repro.core.journey import FLOP_PEAK
    for size in ("si214", "si510"):
        rows = journey_rows(size, measure_cpu=(size == "si214"))
        for r in rows:
            us = r.cpu_ms * 1e3 if r.cpu_ms else None
            blocks = r.report.extra.get("block_config")
            kc = None
            if blocks:
                kc = {"kernel": "gpp", "version": r.version,
                      "config": {"blk_ig": blocks[0], "blk_igp": blocks[1],
                                 "blk_band": blocks[2]},
                      "source": "model" if r.version == "v10" else "static"}
            _csv(f"gpp_{size}_{r.version}", us,
                 f"modeled_tflops={r.modeled_tflops:.3f};"
                 f"pct_vpu_peak={r.modeled_tflops*1e12/FLOP_PEAK:.3f};"
                 f"step_s={r.report.modeled_step_s:.4f}",
                 kernel_config=kc)
        v0, vbest = rows[0], rows[-1]
        v8 = next(r for r in rows if r.version == "v8")
        _csv(f"gpp_{size}_speedup_v8_over_v0", None,
             f"{v0.report.modeled_step_s / v8.report.modeled_step_s:.3f}x"
             f" (paper: {'2.36x' if size == 'si214' else '3.27x'})")
        _csv(f"gpp_{size}_speedup_v10_over_v0", None,
             f"{v0.report.modeled_step_s / vbest.report.modeled_step_s:.3f}x"
             f" (beyond-paper steps)")


def fig_roofline_terms():
    for r in journey_rows("si214"):
        rep = r.report
        _csv(f"roofline_{r.version}", None,
             f"compute_s={rep.compute_s:.4f};memory_s={rep.memory_s:.5f};"
             f"dominant={rep.dominant}")


def fig8_locality():
    rows = journey_rows("si214")
    base = rows[0].report.bytes_per_chip
    for r in rows:
        rep = r.report
        _csv(f"hbm_bytes_{r.version}", None,
             f"gib={rep.bytes_per_chip/2**30:.2f};"
             f"vs_v0={rep.bytes_per_chip/base:.3f}")


def v8_block_sweep():
    from repro.core.journey import sweep_blocks
    for row in sweep_blocks("si214")[:8]:
        _csv(f"sweep_ig{row['blk_ig']}_igp{row['blk_igp']}_b{row['blk_band']}",
             None, f"modeled_s={row['modeled_s']:.4f};"
             f"tflops={row['tflops']:.3f};vmem_mib={row['vmem_mib']:.1f}")


def gpp_tuner():
    """The autotuner's pick per size. Model-only (measure_mode=False) so
    the artifact rows are deterministic — the regression gate must not
    depend on one noisy interpret-mode timing choosing among near-tied
    configs; the measured pass is exercised by tests/test_tune.py and the
    ops.gpp("v10") dispatch path."""
    import dataclasses

    from repro.kernels.gpp.problem import SIZES
    from repro.tune import tuner
    for name in ("tiny", "bench", "si214", "si510"):
        tc = tuner.tune(SIZES[name], use_cache=False, measure_mode=False)
        c = tc.config
        _csv(f"tuned_{name}", None,
             f"blk_ig={c.blk_ig};blk_igp={c.blk_igp};blk_band={c.blk_band};"
             f"modeled_s={tc.modeled_s:.4g};source={tc.source}",
             kernel_config={"kernel": "gpp", "version": "v10",
                            "config": dataclasses.asdict(c),
                            "source": tc.source})


def kernel_tuner():
    """The registry-wide generalization of gpp_tuner: every non-gpp kernel's
    tuned pick at representative sizes, through the same model-then-measure
    flow and (kernel, ProblemKey, backend, version) cache keying.
    Model-only for determinism (same rationale as gpp_tuner)."""
    import dataclasses

    from repro.kernels.flash.kernel_def import FlashKey
    from repro.kernels.ssm.kernel_def import SsmKey
    from repro.tune import tuner

    keys = [
        # (row name, kernel, key)
        ("flash_train_4k", "flash",
         FlashKey(b=8, h=16, kvh=4, sq=4096, skv=4096, hd=128)),
        ("flash_prefill_32k", "flash",
         FlashKey(b=1, h=16, kvh=4, sq=32768, skv=32768, hd=128)),
        ("flash_block_256", "flash",
         FlashKey(b=4, h=8, kvh=2, sq=256, skv=256, hd=64)),
        ("ssm_hymba_4k", "ssm", SsmKey(b=16, t=4096, c=6400, n=16)),
        ("ssm_small", "ssm", SsmKey(b=2, t=256, c=256, n=16)),
    ]
    for name, kernel, key in keys:
        tc = tuner.tune_kernel(kernel, key, use_cache=False,
                               measure_mode=False)
        cfg = dataclasses.asdict(tc.config)
        dims = ";".join(f"{k}={v}" for k, v in cfg.items() if k != "name")
        _csv(f"tuned_{name}", None,
             f"{dims};modeled_s={tc.modeled_s:.4g};source={tc.source}",
             kernel_config={"kernel": kernel, "version": tc.key.split("|")[-1],
                            "config": cfg, "source": tc.source})


def model_cells():
    files = sorted(glob.glob(os.path.join(RUNS, "*__single.json")))
    if not files:
        _csv("model_cells", None, "no dry-run artifacts (run launch.dryrun)")
        return
    for f in files:
        r = json.load(open(f))
        _csv(f"cell_{r['name'].replace('/', '_')}", None,
             f"step_s={r['step_s']:.4g};dominant={r['dominant']};"
             f"roofline={r['roofline_frac']:.3f};"
             f"mem_gib={r.get('hbm_adjusted_gib', 0):.2f};"
             f"fits={r['fits_hbm']}")


def train_step_cpu():
    import jax
    from repro.configs.base import ARCH_IDS, get_config, reduce_config
    from repro.models.registry import build_model
    for arch in ARCH_IDS:
        cfg = reduce_config(get_config(arch))
        model = build_model(cfg)
        rng = jax.random.PRNGKey(0)
        params = model.init_params(rng)
        batch = {"tokens": jax.random.randint(rng, (2, 64), 0, cfg.vocab_size),
                 "labels": jax.random.randint(rng, (2, 64), 0, cfg.vocab_size)}
        if cfg.family == "encdec":
            batch["frames"] = jax.numpy.zeros((2, cfg.enc_seq, cfg.d_model),
                                              jax.numpy.bfloat16)
        if cfg.family == "vlm":
            batch["vis"] = jax.numpy.zeros((2, cfg.n_vis_tokens, cfg.d_model),
                                           jax.numpy.bfloat16)
        fn = jax.jit(jax.grad(lambda p: model.loss_fn(p, batch)[0]))
        g = fn(params)
        jax.block_until_ready(g)
        t0 = time.perf_counter()
        g = fn(params)
        jax.block_until_ready(g)
        dt = time.perf_counter() - t0
        _csv(f"train_step_{arch}", dt * 1e6, "reduced-config fwd+bwd on CPU")


# --serve --mesh tp size (int), set by main() before jax imports so the
# forced host device count can take effect
SERVE_MESH = None


def _parse_mesh(spec: str) -> int:
    axis, sep, n = spec.partition("=")
    if axis != "tp" or not sep or not n.isdigit() or int(n) < 1:
        raise SystemExit(f"--mesh expects 'tp=N', got {spec!r}")
    return int(n)


def serve():
    """Slot-level continuous-batching stats: a mixed-length workload with
    more requests than slots on a reduced model. decode_steps / prefills /
    new_tokens / occupancy are deterministic (fixed workload, greedy or
    per-request keyed sampling); ttft/queue/tok_per_s are wall clock and
    therefore informational only (no gate-list metric names).

    With --mesh tp=N the engine serves tensor-parallel over an N-way
    `model` mesh axis (dist.sharding.serve_specs exact-TP layout) and one
    extra serve_device_<i> row per device records its occupancy / tok_per_s
    plus the measured local param/cache shard sizes."""
    import jax
    import numpy as np

    from repro.configs.base import get_config, reduce_config
    from repro.models.registry import build_model
    from repro.serve.engine import Request, ServeEngine

    mesh = None
    if SERVE_MESH is not None:
        tp = SERVE_MESH
        if jax.device_count() < tp:
            raise SystemExit(
                f"--mesh tp={tp} needs {tp} devices but jax sees "
                f"{jax.device_count()} (run.py forces the host platform "
                "count only when jax is not already initialized)")
        mesh = jax.make_mesh((tp,), ("model",))
    # d_model=256 gives 8 heads / d_ff 768: dims an 8-way axis divides
    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2,
                        d_model=(256 if mesh is not None else 64),
                        vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=4, cache_len=128, mesh=mesh)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 4 + i % 7),
                    max_new_tokens=(4 if i % 3 else 32),
                    temperature=(0.7 if i % 2 else 0.0))
            for i in range(12)]
    out, stats = eng.run(reqs, collect_stats=True)
    e = stats["engine"]
    _csv("serve_engine", e["wall_s"] * 1e6,
         f"decode_steps={e['decode_steps']};prefills={e['prefills']};"
         f"new_tokens={e['new_tokens']};occupancy={e['occupancy']:.3f};"
         f"tok_per_s={e['tok_per_s']:.1f};"
         f"mean_ttft_ms={e['mean_ttft_s'] * 1e3:.1f};"
         f"mean_queue_ms={e['mean_queue_wait_s'] * 1e3:.1f}")
    ttfts = [r.ttft_s for r in stats["requests"].values()]
    waits = [r.queue_wait_s for r in stats["requests"].values()]
    _csv("serve_latency", None,
         f"p50_ttft_ms={np.percentile(ttfts, 50) * 1e3:.1f};"
         f"p95_ttft_ms={np.percentile(ttfts, 95) * 1e3:.1f};"
         f"p50_queue_ms={np.percentile(waits, 50) * 1e3:.1f};"
         f"p95_queue_ms={np.percentile(waits, 95) * 1e3:.1f}")
    for d in e.get("per_device", []):
        _csv(f"serve_device_{d['device']}", None,
             f"occupancy={d['occupancy']:.3f};"
             f"tok_per_s={d['tok_per_s']:.1f};"
             f"params_mib={d['params_bytes'] / 2**20:.3f};"
             f"cache_mib={d['cache_bytes'] / 2**20:.3f}")


# --router knobs, set by main()
ROUTER_REPLICAS = 2
ROUTER_FAULT = None


def _parse_fault(spec: str):
    """'kill:R@T', 'stall:R@T+D', 'recover:R@T', or 'flap:R@T+D' (a
    kill at T + recover at T+D) -> FaultPlan (import-free parse check
    lives here so argparse errors stay legible)."""
    from repro.serve.router import FaultPlan
    plan = FaultPlan()
    for part in spec.split(","):
        kind, sep, rest = part.partition(":")
        try:
            if kind == "kill":
                rep, tick = rest.split("@")
                plan.kill(int(rep), at_tick=int(tick))
            elif kind == "stall":
                rep, rest2 = rest.split("@")
                tick, dur = rest2.split("+")
                plan.stall(int(rep), at_tick=int(tick), ticks=int(dur))
            elif kind == "recover":
                rep, tick = rest.split("@")
                plan.recover(int(rep), at_tick=int(tick))
            elif kind == "flap":
                rep, rest2 = rest.split("@")
                tick, down = rest2.split("+")
                plan.flap(int(rep), at_tick=int(tick),
                          down_ticks=int(down))
            else:
                raise ValueError(kind)
        except ValueError:
            raise SystemExit(
                f"--fault expects 'kill:R@T', 'stall:R@T+D', "
                f"'recover:R@T', or 'flap:R@T+D' "
                f"(comma-separated), got {part!r}")
    return plan


def router():
    """The serving-tier SLO table: a seeded bursty trace load-balanced
    across ROUTER_REPLICAS replica engines (optionally with a scripted
    fault). Tick-denominated tail-latency rows, queue depth, and
    goodput-under-burst counts are deterministic — the same trace seed
    schedules identically on every host, so report.py --compare can gate
    tail latency. The _ms mirrors and tok-per-wall-second rates are wall
    clock (informational; see report.WALLCLOCK). Two extra fixed
    scenarios ride along: router_overload (deadlines + bounded queue +
    retry backoff + brown-out controller under a hot burst) and
    router_recovery (goodput and fence->recover gap under a replica
    flap)."""
    import jax

    from repro.configs.base import get_config, reduce_config
    from repro.models.registry import build_model
    from repro.serve.router import OverloadConfig, Router
    from repro.serve.trace import TraceConfig, generate_trace

    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2, d_model=64,
                        vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    # bursty heavy-tail mix sized so bursts actually overlap the run:
    # ~0.5s of calm, then a 4x-rate burst window every second
    trace = generate_trace(TraceConfig(
        n_requests=24, arrival="bursty", rate_rps=16.0, burst_factor=4.0,
        burst_every_s=1.0, burst_len_s=0.5, prompt_median=6,
        prompt_sigma=0.6, prompt_max=24, out_median=8, out_sigma=0.8,
        out_max=32, temperatures=(0.0, 0.7), vocab=128, seed=0))
    plan = _parse_fault(ROUTER_FAULT) if ROUTER_FAULT else None
    rt = Router(cfg, params, replicas=ROUTER_REPLICAS, max_batch=4,
                cache_len=64, fault_plan=plan, stale_after_ticks=3)
    out, s = rt.run(trace, tick_s=0.05)
    fault_note = f";fault={ROUTER_FAULT}" if ROUTER_FAULT else ""
    _csv("router_engine", s["wall_s"] * 1e6,
         f"replicas={s['replicas']};completed={s['completed']};"
         f"requeued={s['requeued']};ticks={s['ticks']};"
         f"decode_steps={s['decode_steps']};prefills={s['prefills']};"
         f"goodput_toks={s['goodput_toks']};wasted_toks={s['wasted_toks']};"
         f"goodput_tok_per_s={s['goodput_tok_per_s']:.1f}{fault_note}")
    _csv("router_slo_ticks", None,
         f"p50_ttft_ticks={s['p50_ttft_ticks']:.2f};"
         f"p99_ttft_ticks={s['p99_ttft_ticks']:.2f};"
         f"p50_tpot_ticks={s['p50_tpot_ticks']:.3f};"
         f"p99_tpot_ticks={s['p99_tpot_ticks']:.3f}")
    _csv("router_slo_wall", None,
         f"p50_ttft_ms={s['p50_ttft_s'] * 1e3:.1f};"
         f"p99_ttft_ms={s['p99_ttft_s'] * 1e3:.1f};"
         f"p50_tpot_ms={s['p50_tpot_s'] * 1e3:.2f};"
         f"p99_tpot_ms={s['p99_tpot_s'] * 1e3:.2f}")
    _csv("router_queue", None,
         f"mean_queue_depth={s['mean_queue_depth']:.2f};"
         f"p99_queue_depth={s['p99_queue_depth']:.2f};"
         f"max_queue_depth={s['max_queue_depth']}")
    b = s.get("burst")
    if b:
        _csv("router_burst", None,
             f"burst_ticks={b['ticks']};burst_arrivals={b['arrivals']};"
             f"burst_new_tokens={b['new_tokens']};"
             f"burst_tok_per_tick={b['tok_per_tick']:.2f}")
    for pr in s["per_replica"]:
        _csv(f"router_replica_{pr['replica']}", None,
             f"decode_steps={pr['decode_steps']};"
             f"prefills={pr['prefills']};completed={pr['completed']};"
             f"evicted={pr['evicted']};stalled_ticks={pr['stalled_ticks']};"
             f"killed={pr['killed']};fenced={pr['fenced']}")

    # --- overload scenario: a hotter burst mix with per-request deadlines
    # pushed through a bounded queue, retry backoff, and the windowed
    # brown-out controller. Every rate below is tick-denominated and
    # deterministic per seed, so report.py --compare gates them exactly
    # (docs/serving.md §Overload & recovery).
    o_trace = generate_trace(TraceConfig(
        n_requests=24, arrival="bursty", rate_rps=32.0, burst_factor=6.0,
        burst_every_s=0.5, burst_len_s=0.25, prompt_median=6,
        prompt_sigma=0.6, prompt_max=24, out_median=8, out_sigma=0.8,
        out_max=32, temperatures=(0.0, 0.7), vocab=128, seed=0,
        deadline_median=24, deadline_sigma=0.8, deadline_max=96))
    ort = Router(cfg, params, replicas=ROUTER_REPLICAS, max_batch=4,
                 cache_len=64, stale_after_ticks=3, max_queue=4,
                 retry_budget=2, retry_backoff_base=1, retry_backoff_cap=8,
                 overload=OverloadConfig(window_ticks=2, queue_high=1,
                                         queue_low=0))
    _, so = ort.run(o_trace, tick_s=0.05)
    _csv("router_overload", None,
         f"completed={so['completed']};shed={so['shed']};"
         f"deadline_missed={so['deadline_missed']};"
         f"shed_rate={so['shed_rate']:.3f};"
         f"deadline_miss_rate={so['deadline_miss_rate']:.3f};"
         f"retries_per_request={so['retries_per_request']:.3f};"
         f"brownout_ticks={so['brownout_ticks']};"
         f"goodput_toks={so['goodput_toks']};"
         f"p99_ttft_ticks={so['p99_ttft_ticks']:.2f}")

    # --- recovery scenario: the base trace under a kill->recover flap of
    # replica 1; goodput-under-flap and the fence->recover gap gate the
    # recovery path (every completed output stays bit-exact vs an
    # undisturbed single-engine run — the chaos tier asserts that).
    from repro.serve.router import FaultPlan
    r_trace = generate_trace(TraceConfig(
        n_requests=24, arrival="bursty", rate_rps=16.0, burst_factor=4.0,
        burst_every_s=1.0, burst_len_s=0.5, prompt_median=6,
        prompt_sigma=0.6, prompt_max=24, out_median=8, out_sigma=0.8,
        out_max=32, temperatures=(0.0, 0.7), vocab=128, seed=0))
    rrt = Router(cfg, params, replicas=ROUTER_REPLICAS, max_batch=4,
                 cache_len=64, stale_after_ticks=3,
                 fault_plan=FaultPlan().flap(1, at_tick=6, down_ticks=6))
    _, sr = rrt.run(r_trace, tick_s=0.05)
    _csv("router_recovery", None,
         f"completed={sr['completed']};recoveries={sr['recoveries']};"
         f"mean_recovery_ticks={sr['mean_recovery_ticks']:.2f};"
         f"requeued={sr['requeued']};wasted_toks={sr['wasted_toks']};"
         f"goodput_toks={sr['goodput_toks']};ticks={sr['ticks']};"
         f"p99_ttft_ticks={sr['p99_ttft_ticks']:.2f}")


def kvcache():
    """Paged-K/V rows: one shared-prompt workload served three ways (cold
    static cache, paged bf16, paged int8) plus the registry-routed
    paged_decode kernel. Hit rates, token counts, page accounting, and
    the kernel error bounds are deterministic per seed; only the
    us_per_call column is wall clock."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import get_config, reduce_config
    from repro.kernels import api
    from repro.models.registry import build_model
    from repro.serve.engine import ServeEngine
    from repro.serve.router import Router
    from repro.serve.trace import TraceConfig, generate_trace
    from repro.tune import tuner

    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2, d_model=64,
                        vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    # 90% of requests share one of two 16-token system prompts: the
    # workload shape the prefix index exists for (greedy sampling so the
    # paged-vs-static compare is a bit-exactness check, not a similarity)
    trace = generate_trace(TraceConfig(
        n_requests=16, rate_rps=16.0, prompt_median=6, prompt_sigma=0.6,
        prompt_max=16, out_median=6, out_sigma=0.6, out_max=16,
        temperatures=(0.0,), vocab=128, seed=0,
        shared_prefix_frac=0.9, prefix_pool=2, prefix_len=16))
    reqs = trace.plain_requests()

    base = ServeEngine(cfg, params, max_batch=4, cache_len=64)
    out_base, _ = base.run(reqs, collect_stats=True)

    eng = ServeEngine(cfg, params, max_batch=4, cache_len=64,
                      kv_page_size=8)
    out_paged, stats = eng.run(reqs, collect_stats=True)
    eng.kv.check_conservation()
    kv = stats["engine"]["kvcache"]
    _csv("kvcache_engine", stats["engine"]["wall_s"] * 1e6,
         f"page_size={kv['page_size']};"
         f"prefix_hit_rate={kv['prefix_hit_rate']:.3f};"
         f"prefill_tokens_saved={kv['prefill_tokens_saved']};"
         f"peak_live_pages={kv['peak_live_pages']};"
         f"page_occupancy={kv['page_occupancy']:.3f}")
    _csv("kvcache_bytes", None,
         f"kv_bytes_per_slot={kv['kv_bytes_per_slot']:.0f};"
         f"static_bytes_per_slot={kv['static_bytes_per_slot']};"
         f"bytes_per_slot_reduction={kv['bytes_per_slot_reduction']:.3f}")
    exact = sum(np.array_equal(out_base[r], out_paged[r]) for r in out_base)
    _csv("kvcache_parity", None,
         f"bitexact_frac={exact / len(out_base):.3f};"
         f"requests={len(out_base)};"
         f"tokens={sum(len(t) for t in out_base.values())}")

    # int8 pool: token agreement vs the bf16 baseline (informational
    # similarity — int8 is lossy by design) + the pinned attention-level
    # error of the quantized kernel route at the canonical shape
    eng8 = ServeEngine(cfg, params, max_batch=4, cache_len=64,
                       kv_page_size=8, kv_dtype="int8")
    out8, _ = eng8.run(reqs, collect_stats=True)
    agree = sum(np.array_equal(out_base[r], out8[r]) for r in out_base)
    ks = api.get_kernel("paged_decode")
    key = ks.canonical_keys()[0]
    (q, kp, vp, tbl, cl), _kw = ks.make_example(key)
    ref = api.dispatch("paged_decode", q, kp, vp, tbl, cl, version="ref")
    i8 = api.dispatch("paged_decode", q, kp, vp, tbl, cl, version="int8")
    err8 = float(jnp.max(jnp.abs(ref.astype(jnp.float32)
                                 - i8.astype(jnp.float32))))
    _csv("kvcache_int8", None,
         f"token_agree_frac={agree / len(out_base):.3f};"
         f"attn_max_abs_err={err8:.4g}")

    # the registry route: tuned pages_per_block pick (model-ranked, same
    # determinism rationale as gpp_tuner) + gather-vs-oracle error
    tc = tuner.tune_kernel("paged_decode", key, use_cache=False,
                           measure_mode=False)
    gat = api.dispatch("paged_decode", q, kp, vp, tbl, cl,
                       version="gather", config=tc.config)
    errg = float(jnp.max(jnp.abs(ref.astype(jnp.float32)
                                 - gat.astype(jnp.float32))))
    _csv("kvcache_kernel", None,
         f"pages_per_block={tc.config.pages_per_block};"
         f"modeled_s={tc.modeled_s:.4g};gather_max_abs_err={errg:.4g};"
         f"source={tc.source}",
         kernel_config={"kernel": "paged_decode",
                        "version": tc.key.split("|")[-1],
                        "config": dataclasses.asdict(tc.config),
                        "source": tc.source})

    # fleet view: replica-local pools/indexes — the shared prompt
    # prefills once PER REPLICA, so the fleet hit rate sits below a
    # single engine's on the same trace (docs/serving.md §Paged K/V)
    rt = Router(cfg, params, replicas=2, max_batch=4, cache_len=64,
                kv_page_size=8)
    _, rs = rt.run(trace, tick_s=0.05)
    rkv = rs["kvcache"]
    _csv("kvcache_router", None,
         f"replicas=2;prefix_hit_rate={rkv['prefix_hit_rate']:.3f};"
         f"prefill_tokens_saved={rkv['prefill_tokens_saved']};"
         f"pages_allocated={rkv['pages_allocated']};"
         f"pages_freed={rkv['pages_freed']}")


def spec():
    """Speculative-decoding rows: one greedy trace served plain and with
    a layer-sliced draft (the target's own first layer — the zero-train
    draft that works because the residual stream is embedding-dominated).
    Acceptance, accounting, and parity rows are deterministic per seed;
    the tok/s rows are wall clock. The size is the smallest where the
    verify's shared weight traffic beats per-step dispatch overhead, so
    the speedup is a real effect, not noise."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import get_config, reduce_config
    from repro.kernels import api
    from repro.models.registry import build_model
    from repro.serve.engine import ServeEngine
    from repro.serve.trace import TraceConfig, generate_trace
    from repro.tune import tuner

    spec_k = 3
    cfg = reduce_config(get_config("qwen2-1.5b"), layers=6, d_model=384,
                        vocab=256)
    params = build_model(cfg).init_params(jax.random.PRNGKey(0))
    dcfg = dataclasses.replace(cfg, n_layers=1)
    dparams = dict(params)
    dparams["layers"] = jax.tree_util.tree_map(lambda x: x[:1],
                                               params["layers"])
    trace = generate_trace(TraceConfig(
        n_requests=12, rate_rps=16.0, prompt_median=6, prompt_sigma=0.6,
        prompt_max=16, out_median=8, out_sigma=0.5, out_max=12,
        temperatures=(0.0,), vocab=256, seed=0))
    reqs = trace.plain_requests()

    plain = ServeEngine(cfg, params, max_batch=4, cache_len=64)
    seng = ServeEngine(cfg, params, max_batch=4, cache_len=64,
                       draft_cfg=dcfg, draft_params=dparams, spec_k=spec_k)
    # first run jits; best-of-2 timed reps after
    out_plain, out_spec = plain.run(list(reqs)), seng.run(list(reqs))
    walls = {}
    for name, eng in (("plain", plain), ("spec", seng)):
        best = None
        for _ in range(2):
            t0 = time.perf_counter()
            out = eng.run(list(reqs))
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        walls[name] = best
    toks = sum(len(v) for v in out_plain.values())

    sp = seng.last_stats["spec"]
    _csv("spec_engine", walls["spec"] * 1e6,
         f"k={sp['k']};acceptance_rate={sp['acceptance_rate']:.3f};"
         f"accepted_tokens_per_step={sp['accepted_tokens_per_step']:.3f};"
         f"tokens_emitted={sp['tokens_emitted']};"
         f"verify_steps={sp['verify_steps']};"
         f"draft_steps={sp['draft_steps']}")
    exact = sum(np.array_equal(out_plain[r], out_spec[r]) for r in out_plain)
    _csv("spec_parity", None,
         f"bitexact_frac={exact / len(out_plain):.3f};"
         f"requests={len(out_plain)};tokens={toks}")
    # effective throughput: tokens per wall second, both routes — the
    # tok_per_s fields are wall clock (gate-excluded); the speedup ratio
    # is the headline the baseline artifact records
    _csv("spec_throughput", None,
         f"plain_tok_per_s={toks / walls['plain']:.1f};"
         f"effective_tok_per_s={toks / walls['spec']:.1f};"
         f"wall_speedup={walls['plain'] / walls['spec']:.3f}")

    # the multi-query kernel route: tuned pages_per_block for the verify
    # version at the qlen>1 canonical shape + its error vs the ref oracle
    ks = api.get_kernel("paged_decode")
    key = next(k for k in ks.canonical_keys() if k.qlen > 1)
    (q, kp, vp, tbl, cl), _kw = ks.make_example(key)
    tc = tuner.tune_kernel("paged_decode", key, version="verify",
                           use_cache=False, measure_mode=False)
    ref = api.dispatch("paged_decode", q, kp, vp, tbl, cl, version="ref")
    ver = api.dispatch("paged_decode", q, kp, vp, tbl, cl,
                       version="verify", config=tc.config)
    errv = float(jnp.max(jnp.abs(ref.astype(jnp.float32)
                                 - ver.astype(jnp.float32))))
    _csv("spec_kernel", None,
         f"qlen={key.qlen};pages_per_block={tc.config.pages_per_block};"
         f"modeled_s={tc.modeled_s:.4g};verify_max_abs_err={errv:.4g};"
         f"source={tc.source}",
         kernel_config={"kernel": "paged_decode",
                        "version": "verify",
                        "config": dataclasses.asdict(tc.config),
                        "source": tc.source})


TABLES = {
    "gpp_journey": table1_gpp_journey,
    "roofline_terms": fig_roofline_terms,
    "fig8_locality": fig8_locality,
    "v8_block_sweep": v8_block_sweep,
    "gpp_tuner": gpp_tuner,
    "kernel_tuner": kernel_tuner,
    "model_cells": model_cells,
    "train_step_cpu": train_step_cpu,
    "serve": serve,
    "router": router,
    "kvcache": kvcache,
    "spec": spec,
}

# the cheap, deterministic-model subset CI benchmarks and the committed
# baseline artifact are built from (no multi-minute train-step jits)
FAST_TABLES = ("gpp_journey", "roofline_terms", "fig8_locality",
               "v8_block_sweep", "gpp_tuner", "kernel_tuner")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated table names (or 'fast' for the "
                         f"CI subset: {','.join(FAST_TABLES)})")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a BENCH_*.json artifact "
                         "(schema: benchmarks/report.py)")
    ap.add_argument("--serve", action="store_true",
                    help="shortcut for --only serve (slot-scheduler stats)")
    ap.add_argument("--mesh", default=None, metavar="tp=N",
                    help="with --serve: run the engine tensor-parallel "
                         "over an N-way model axis (forces N host devices "
                         "when jax is not yet initialized)")
    ap.add_argument("--router", action="store_true",
                    help="shortcut for --only router (multi-replica DP "
                         "router SLO rows)")
    ap.add_argument("--kvcache", action="store_true",
                    help="add the kvcache table (paged K/V cache rows; "
                         "alone it runs just that table, with --serve it "
                         "rides along)")
    ap.add_argument("--spec", action="store_true",
                    help="add the spec table (speculative decoding rows; "
                         "alone it runs just that table, composable with "
                         "--serve/--kvcache)")
    ap.add_argument("--replicas", type=int, default=2, metavar="N",
                    help="with --router: number of replica engines "
                         "(default 2)")
    ap.add_argument("--fault", default=None, metavar="SPEC",
                    help="with --router: scripted fault(s), "
                         "'kill:R@T' or 'stall:R@T+D' (comma-separated)")
    args = ap.parse_args()
    if args.router:
        todo = ["router"]
    elif args.serve:
        todo = ["serve"]
    elif args.only is None:
        if args.kvcache or args.spec:
            todo = (["kvcache"] if args.kvcache else []) \
                + (["spec"] if args.spec else [])
        else:
            todo = list(TABLES)
    elif args.only == "fast":
        todo = list(FAST_TABLES)
    else:
        todo = args.only.split(",")
        unknown = [t for t in todo if t not in TABLES]
        if unknown:
            ap.error(f"unknown tables {unknown}; choose from {list(TABLES)}")
    if args.mesh:
        if todo != ["serve"]:
            # a forced host device count would silently skew every other
            # table's wall-clock rows while the mesh itself went unused
            ap.error("--mesh only applies to the serve table "
                     "(use --serve or --only serve)")
        import sys as _sys
        tp = _parse_mesh(args.mesh)
        if "jax" not in _sys.modules:
            # must land before the first jax import; harmless off-CPU
            # (the flag only affects the host platform)
            flags = os.environ.get("XLA_FLAGS", "")
            if "xla_force_host_platform_device_count" not in flags:
                os.environ["XLA_FLAGS"] = (
                    f"{flags} --xla_force_host_platform_device_count={tp}"
                ).strip()
        global SERVE_MESH
        SERVE_MESH = tp
    if args.replicas != 2 or args.fault:
        if "router" not in todo:
            ap.error("--replicas/--fault only apply to the router table "
                     "(use --router or --only router)")
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    global ROUTER_REPLICAS, ROUTER_FAULT
    ROUTER_REPLICAS = args.replicas
    if args.fault:
        _parse_fault(args.fault)        # validate up front: SystemExit here
        ROUTER_FAULT = args.fault       # beats a traceback mid-table
    if args.kvcache and "kvcache" not in todo:
        todo.append("kvcache")
    if args.spec and "spec" not in todo:
        todo.append("spec")
    print("name,us_per_call,derived")
    for name in todo:
        TABLES[name]()
    if args.json:
        from benchmarks import report
        report.write_artifact(RESULTS, args.json, tables=todo)
        print(f"# wrote {args.json} ({len(RESULTS)} rows)")


if __name__ == '__main__':
    main()
