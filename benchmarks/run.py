"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run --only gpp_journey

Prints `name,us_per_call,derived` CSV rows per the repo contract.

Tables:
  table1_gpp_journey   — paper Table I: v0..v8 (CPU wall-clock at BENCH size
                         + modeled v5e TFLOP/s at Si-214/Si-510)
  fig_roofline_terms   — paper Figs 1/3/5/6: hierarchical terms per version
  fig8_locality        — paper Fig 8: HBM bytes per version (locality)
  v8_block_sweep       — the v8 tuning sweep (paper Sec. III-v8)
  model_cells          — the 40-cell dry-run roofline table (reads
                         runs/dryrun/*.json written by launch/dryrun.py)
  train_step_cpu       — measured wall-time of a reduced-config train step
                         per architecture (the CPU-executable signal)
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import time

HERE = os.path.dirname(__file__)
RUNS = os.path.join(HERE, "..", "runs", "dryrun")


def _csv(name, us, derived):
    print(f"{name},{us if us is not None else ''},{derived}")


def table1_gpp_journey():
    from repro.core.journey import FLOP_PEAK, format_journey, run_journey
    for size in ("si214", "si510"):
        rows = run_journey(size, measure_cpu=(size == "si214"),
                           verbose=False)
        for r in rows:
            us = r.cpu_ms * 1e3 if r.cpu_ms else None
            _csv(f"gpp_{size}_{r.version}", us,
                 f"modeled_tflops={r.modeled_tflops:.3f};"
                 f"pct_vpu_peak={r.modeled_tflops*1e12/FLOP_PEAK:.3f};"
                 f"step_s={r.report.modeled_step_s:.4f}")
        v0, v8 = rows[0], rows[-1]
        _csv(f"gpp_{size}_speedup_v8_over_v0", None,
             f"{v0.report.modeled_step_s / v8.report.modeled_step_s:.3f}x"
             f" (paper: {'2.36x' if size == 'si214' else '3.27x'})")


def fig_roofline_terms():
    from repro.core.journey import run_journey
    rows = run_journey("si214", measure_cpu=False, verbose=False)
    for r in rows:
        rep = r.report
        _csv(f"roofline_{r.version}", None,
             f"compute_s={rep.compute_s:.4f};memory_s={rep.memory_s:.5f};"
             f"dominant={rep.dominant}")


def fig8_locality():
    from repro.core.journey import run_journey
    rows = run_journey("si214", measure_cpu=False, verbose=False)
    base = rows[0].report.bytes_per_chip
    for r in rows:
        rep = r.report
        _csv(f"hbm_bytes_{r.version}", None,
             f"gib={rep.bytes_per_chip/2**30:.2f};"
             f"vs_v0={rep.bytes_per_chip/base:.3f}")


def v8_block_sweep():
    from repro.core.journey import sweep_blocks
    for row in sweep_blocks("si214")[:8]:
        _csv(f"sweep_ig{row['blk_ig']}_igp{row['blk_igp']}_b{row['blk_band']}",
             None, f"modeled_s={row['modeled_s']:.4f};"
             f"tflops={row['tflops']:.3f};vmem_mib={row['vmem_mib']:.1f}")


def model_cells():
    files = sorted(glob.glob(os.path.join(RUNS, "*__single.json")))
    if not files:
        _csv("model_cells", None, "no dry-run artifacts (run launch.dryrun)")
        return
    for f in files:
        r = json.load(open(f))
        _csv(f"cell_{r['name'].replace('/', '_')}", None,
             f"step_s={r['step_s']:.4g};dominant={r['dominant']};"
             f"roofline={r['roofline_frac']:.3f};"
             f"mem_gib={r.get('hbm_adjusted_gib', 0):.2f};"
             f"fits={r['fits_hbm']}")


def train_step_cpu():
    import jax
    from repro.configs.base import ARCH_IDS, get_config, reduce_config
    from repro.models.registry import build_model
    for arch in ARCH_IDS:
        cfg = reduce_config(get_config(arch))
        model = build_model(cfg)
        rng = jax.random.PRNGKey(0)
        params = model.init_params(rng)
        batch = {"tokens": jax.random.randint(rng, (2, 64), 0, cfg.vocab_size),
                 "labels": jax.random.randint(rng, (2, 64), 0, cfg.vocab_size)}
        if cfg.family == "encdec":
            batch["frames"] = jax.numpy.zeros((2, cfg.enc_seq, cfg.d_model),
                                              jax.numpy.bfloat16)
        if cfg.family == "vlm":
            batch["vis"] = jax.numpy.zeros((2, cfg.n_vis_tokens, cfg.d_model),
                                           jax.numpy.bfloat16)
        fn = jax.jit(jax.grad(lambda p: model.loss_fn(p, batch)[0]))
        g = fn(params)
        jax.block_until_ready(g)
        t0 = time.perf_counter()
        g = fn(params)
        jax.block_until_ready(g)
        dt = time.perf_counter() - t0
        _csv(f"train_step_{arch}", dt * 1e6, "reduced-config fwd+bwd on CPU")


TABLES = {
    "gpp_journey": table1_gpp_journey,
    "roofline_terms": fig_roofline_terms,
    "fig8_locality": fig8_locality,
    "v8_block_sweep": v8_block_sweep,
    "model_cells": model_cells,
    "train_step_cpu": train_step_cpu,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(TABLES))
    args = ap.parse_args()
    print("name,us_per_call,derived")
    todo = [args.only] if args.only else list(TABLES)
    for name in todo:
        TABLES[name]()


if __name__ == '__main__':
    main()
