"""The paper's kernel, end to end: run the GPP optimization journey
(v0 -> v8 per the paper, then the beyond-paper v9/v10 steps) with
correctness checks against the complex128 oracle, CPU wall-clock at BENCH
size, and the modeled TPU-v5e roofline trajectory — the Table-I
reproduction (EXPERIMENTS.md §Perf/GPP).

    PYTHONPATH=src python examples/gpp_science.py [--size si510] [--sweep]
"""

import argparse

from repro.core.journey import format_journey, run_journey, sweep_blocks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="si214", choices=("si214", "si510"))
    ap.add_argument("--sweep", action="store_true",
                    help="print the v8 block-size tuning sweep")
    ap.add_argument("--tune", action="store_true",
                    help="print the repro.tune autotuner ranking")
    ap.add_argument("--no-cpu", action="store_true",
                    help="skip CPU wall-clock measurements")
    args = ap.parse_args()

    rows = run_journey(args.size, measure_cpu=not args.no_cpu)
    print()
    print(format_journey(rows, args.size))

    v0 = rows[0]
    v8 = next(r for r in rows if r.version == "v8")
    vbest = rows[-1]
    speedup = v0.report.modeled_step_s / v8.report.modeled_step_s
    print(f"\nmodeled v8/v0 speedup: {speedup:.2f}x "
          f"(paper measured 2.36x Si-214, 3.27x Si-510); "
          f"v10/v0: {v0.report.modeled_step_s / vbest.report.modeled_step_s:.2f}x")

    if args.sweep:
        print("\nv8 block sweep (top 10):")
        for r in sweep_blocks(args.size)[:10]:
            print(f"  blk=({r['blk_ig']},{r['blk_igp']},{r['blk_band']}) "
                  f"modeled={r['modeled_s']*1e3:.1f}ms "
                  f"tflops={r['tflops']:.2f} vmem={r['vmem_mib']:.1f}MiB")

    if args.tune:
        from repro.kernels.gpp.problem import SIZES
        from repro.tune import tuner
        print("\nautotuner ranking (top 10, model):")
        for cfg, t in tuner.rank(SIZES[args.size])[:10]:
            print(f"  blk=({cfg.blk_ig},{cfg.blk_igp},{cfg.blk_band}) "
                  f"modeled={t*1e3:.1f}ms")


if __name__ == "__main__":
    main()
