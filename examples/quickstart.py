"""Quickstart: train a tiny qwen2-family model for a few steps on CPU and
sample from it. Runs in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs.base import get_config, reduce_config
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import TrainLoopConfig, Trainer


def main():
    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2, d_model=128,
                        vocab=256)
    loop = TrainLoopConfig(total_steps=20, ckpt_every=10, log_every=5,
                           ckpt_dir="runs/quickstart_ckpt", seq_len=64,
                           global_batch=4, peak_lr=1e-3)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    trainer = Trainer(cfg, loop, mesh)
    out = trainer.run()
    if out["final_loss"] is None:
        print(f"checkpoint already at step {out['start_step']}; skipping train")
    else:
        print(f"final loss: {out['final_loss']:.4f} "
              f"(stragglers flagged: {out['stragglers']})")

    # restore the checkpoint and serve a couple of batched requests
    step, state = trainer.ckpt.restore()
    print(f"restored step {step}")
    engine = ServeEngine(cfg, state["params"], max_batch=2)
    reqs = [Request(rid=i, prompt=np.arange(5 + i) % 256, max_new_tokens=8)
            for i in range(3)]
    for rid, toks in engine.run(reqs).items():
        print(f"request {rid}: {toks}")


if __name__ == "__main__":
    main()
