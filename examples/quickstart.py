"""Quickstart: the public `repro` surface end to end — dispatch a kernel
through the unified registry, train a tiny qwen2-family model for a few
steps on CPU, and sample from it. Runs in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

import repro
from repro.configs.base import get_config, reduce_config
from repro.train.trainer import TrainLoopConfig, Trainer


def kernel_demo():
    """The registry is the one entry point for every kernel family: list
    it, then dispatch the paper's GPP kernel at TINY size — version=None
    runs the default (autotuned v10, config from the repro.tune cache)."""
    from repro.kernels.gpp import problem
    print(f"registered kernels: {repro.list_kernels()}")
    inputs = problem.make_inputs(problem.TINY)
    ach, asx = repro.dispatch("gpp", inputs)
    print(f"gpp@tiny achtemp[0] = {complex(np.asarray(ach)[0]):.4f}")
    gpp = repro.get_kernel("gpp")
    print(f"gpp versions: {gpp.versions[0]}..{gpp.versions[-1]} "
          f"(default {gpp.default_version}, tunable {gpp.tunable})")


def main():
    kernel_demo()

    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2, d_model=128,
                        vocab=256)
    loop = TrainLoopConfig(total_steps=20, ckpt_every=10, log_every=5,
                           ckpt_dir="runs/quickstart_ckpt", seq_len=64,
                           global_batch=4, peak_lr=1e-3)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    trainer = Trainer(cfg, loop, mesh)
    out = trainer.run()
    if out["final_loss"] is None:
        print(f"checkpoint already at step {out['start_step']}; skipping train")
    else:
        print(f"final loss: {out['final_loss']:.4f} "
              f"(stragglers flagged: {out['stragglers']})")

    # restore the checkpoint and serve a couple of batched requests
    step, state = trainer.ckpt.restore()
    print(f"restored step {step}")
    engine = repro.ServeEngine(cfg, state["params"], max_batch=2)
    reqs = [repro.Request(rid=i, prompt=np.arange(5 + i) % 256,
                          max_new_tokens=8)
            for i in range(3)]
    for rid, toks in engine.run(reqs).items():
        print(f"request {rid}: {toks}")


if __name__ == "__main__":
    main()
