"""Registry smoke (the CI registry-smoke job's script): import `repro`,
list the kernel registry, and dispatch every registered kernel at TINY
size on CPU interpret, checking each against its reference version.

    PYTHONPATH=src python examples/registry_smoke.py

Exits nonzero if a family is missing, a dispatch fails, or a kernel
disagrees with its reference — the cheapest end-to-end proof that a new
kernel actually joined the dispatch/tune/bench plumbing.
"""

import numpy as np

import repro
from repro.kernels import api


def check(name: str, outs, refs, atol: float) -> None:
    for o, r in zip(outs, refs):
        err = float(np.max(np.abs(np.asarray(o) - np.asarray(r))))
        assert err <= atol, (name, err)
    print(f"  {name}: ok (atol {atol})")


def main():
    names = repro.list_kernels()
    print(f"registered kernels: {names}")
    assert {"gpp", "flash", "ssm", "paged_decode"} <= set(names), names

    # gpp at TINY vs the complex128 oracle
    from repro.kernels.gpp import problem, ref
    inputs = problem.make_inputs(problem.TINY)
    ar, xr = ref.ref_numpy(inputs)
    a, x = repro.dispatch("gpp", inputs, interpret=True)
    check("gpp v10@tiny", (a, x), (ar, xr),
          atol=1e-4 * float(np.max(np.abs(ar))))

    # flash + ssm: default (tuned pallas) vs their "ref" version, on tiny
    # synthetic inputs from each kernel's own make_example
    from repro.kernels.flash.kernel_def import FlashKey
    fkey = FlashKey(b=2, h=4, kvh=2, sq=64, skv=64, hd=16)
    fargs, fkw = api.get_kernel("flash").make_example(fkey)
    out = repro.dispatch("flash", *fargs, interpret=True, **fkw)
    out_ref = repro.dispatch("flash", *fargs, version="ref", **fkw)
    check("flash pallas@64", (out,), (out_ref,), atol=2e-2)

    from repro.kernels.ssm.kernel_def import SsmKey
    skey = SsmKey(b=2, t=32, c=8, n=4)
    sargs, _ = api.get_kernel("ssm").make_example(skey)
    y, hT = repro.dispatch("ssm", *sargs, interpret=True)
    y_ref, hT_ref = repro.dispatch("ssm", *sargs, version="ref")
    check("ssm pallas@32", (y, hT), (y_ref, hT_ref), atol=1e-3)

    # paged_decode: block-table gather decode vs its gather+oracle ref
    from repro.kernels.paged.kernel_def import PagedKey
    pkey = PagedKey(b=2, h=2, kvh=2, page=16, npt=4, hd=32)
    pargs, pkw = api.get_kernel("paged_decode").make_example(pkey)
    pd = repro.dispatch("paged_decode", *pargs, interpret=True, **pkw)
    pd_ref = repro.dispatch("paged_decode", *pargs, version="ref", **pkw)
    check("paged_decode gather@16x4", (pd,), (pd_ref,), atol=1e-2)

    print("registry smoke: all kernels dispatch and match their references")


if __name__ == "__main__":
    main()
