"""Serve a small model with batched requests (deliverable-b serving path):
slot-level continuous batching — finished slots refill from the queue
mid-flight — with greedy + temperature sampling and per-request
latency/throughput stats.

    PYTHONPATH=src python examples/serve_batched.py --requests 8 --batch 4

Tensor-parallel over N forced host devices (docs/serving.md §Sharded
serving; outputs are bit-exact vs --tp 1):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/serve_batched.py --tp 4
"""

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduce_config
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--tp", type=int, default=1,
                    help="serve tensor-parallel over a tp-way model axis "
                         "(needs >= tp jax devices)")
    args = ap.parse_args()

    cfg = reduce_config(get_config("phi4-mini-3.8b"), layers=4, d_model=256,
                        vocab=1024)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    mesh = (jax.make_mesh((args.tp,), ("model",)) if args.tp > 1 else None)
    engine = ServeEngine(cfg, params, max_batch=args.batch, mesh=mesh)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8 + i % 5),
                    max_new_tokens=args.new_tokens,
                    temperature=args.temperature)
            for i in range(args.requests)]

    t0 = time.perf_counter()
    out, stats = engine.run(reqs, collect_stats=True)
    dt = time.perf_counter() - t0
    e = stats["engine"]
    total = sum(len(v) for v in out.values())
    tp = f", tp={args.tp}" if args.tp > 1 else ""
    print(f"served {len(reqs)} requests / {total} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s, batch={args.batch}{tp})")
    for d in e.get("per_device", []):
        print(f"  device {d['device']}: params "
              f"{d['params_bytes']/2**20:.2f} MiB, cache "
              f"{d['cache_bytes']/2**20:.2f} MiB")
    print(f"  decode_steps={e['decode_steps']} prefills={e['prefills']} "
          f"occupancy={e['occupancy']:.2f} "
          f"mean_ttft={e['mean_ttft_s']*1e3:.0f}ms "
          f"mean_queue_wait={e['mean_queue_wait_s']*1e3:.0f}ms")
    for rid in sorted(out)[:4]:
        st = stats["requests"][rid]
        print(f"  req {rid}: {out[rid][:10]}{'...' if len(out[rid])>10 else ''}"
              f"  (ttft {st.ttft_s*1e3:.0f}ms, {st.tok_per_s:.1f} tok/s)")


if __name__ == "__main__":
    main()
