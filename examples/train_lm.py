"""End-to-end LM training driver.

Presets:
    --preset 100m   12L/768d qwen2-family ~100M params (the deliverable-b
                    scale; a few hundred steps ~ 1-2 h on this CPU host)
    --preset 20m    8L/384d  (~15 min for 200 steps on CPU)
    --preset smoke  2L/128d  (~1 min, CI)

Demonstrates the full production path: config -> model -> sharded train
step (mesh via flags) -> prefetching data pipeline -> checkpoint/auto-resume
(kill it and rerun: it continues) -> straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py --preset 20m --steps 200
"""

import argparse
import dataclasses

import jax

from repro.configs.base import ModelConfig
from repro.train.trainer import TrainLoopConfig, Trainer

PRESETS = {
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab_size=32768, seq_len=256, batch=4),
    "20m": dict(n_layers=8, d_model=384, n_heads=6, n_kv_heads=2,
                d_ff=1024, vocab_size=16384, seq_len=256, batch=4),
    "smoke": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                  d_ff=384, vocab_size=1024, seq_len=64, batch=4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis")
    ap.add_argument("--token-file", default=None,
                    help="flat binary token file (default: synthetic)")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"train-lm-{args.preset}", family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"], tie_embeddings=True, remat="none")
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    loop = TrainLoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every, log_every=10,
        ckpt_dir=args.ckpt_dir or f"runs/train_lm_{args.preset}",
        seq_len=p["seq_len"], global_batch=p["batch"], peak_lr=args.lr,
        token_file=args.token_file)
    mesh = jax.make_mesh((args.data, args.model), ("data", "model"))
    out = Trainer(cfg, loop, mesh).run()
    if out["final_loss"] is None:
        print(f"nothing to do: checkpoint already at step {out['start_step']}")
    else:
        print(f"done. final loss {out['final_loss']:.4f} "
              f"over {len(out['losses'])} steps this run")


if __name__ == "__main__":
    main()
