"""repro: a production-scale jax_pallas system grown from the paper's
single-kernel roofline study (8 Steps to 3.7 TFLOP/s, arXiv:2008.11326).

`import repro` is the documented entry point; the public surface is lazy
(nothing heavy imports until first attribute access). Every name below
carries a full docstring with a runnable example at its definition —
`help(repro.dispatch)` etc. resolves it:

    dispatch(name, *args, version=, config=, problem_key=)
        Run a registered kernel; config resolves from the tune cache.
    get_kernel(name) / list_kernels()
        The Kernel descriptor registry (docs/kernels.md).
    build_model(cfg)
        Config -> Model bundle: init/loss/prefill/decode/prefill_into_slot
        + the logical-axis metadata the sharding engine consumes.
    ServeEngine(cfg, params, max_batch=, cache_len=, mesh=) / Request
        Slot-level continuous-batching server; pass mesh= to serve
        tensor-parallel over a repro.dist mesh (docs/serving.md).
    PagedKVCache
        Paged K/V storage behind ServeEngine(kv_page_size=): block
        tables, FIFO free-list, refcounted prefix reuse, optional int8
        pages (docs/serving.md §Paged K/V cache).
    Router(cfg, params, replicas=, fault_plan=) / FaultPlan
        DP router over N replica engines with heartbeat failover,
        deterministic fault injection + recovery (FaultPlan.recover/
        flap), deadlines, and bounded-queue load shedding with retry
        backoff (docs/serving.md §router).
    OverloadConfig(window_ticks=, queue_high=, ttft_p99_high=)
        Windowed brown-out controller for the Router's admission path
        (docs/serving.md §Overload & recovery).
    generate_trace(TraceConfig(...))
        Seeded synthetic request traces: Poisson/bursty arrivals,
        heavy-tail length mixes.
    run_journey(size)
        The paper's Table I, v0-v10, on the modeled v5e roofline.
    tune_kernel(kernel, key)
        Model-then-measure autotuner; winners persist to the JSON cache.
    audit_registry(kernels=None)
        Static kernel auditor: jaxpr census + rule catalog over every
        registered (kernel, version, canonical shape) — no execution
        (docs/analysis.md; `python -m repro.analyze --strict` is the CI
        gate, `python -m repro.tune validate|prune` the cache hygiene).

    import repro
    repro.list_kernels()          # ['flash', 'gpp', 'paged_decode', 'ssm']
    ach, asx = repro.dispatch("gpp", inputs, version="v10")
    k = repro.get_kernel("flash")              # Kernel descriptor
    model = repro.build_model(cfg)
    engine = repro.ServeEngine(cfg, params)
    rows = repro.run_journey("si214")
"""

from repro import _compat  # noqa: F401  (jax API shims; must import first)

# public name -> defining module; resolved lazily on first access so that
# `import repro` stays cheap and optional layers never import eagerly
_EXPORTS = {
    "get_kernel": "repro.kernels.api",
    "dispatch": "repro.kernels.api",
    "list_kernels": "repro.kernels.api",
    "ServeEngine": "repro.serve.engine",
    "Request": "repro.serve.engine",
    "PagedKVCache": "repro.serve.kvcache",
    "Router": "repro.serve.router",
    "FaultPlan": "repro.serve.router",
    "OverloadConfig": "repro.serve.router",
    "TraceConfig": "repro.serve.trace",
    "generate_trace": "repro.serve.trace",
    "build_model": "repro.models.registry",
    "run_journey": "repro.core.journey",
    "tune_kernel": "repro.tune.tuner",
    "audit_registry": "repro.analyze.rules",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}"
                             ) from None
    import importlib
    value = getattr(importlib.import_module(module), name)
    globals()[name] = value        # cache: subsequent access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
