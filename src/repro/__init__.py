"""repro: a production-scale jax_pallas system grown from the paper's
single-kernel roofline study (8 Steps to 3.7 TFLOP/s, arXiv:2008.11326)."""

from repro import _compat  # noqa: F401  (jax API shims; must import first)
