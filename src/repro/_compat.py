"""JAX version compatibility shims, applied on `import repro`.

The codebase targets the current jax API (`jax.set_mesh`, `jax.shard_map`
with `axis_names=` / `check_vma=`). On older toolchains (<= 0.4.x, the
pinned container version) those names don't exist yet, so this module
backfills them from their 0.4-era equivalents:

  * jax.set_mesh(mesh) -> returns the Mesh itself; `with jax.set_mesh(m):`
    then enters the legacy Mesh context manager (the ambient-mesh
    mechanism of that era).
  * jax.shard_map(...)  -> jax.experimental.shard_map.shard_map with
    axis_names translated to its complement `auto` set and check_vma
    mapped to check_rep.

No-ops on toolchains that already provide the new names.
"""

from __future__ import annotations

import jax

if not hasattr(jax, "set_mesh"):
    def _set_mesh(mesh):
        return mesh

    jax.set_mesh = _set_mesh


if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def _shard_map(f=None, *, mesh, in_specs, out_specs,
                   axis_names=None, check_vma=True, **kw):
        # axis_names (partial-manual) is intentionally dropped: 0.4-era
        # `auto` lowers to a PartitionId op XLA:CPU can't partition. Fully
        # manual is safe for this codebase — in_specs give global views on
        # the unnamed axes and bodies only psum/ppermute over named ones —
        # it just forgoes compiler-automatic sharding of the auto dims.
        del axis_names
        kwargs = dict(kw, check_rep=bool(check_vma))

        def bind(fn):
            return _shard_map_04(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kwargs)

        return bind if f is None else bind(f)

    jax.shard_map = _shard_map
