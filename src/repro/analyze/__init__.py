"""repro.analyze: registry-wide static kernel auditor (docs/analysis.md).

The paper's whole method is artifact-driven: every one of the 8 steps starts
from a profiler census and a roofline position, never from intuition. This
subsystem is that discipline as a pre-merge gate — it lowers every
registered `(kernel, version, problem shape)` to jaxpr **without executing
anything**, produces a per-kernel static census (FLOPs, FMA-pairable
fraction, bytes per memory level, arithmetic intensity, Pallas VMEM working
set), and runs a findings engine with stable rule IDs over the result:

    VMEM001   config VMEM working set over the hardware budget   (error)
    BLK001    clamped config cannot tile the problem dims        (error)
    DTYPE001  float dtype outside the kernel's declared set      (error)
    DUP001    duplicate (CSE-able) expensive computations        (warning)
    CACHE001  stale tuned-config cache entry                     (error)
    MODEL001  declared model_step_s below the census bound       (error)

Layers:
    hlo     — the HLO-text parsing layer (shared with core.roofline; the
              former core/hlo_analysis.py)
    census  — jaxpr walker: KernelCensus per (kernel, version, key)
    rules   — Finding engine: audit_kernel / audit_registry / RULES

CLI: `python -m repro.analyze [--strict] [--json out.json]` — the
`static-analysis` CI job runs this over the full registry and fails on any
error-severity finding.

Example::

    from repro import analyze
    report = analyze.audit_registry()
    [f.rule for f in report.findings if f.severity == "error"]   # []
"""

from __future__ import annotations

_EXPORTS = {
    "KernelCensus": "repro.analyze.census",
    "census_kernel": "repro.analyze.census",
    "Finding": "repro.analyze.rules",
    "RULES": "repro.analyze.rules",
    "AuditReport": "repro.analyze.rules",
    "audit_kernel": "repro.analyze.rules",
    "audit_registry": "repro.analyze.rules",
    "audit_tune_cache": "repro.analyze.rules",
}

__all__ = sorted(set(_EXPORTS) | {"hlo"})


def __getattr__(name: str):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro.analyze' has no attribute "
                             f"{name!r}") from None
    import importlib
    value = getattr(importlib.import_module(module), name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
