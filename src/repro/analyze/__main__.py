"""Static kernel auditor CLI — the CI lint gate (docs/analysis.md §CLI).

    python -m repro.analyze [--strict] [--json OUT] [--kernel NAME ...]
                            [--cache-dir DIR] [--no-cache]
                            [--extra-module MOD ...]

Censuses every registered `(kernel, version, canonical shape)` by tracing
to jaxpr — no kernel is executed — and runs the rule catalog (VMEM001,
BLK001, DTYPE001, DUP001, CACHE001, MODEL001). `--strict` exits 1 on any
error-severity finding; `--json` writes the full `repro-analyze/v1` report
(the CI artifact). `--extra-module` imports additional modules first so
out-of-tree kernels can register themselves before the audit.
"""

from __future__ import annotations

import argparse
import importlib
import json
import sys

from repro.analyze import rules


def _fmt_si(x: float) -> str:
    for div, suffix in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if x >= div:
            return f"{x / div:.2f}{suffix}"
    return f"{x:.0f}"


def _print_report(report: rules.AuditReport) -> None:
    hdr = (f"{'kernel':7s} {'version':8s} {'shape':22s} {'flops':>8s} "
           f"{'fma%':>5s} {'AI':>7s} {'vmem':>9s} {'grid':>5s} "
           f"{'model_s':>9s} {'bound_s':>9s}")
    print(hdr)
    print("-" * len(hdr))
    for c in report.censuses:
        vmem = c.vmem_config_bytes if c.vmem_config_bytes is not None \
            else c.vmem_block_bytes
        print(f"{c.kernel:7s} {c.version:8s} {c.key_dims:22s} "
              f"{_fmt_si(c.flops):>8s} {100 * c.fma_fraction:4.0f}% "
              f"{c.arithmetic_intensity:7.1f} "
              f"{_fmt_si(vmem) + 'B' if vmem else '-':>9s} "
              f"{c.grid_instances:5d} "
              f"{c.model_s if c.model_s is not None else float('nan'):9.3g} "
              f"{c.bound_s:9.3g}")
    print()
    for f in report.findings:
        print(f"[{f.severity.upper():7s}] {f.rule} "
              f"{f.kernel}/{f.version}@{f.key_dims}: {f.message}")
    print(f"{len(report.censuses)} censuses, {len(report.errors)} errors, "
          f"{len(report.warnings)} warnings")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.analyze",
                                description=__doc__.splitlines()[0])
    p.add_argument("--strict", action="store_true",
                   help="exit 1 on any error-severity finding (CI gate)")
    p.add_argument("--json", metavar="OUT", default=None,
                   help="write the repro-analyze/v1 JSON report here")
    p.add_argument("--kernel", action="append", default=None,
                   help="audit only this family (repeatable; default all)")
    p.add_argument("--cache-dir", default=None,
                   help="tune cache for CACHE001 (default: "
                        "$REPRO_TUNE_CACHE or runs/tune)")
    p.add_argument("--no-cache", action="store_true",
                   help="skip the CACHE001 tune-cache audit")
    p.add_argument("--extra-module", action="append", default=[],
                   help="import this module before auditing (registers "
                        "out-of-tree kernels; repeatable)")
    args = p.parse_args(argv)

    for mod in args.extra_module:
        importlib.import_module(mod)

    report = rules.audit_registry(args.kernel, cache_dir=args.cache_dir,
                                  skip_cache=args.no_cache)
    _print_report(report)

    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report.to_json(), fh, indent=1, sort_keys=True)
        print(f"wrote {args.json}")

    if args.strict and report.errors:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
