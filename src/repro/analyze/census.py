"""Static per-kernel census: lower a registered `(kernel, version, problem
shape)` to jaxpr and count what it would execute — WITHOUT running the
kernel (docs/analysis.md §Census).

This is the registry-wide generalization of the paper's Nsight census: the
analogue of its FMA-ratio, register-pressure and memory-traffic counters,
derived from the traced jaxpr instead of a profiler run:

  * `flops` / `dot_flops` — every floating/complex arithmetic primitive
    counted at 1 FLOP per output element (dots at 2·M·N·K), scaled through
    `scan` lengths and `pallas_call` grids;
  * `fma_fraction` — the fraction of FLOPs that can retire as mul+add FMA
    pairs (`2·min(mul, add/sub) / flops`), the paper's 58%-FMA lens; the
    `core.vpu_model` PASSES/FLOPS tables charge exactly these pairs 2
    FLOPs per VPU pass, so the census fraction is directly comparable to
    a version's OpMix (`fma·2 / flops`);
  * bytes per memory level — compulsory HBM traffic (top-level operand +
    result avals) and the Pallas VMEM block working set read off the
    kernel's BlockSpecs (double-buffered);
  * `bound_s` — the census-derived roofline lower bound
    `max(flops/ceiling, hbm_bytes/bw)` with the MXU/VPU customized ceiling,
    which the MODEL001 drift rule holds each kernel's declared
    `model_step_s` against;
  * structural counters — pallas grid instances, statically-unbounded
    `while` loops, duplicate (CSE-able) expensive equations.

Branches (`cond` / `pl.when`) are counted at their most expensive branch —
the census is an upper estimate there, which is why MODEL001 compares with
a tolerance instead of exact equality.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.hw import TPU_V5E
from repro.core.roofline import customized_ceiling

# primitive classes (jaxpr primitive names). Everything here counts 1 FLOP
# per output element when any operand/result dtype is inexact; dots are
# counted at 2·result·contraction. Data-movement primitives are free.
_MUL_OPS = {"mul"}
_ADDSUB_OPS = {"add", "sub", "add_any"}
_EW_OPS = {
    "div", "rsqrt", "sqrt", "cbrt", "exp", "exp2", "expm1", "log", "log1p",
    "tanh", "logistic", "pow", "integer_pow", "erf", "erfc", "erf_inv",
    "sin", "cos", "tan", "atan2", "rem", "neg", "abs", "sign", "max", "min",
    "floor", "ceil", "round", "clamp", "nextafter", "select_n", "square",
    "ge", "gt", "le", "lt", "eq", "ne", "and", "or", "xor", "not",
    "is_finite", "real", "imag", "conj", "complex",
}
_REDUCE_OPS = {
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "argmax", "argmin", "cumsum", "cummax", "cummin",
    "cumprod", "cumlogsumexp",
}
# eqns cheaper than this many FLOPs are not fingerprinted for duplicates
DUP_MIN_FLOPS = 1024.0


def _aval_elems(aval) -> float:
    n = 1.0
    for d in getattr(aval, "shape", ()):
        n *= int(d)
    return n


def _aval_bytes(aval) -> float:
    try:
        return _aval_elems(aval) * np.dtype(aval.dtype).itemsize
    except Exception:
        return 0.0


def _is_inexact(aval) -> bool:
    dt = getattr(aval, "dtype", None)
    if dt is None:
        return False
    # jnp.issubdtype, not np: bfloat16 & friends are ml_dtypes extension
    # types outside numpy's hierarchy (np.issubdtype calls them exact)
    import jax.numpy as jnp
    return jnp.issubdtype(dt, jnp.inexact)


@dataclasses.dataclass
class JaxprCensus:
    """Raw counters accumulated by the jaxpr walk (all loop/grid-scaled)."""
    flops: float = 0.0
    dot_flops: float = 0.0
    mul_flops: float = 0.0
    addsub_flops: float = 0.0
    float_dtypes: set = dataclasses.field(default_factory=set)
    grid_instances: int = 0
    vmem_block_bytes: int = 0          # max working set over pallas_calls
    unbounded_loops: int = 0
    duplicate_eqns: int = 0
    duplicate_flops: float = 0.0

    @property
    def fma_flops(self) -> float:
        """FLOPs retiring in mul+add pairs: 2 per pairable (mul, add)."""
        return 2.0 * min(self.mul_flops, self.addsub_flops)

    @property
    def fma_fraction(self) -> float:
        return self.fma_flops / self.flops if self.flops > 0 else 0.0

    def _merge_max(self, other: "JaxprCensus") -> None:
        """Branch merge: numeric counters from the more expensive branch
        are already chosen by the caller; dtypes union unconditionally."""
        self.float_dtypes |= other.float_dtypes


def _eqn_flops(eqn) -> Tuple[float, float, str]:
    """(flops, dot_flops, klass) for one equation, unscaled."""
    name = eqn.primitive.name
    inexact = any(_is_inexact(v.aval) for v in eqn.invars
                  if hasattr(v, "aval")) or \
        any(_is_inexact(v.aval) for v in eqn.outvars)
    if not inexact:
        return 0.0, 0.0, "int"
    if name in ("dot_general",):
        out = eqn.outvars[0].aval
        (lhs_c, _), _ = eqn.params["dimension_numbers"]
        lhs = eqn.invars[0].aval
        contract = 1.0
        for d in lhs_c:
            contract *= int(lhs.shape[d])
        f = 2.0 * _aval_elems(out) * contract
        return f, f, "dot"
    if name in _MUL_OPS:
        return _aval_elems(eqn.outvars[0].aval), 0.0, "mul"
    if name in _ADDSUB_OPS:
        return _aval_elems(eqn.outvars[0].aval), 0.0, "addsub"
    if name in _EW_OPS:
        return _aval_elems(eqn.outvars[0].aval), 0.0, "ew"
    if name in _REDUCE_OPS:
        src = eqn.invars[0]
        n = _aval_elems(src.aval) if hasattr(src, "aval") else 0.0
        return n, 0.0, "addsub" if name in ("reduce_sum", "cumsum") else "ew"
    return 0.0, 0.0, "free"


def _sub_jaxprs(params: Dict) -> List[Tuple[Any, float]]:
    """(jaxpr, multiplier) pairs hidden in a primitive's params — the
    generic fallback for call-like primitives."""
    out = []
    for v in params.values():
        if hasattr(v, "jaxpr") and hasattr(v, "eqns") is False:
            out.append((v.jaxpr, 1.0))          # ClosedJaxpr
        elif hasattr(v, "eqns"):
            out.append((v, 1.0))                # raw Jaxpr
        elif isinstance(v, (tuple, list)):
            for item in v:
                if hasattr(item, "jaxpr"):
                    out.append((item.jaxpr, 1.0))
                elif hasattr(item, "eqns"):
                    out.append((item, 1.0))
    return out


def _dup_key(eqn):
    """Fingerprint for CSE-able duplicate detection: primitive + operand
    identities + shape. Two eqns with the same key recompute the same
    value (remat-style waste, the paper's duplicate-dot lens)."""
    ops = []
    for v in eqn.invars:
        if hasattr(v, "aval") and hasattr(v, "count"):
            ops.append(("v", id(v)))
        else:  # Literal
            ops.append(("l", str(getattr(v, "val", v))))
    shape = tuple(getattr(eqn.outvars[0].aval, "shape", ())) \
        if eqn.outvars else ()
    return (eqn.primitive.name, tuple(ops), shape)


def _census_branch(jaxpr, scale: float) -> JaxprCensus:
    c = JaxprCensus()
    _walk(jaxpr, scale, c)
    return c


def _walk(jaxpr, scale: float, out: JaxprCensus) -> None:
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)        # ClosedJaxpr -> Jaxpr
    seen: Dict[Any, int] = {}
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and _is_inexact(aval):
                out.float_dtypes.add(str(aval.dtype))

        if name == "scan":
            length = float(eqn.params.get("length", 1))
            _walk(eqn.params["jaxpr"], scale * length, out)
            continue
        if name == "while":
            out.unbounded_loops += 1
            _walk(eqn.params["body_jaxpr"], scale, out)
            _walk(eqn.params["cond_jaxpr"], scale, out)
            continue
        if name == "cond":
            branches = [_census_branch(b, scale)
                        for b in eqn.params["branches"]]
            best = max(branches, key=lambda c: c.flops, default=None)
            if best is not None:
                for other in branches:
                    best._merge_max(other)
                out.flops += best.flops
                out.dot_flops += best.dot_flops
                out.mul_flops += best.mul_flops
                out.addsub_flops += best.addsub_flops
                out.float_dtypes |= best.float_dtypes
                out.grid_instances += best.grid_instances
                out.vmem_block_bytes = max(out.vmem_block_bytes,
                                           best.vmem_block_bytes)
                out.unbounded_loops += best.unbounded_loops
                out.duplicate_eqns += best.duplicate_eqns
                out.duplicate_flops += best.duplicate_flops
            continue
        if name == "pallas_call":
            gm = eqn.params.get("grid_mapping")
            grid = 1.0
            for g in getattr(gm, "grid", ()) or ():
                if isinstance(g, int):
                    grid *= g
            out.grid_instances += int(grid * scale)
            vmem = 0
            for bm in getattr(gm, "block_mappings", ()) or ():
                sd = getattr(bm, "array_shape_dtype", None)
                blk = [d for d in getattr(bm, "block_shape", ())
                       if isinstance(d, int)]
                if sd is not None and blk:
                    n = 1
                    for d in blk:
                        n *= d
                    vmem += 2 * n * np.dtype(sd.dtype).itemsize  # dbl-buffer
            out.vmem_block_bytes = max(out.vmem_block_bytes, vmem)
            _walk(eqn.params["jaxpr"], scale * grid, out)
            continue
        subs = _sub_jaxprs(eqn.params)
        if subs:                                   # pjit / calls / custom_*
            for sub, mult in subs:
                _walk(sub, scale * mult, out)
            continue

        f, df, klass = _eqn_flops(eqn)
        if f <= 0.0:
            continue
        out.flops += f * scale
        out.dot_flops += df * scale
        if klass == "mul":
            out.mul_flops += f * scale
        elif klass == "addsub":
            out.addsub_flops += f * scale
        if f >= DUP_MIN_FLOPS:
            k = _dup_key(eqn)
            n = seen.get(k, 0)
            seen[k] = n + 1
            if n:
                out.duplicate_eqns += 1
                out.duplicate_flops += f * scale


def census_jaxpr(closed) -> JaxprCensus:
    """Walk a (Closed)Jaxpr and return the scaled counters."""
    c = JaxprCensus()
    _walk(closed, 1.0, c)
    return c


# ---------------------------------------------------------------------------
# per-kernel census
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KernelCensus:
    """The auditor's per-(kernel, version, shape) record — the static
    analogue of one Nsight Compute profile (schema: docs/analysis.md)."""
    kernel: str
    version: str
    key_name: str
    key_dims: str
    flops: float
    dot_flops: float
    fma_flops: float
    fma_fraction: float
    hbm_bytes: float                    # compulsory: operands + results
    vmem_block_bytes: Optional[int]     # BlockSpec working set (pallas)
    vmem_config_bytes: Optional[int]    # the config's declared VMEM model
    arithmetic_intensity: float         # flops / hbm_bytes
    grid_instances: int
    unbounded_loops: int
    duplicate_eqns: int
    duplicate_flops: float
    float_dtypes: Tuple[str, ...]
    bound_s: float                      # census roofline lower bound
    model_s: Optional[float]            # declared model_step_s (if any)
    config: Optional[Dict] = None

    def row(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["float_dtypes"] = list(self.float_dtypes)
        return d


def resolve_config(k, version: str, key) -> Optional[Any]:
    """The config the auditor (and dispatch, absent a measured cache)
    charges this version with: the clamped static config when the version
    has one, else the model-ranked top candidate for tunable versions.
    Fully deterministic — never reads the tune cache, never measures."""
    cfg = k.static_config(key, version)
    if cfg is None and version in k.tunable:
        from repro.tune import tuner
        ranked = tuner.rank_kernel(k.name, key, version=version)
        if ranked:
            cfg = k.finalize_config(ranked[0][0], version)
    return cfg


def census_kernel(kernel, version: str, key, *, config: Any = None
                  ) -> KernelCensus:
    """Trace `(kernel, version, key)` to jaxpr and census it statically.

    Inputs come from the kernel's `make_example` (synthesis only — the
    traced function itself is never executed); `config=None` resolves via
    `resolve_config`. Works for every registered family, Pallas or
    pure-JAX.

    Example::

        from repro.analyze.census import census_kernel
        from repro.kernels import api
        from repro.kernels.gpp import problem
        c = census_kernel(api.get_kernel("gpp"), "v10", problem.TINY)
        c.flops > 0 and 0 <= c.fma_fraction <= 1    # True
    """
    from repro.kernels import api
    k = api.get_kernel(kernel) if isinstance(kernel, str) else kernel
    cfg = config if config is not None else resolve_config(k, version, key)
    args, kwargs = k.make_example(key)

    def traced(*a):
        return k.run(*a, version=version, config=cfg, interpret=True,
                     **kwargs)

    closed = jax.make_jaxpr(traced)(*args)
    jc = census_jaxpr(closed)

    hbm = sum(_aval_bytes(v.aval) for v in closed.jaxpr.invars)
    hbm += sum(_aval_bytes(v.aval) for v in closed.jaxpr.outvars)

    vmem_cfg = None
    if cfg is not None:
        clamped = k.clamp(cfg, key)
        vmem_cfg = k.config_vmem_bytes(clamped, key)

    model_s = None
    if cfg is not None:
        try:
            model_s = float(k.model_step_s(key, cfg, version))
        except Exception:
            model_s = None

    peak = customized_ceiling(jc.flops, jc.dot_flops)
    bound_s = max(jc.flops / peak if peak > 0 else 0.0,
                  hbm / TPU_V5E.hbm_bw)

    return KernelCensus(
        kernel=k.name,
        version=version,
        key_name=getattr(key, "name", "?"),
        key_dims=key.key_dims(),
        flops=jc.flops,
        dot_flops=jc.dot_flops,
        fma_flops=jc.fma_flops,
        fma_fraction=jc.fma_fraction,
        hbm_bytes=hbm,
        vmem_block_bytes=jc.vmem_block_bytes or None,
        vmem_config_bytes=vmem_cfg,
        arithmetic_intensity=jc.flops / hbm if hbm > 0 else 0.0,
        grid_instances=jc.grid_instances,
        unbounded_loops=jc.unbounded_loops,
        duplicate_eqns=jc.duplicate_eqns,
        duplicate_flops=jc.duplicate_flops,
        float_dtypes=tuple(sorted(jc.float_dtypes)),
        bound_s=bound_s,
        model_s=model_s,
        config=k.config_to_json(cfg) if cfg is not None else None,
    )
