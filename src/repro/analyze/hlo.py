"""HLO-text parsing layer of `repro.analyze` — the 'Nsight Compute' of
this framework.

The paper drives every optimization step from profiler artifacts (Nsight
Compute sampling data + roofline dots). On a CPU-only container targeting TPU,
the equivalent artifact is the compiled HLO module: this file extracts

  * collective traffic by op kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), summing operand bytes -- the numerator of
    the roofline collective term;
  * matmul (MXU-eligible) FLOPs from `dot` ops, used for the customized
    ceiling (the TPU analogue of the paper's 58%-FMA ceiling);
  * remat / duplication census (duplicate op fingerprints => recompute waste);
  * layout-change census (transpose/copy bytes, the paper's v7 lens);
  * select census (branching-as-masks, the paper's v2 lens).

Shape lookup is two-pass: pass 1 records every instruction's result shape(s);
pass 2 resolves operand names against that table. Works on plain
compiled.as_text() output in both operand spellings XLA emits:

  * classic `%name` prefixed instructions/operands, and
  * post-SPMD / newer dumps that print *bare* names (`out = f32[8]{0}
    add(a, b)`) — operands are then recovered by splitting the call body on
    top-level commas and taking each argument's trailing identifier.

Bounded-dynamic dims (`f32[<=8,4]`) are counted at their bound rather than
silently dropped.

This module is stdlib-only (regex over text, no jax import) so the serve/
dist bench paths, `core.roofline`, and the `repro.analyze` auditor all share
one census implementation. `repro.core.hlo_analysis` re-exports everything
here for backward compatibility.
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Dict, List, Optional, Tuple

from repro.core.hw import DTYPE_BYTES

__all__ = [
    "COLLECTIVE_OPS", "CollectiveStats", "ModuleCensus", "ModuleCost",
    "collect_collectives", "collect_dot_flops", "census", "module_cost",
]

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# `f32[1024,512]{1,0}` / `bf16[8]` / scalar `f32[]` / bounded `f32[<=8,4]`
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[((?:<=)?[0-9,<=]*)\]")
_OPERAND_RE = re.compile(r"%([A-Za-z0-9_.\-]+)")
_BARE_NAME_RE = re.compile(r"[A-Za-z_][\w.\-]*")
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _parse_shapes(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in DTYPE_BYTES:
            # bounded-dynamic dims (`<=8`) count at the bound
            out.append((dt, [int(d.replace("<=", ""))
                             for d in dims.split(",") if d.strip("<=")]))
    return out


def _shape_list_bytes(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    shapes: List[Tuple[str, List[int]]]  # result shape(s)
    operands: List[str]
    line: str


# definition head: `%name = ` or (post-SPMD bare spelling) `name = `
_DEF_NAME_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(?:%(?P<pct>[\w.\-]+)|(?P<bare>[A-Za-z_][\w.\-]*))"
    r"\s*=\s*")
_OP_NAME_RE = re.compile(r"\s*([a-z][a-z0-9\-]*)\(")


def _operand_names(body: str) -> List[str]:
    """Operand names from a call body. `%`-prefixed spellings are matched
    directly; bare spellings split the body on top-level commas and take
    each argument's trailing identifier token (skipping literals like
    `constant(12)`'s `12`, whose token has no leading letter)."""
    if "%" in body:
        return _OPERAND_RE.findall(body)
    pieces, piece, depth = [], [], 0
    for ch in body:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            pieces.append("".join(piece))
            piece = []
        else:
            piece.append(ch)
    pieces.append("".join(piece))
    names = []
    for p in pieces:
        toks = p.strip().split()
        if not toks:
            continue
        m = _BARE_NAME_RE.fullmatch(toks[-1])
        if m and toks[-1] not in ("true", "false", "inf", "nan"):
            names.append(toks[-1])
    return names


def _parse_def(line: str) -> Optional[_Instr]:
    """Robustly parse `%name = <shape|tuple> opname(operands...), attrs`.
    Handles tuple result shapes containing `/*index=N*/` comments (which
    break naive regexes on `=`) and bare (un-`%`-prefixed) names."""
    m = _DEF_NAME_RE.match(line)
    if m is None:
        return None
    name = m.group("pct") or m.group("bare")
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        shape_text = rest[: end + 1]
        rest = rest[end + 1:]
    else:
        sp = re.match(r"\S+", rest)
        if sp is None:
            return None
        shape_text = sp.group(0)
        rest = rest[sp.end():]
    om = _OP_NAME_RE.match(rest)
    if om is None:
        return None
    op = om.group(1)
    after = rest[om.end():]
    depth = 1
    end = len(after)
    for i, ch in enumerate(after):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = _operand_names(after[:end])
    return _Instr(name, op, _parse_shapes(shape_text), operands, line.strip())


def _parse_instructions(hlo_text: str) -> List[_Instr]:
    instrs: List[_Instr] = []
    for line in hlo_text.splitlines():
        ins = _parse_def(line)
        if ins is not None:
            instrs.append(ins)
    return instrs


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]
    ops: List[tuple]  # (kind, bytes, line[:160])

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def collect_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective op in the (per-device) module.

    Async pairs (`*-start`/`*-done`) are counted once, on the start half.
    Operand shapes are resolved via the definition table; if an operand is a
    parameter (defined without an op match) we fall back to the collective's
    own result shape, adjusted per-kind (all-gather results are group_size x
    operand size; reduce-scatter results are 1/group_size).
    """
    instrs = _parse_instructions(hlo_text)
    table: Dict[str, List[Tuple[str, List[int]]]] = {}
    for ins in instrs:
        table[ins.name] = ins.shapes

    bytes_by_kind: Counter = Counter()
    count_by_kind: Counter = Counter()
    ops: List[tuple] = []

    for ins in instrs:
        base = None
        for kind in COLLECTIVE_OPS:
            if ins.op == kind or ins.op == kind + "-start":
                base = kind
                break
        if base is None:
            continue
        nbytes = 0
        resolved = [table[o] for o in ins.operands if o in table and table[o]]
        if resolved:
            for shapes in resolved:
                nbytes += _shape_list_bytes(shapes)
        else:
            nbytes = _shape_list_bytes(ins.shapes)
        bytes_by_kind[base] += nbytes
        count_by_kind[base] += 1
        ops.append((base, nbytes, ins.line[:160]))

    return CollectiveStats(dict(bytes_by_kind), dict(count_by_kind), ops)


def collect_dot_flops(hlo_text: str) -> float:
    """Estimate MXU-eligible FLOPs: 2 * prod(result dims) * contraction size.

    Resolves the lhs operand's shape through the definition table and reads
    `lhs_contracting_dims` off the dot line. Convolutions are counted via
    their result size * 2 * kernel-volume when present (rare in this repo).
    """
    instrs = _parse_instructions(hlo_text)
    table: Dict[str, List[Tuple[str, List[int]]]] = {i.name: i.shapes for i in instrs}
    total = 0.0
    for ins in instrs:
        if ins.op != "dot":
            continue
        if not ins.shapes:
            continue
        result_elems = 1
        for d in ins.shapes[0][1]:
            result_elems *= d
        cm = _DOT_CONTRACT_RE.search(ins.line)
        if cm is None or not ins.operands:
            continue
        lhs_shapes = table.get(ins.operands[0]) or []
        if not lhs_shapes:
            continue
        lhs_dims = lhs_shapes[0][1]
        contract = 1
        for i in [int(x) for x in cm.group(1).split(",") if x]:
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
        total += 2.0 * result_elems * contract
    return total


@dataclasses.dataclass
class ModuleCensus:
    """Structural health metrics for a compiled module (the v2/v6/v7 lenses)."""
    op_counts: Dict[str, int]
    duplicate_dot_ratio: float  # >1.0 means remat-style recompute of matmuls
    transpose_bytes: int        # layout churn (paper v7 lens)
    select_count: int           # branching-as-selects (paper v2 lens)
    fusion_count: int

    def summary(self) -> str:
        return (
            f"fusions={self.fusion_count} selects={self.select_count} "
            f"transpose_bytes={self.transpose_bytes:,} "
            f"dup_dot_ratio={self.duplicate_dot_ratio:.3f}"
        )


def census(hlo_text: str) -> ModuleCensus:
    instrs = _parse_instructions(hlo_text)
    op_counts: Counter = Counter()
    transpose_bytes = 0
    select_count = 0
    fusion_count = 0
    dot_fingerprints: Counter = Counter()

    for ins in instrs:
        op_counts[ins.op] += 1
        if ins.op in ("transpose", "copy"):
            transpose_bytes += _shape_list_bytes(ins.shapes)
        elif ins.op == "select":
            select_count += 1
        elif ins.op == "fusion":
            fusion_count += 1
        elif ins.op == "dot":
            # fingerprint by shape only (operand names differ across remat copies)
            dot_fingerprints[tuple((dt, tuple(d)) for dt, d in ins.shapes)] += 1

    total_dots = sum(dot_fingerprints.values())
    uniq_dots = len(dot_fingerprints)
    ratio = (total_dots / uniq_dots) if uniq_dots else 1.0

    return ModuleCensus(
        op_counts=dict(op_counts),
        duplicate_dot_ratio=ratio,
        transpose_bytes=transpose_bytes,
        select_count=select_count,
        fusion_count=fusion_count,
    )


# ===========================================================================
# loop-aware whole-module cost (fixes XLA cost_analysis undercounting:
# while-loop bodies are counted ONCE by cost_analysis, but a scanned
# 64-layer model executes the body 64 times — this walker scales by trip
# count, which is what makes the §Roofline table correct for scanned models)
# ===========================================================================

_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_INT_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")

# 1 flop per output element
_EW_OPS = {
    "add", "subtract", "multiply", "divide", "negate", "abs", "maximum",
    "minimum", "compare", "select", "and", "or", "not", "xor", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "rsqrt",
    "sqrt", "cbrt", "tanh", "logistic", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "atan2", "clamp",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder", "cosine", "sine", "is-finite", "expm1", "erf",
}
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "custom-call", "reshape", "iota",
    "partition-id", "replica-id", "rng-bit-generator",
}


@dataclasses.dataclass
class ModuleCost:
    flops: float                    # loop-scaled total flops (all ops)
    dot_flops: float                # loop-scaled matmul flops (MXU share)
    hbm_bytes: float                # loop-scaled operand+result bytes at
                                    # fusion granularity (HBM traffic model)
    collective_bytes: float
    collective_bytes_by_kind: Dict[str, float]
    collective_count_by_kind: Dict[str, float]
    while_trips: List[int]


def _split_computations(hlo_text: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    name = None
    entry = None
    for line in hlo_text.splitlines():
        m = _COMP_HEADER_RE.match(line.strip())
        if m and ("->" in line):
            name = m.group(2)
            if m.group(1):
                entry = name
            comps[name] = []
            continue
        if name is None:
            continue
        if line.strip() == "}":
            name = None
            continue
        comps.setdefault(name, [])
        ins = _parse_def(line)
        if ins is not None:
            comps[name].append(ins)
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry
    return comps


def _instr_flops(ins: _Instr, table) -> Tuple[float, float]:
    """(flops, dot_flops) for one instruction."""
    if ins.op == "dot":
        if not ins.shapes or not ins.operands:
            return 0.0, 0.0
        result_elems = 1
        for d in ins.shapes[0][1]:
            result_elems *= d
        cm = _DOT_CONTRACT_RE.search(ins.line)
        lhs_shapes = table.get(ins.operands[0]) or []
        if cm is None or not lhs_shapes:
            return 0.0, 0.0
        lhs_dims = lhs_shapes[0][1]
        contract = 1
        for i in [int(x) for x in cm.group(1).split(",") if x]:
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
        f = 2.0 * result_elems * contract
        return f, f
    if ins.op in _EW_OPS:
        n = 1
        for d in (ins.shapes[0][1] if ins.shapes else []):
            n *= d
        return float(n), 0.0
    if ins.op in ("reduce", "reduce-window", "cumsum"):
        # count input elements of the first operand
        sh = table.get(ins.operands[0]) if ins.operands else None
        n = 1
        for d in (sh[0][1] if sh else []):
            n *= d
        return float(n), 0.0
    return 0.0, 0.0


def _instr_bytes(ins: _Instr, table) -> float:
    """Bytes touched by one instruction (HBM traffic model).

    Slice-family ops only touch the slice, not the whole operand:
      dynamic-slice/slice/gather        -> result bytes x2 (read + write)
      dynamic-update-slice/scatter      -> update operand x2 (in-place on TPU)
    Everything else: operands + results.
    """
    if ins.op in _FREE_OPS or ins.op.endswith("-done"):
        return 0.0
    if ins.op in ("dynamic-slice", "slice", "gather", "broadcast"):
        return 2.0 * _shape_list_bytes(ins.shapes)
    if ins.op in ("dynamic-update-slice", "scatter"):
        # update operand is the second one
        upd = table.get(ins.operands[1]) if len(ins.operands) > 1 else None
        return 2.0 * (_shape_list_bytes(upd) if upd
                      else _shape_list_bytes(ins.shapes))
    total = _shape_list_bytes(ins.shapes)
    for o in ins.operands:
        sh = table.get(o)
        if sh:
            total += _shape_list_bytes(sh)
    return float(total)


def module_cost(hlo_text: str, *, max_depth: int = 32) -> ModuleCost:
    """Loop-aware module cost via scale propagation over the call graph.

    scale(entry)=1; every computation referenced from a scaled computation
    inherits scale x multiplier, where multiplier = trip count for while
    bodies and 1 for fusions/calls/conditionals. Costs are then summed as
    scale(comp) x own_cost(comp). Fusion bodies contribute flops only (their
    internals never touch HBM).
    """
    comps = _split_computations(hlo_text)
    comps.pop("__entry__", None)
    entry = comps.pop("__entry_name__", None)

    table: Dict[str, List[Tuple[str, List[int]]]] = {}
    for instrs in comps.values():
        for ins in instrs:
            table[ins.name] = ins.shapes

    ref_re = re.compile(r"(calls|to_apply|condition|body)=%?([\w.\-]+)")
    branches_re = re.compile(r"branch_computations=\{([^}]*)\}")

    def trip_count(cond_comp: str) -> int:
        best = 1
        for ins in comps.get(cond_comp, []):
            for mm in _CONST_INT_RE.finditer(ins.line):
                best = max(best, int(mm.group(1)))
        return best

    # build edges: comp -> [(child, multiplier, via_fusion)]
    edges: Dict[str, list] = {c: [] for c in comps}
    fusion_bodies = set()
    referenced = set()
    while_trips: List[int] = []
    for cname, instrs in comps.items():
        for ins in instrs:
            body = cond = None
            for key, target in ref_re.findall(ins.line):
                referenced.add(target)
                if key == "body":
                    body = target
                elif key == "condition":
                    cond = target
                elif key == "calls":
                    if ins.op == "fusion":
                        fusion_bodies.add(target)
                    edges[cname].append((target, 1.0, ins.op == "fusion"))
                else:  # to_apply (call, reduce, sort, ...)
                    edges[cname].append((target, 1.0, ins.op not in ("call", "conditional")))
            bm = branches_re.search(ins.line)
            if bm:
                for t in _OPERAND_RE.findall(bm.group(1)):
                    referenced.add(t)
                    edges[cname].append((t, 1.0, False))
            if body is not None:
                trips = trip_count(cond) if cond else 1
                while_trips.append(trips)
                edges[cname].append((body, float(trips), False))
                if cond:
                    edges[cname].append((cond, float(trips), True))

    roots = [c for c in comps if c not in referenced]
    if entry and entry in comps:
        roots = [entry]

    # propagate scales (DAG; guard depth for safety)
    scale: Dict[str, float] = {c: 0.0 for c in comps}
    fus: Dict[str, bool] = {c: False for c in comps}

    def push(c, s, f, depth):
        if depth > max_depth or c not in comps:
            return
        scale[c] += s
        fus[c] = fus[c] or f
        for child, mult, via_fusion in edges.get(c, []):
            push(child, s * mult, f or via_fusion, depth + 1)

    for r in roots:
        push(r, 1.0, False, 0)

    cost = ModuleCost(0.0, 0.0, 0.0, 0.0, {}, {}, while_trips)
    for cname, instrs in comps.items():
        s = scale[cname]
        if s <= 0:
            continue
        in_fusion = fus[cname]
        for ins in instrs:
            f, df = _instr_flops(ins, table)
            cost.flops += f * s
            cost.dot_flops += df * s
            if not in_fusion and ins.op not in ("while", "call", "conditional"):
                cost.hbm_bytes += _instr_bytes(ins, table) * s
            base = None
            for kind in COLLECTIVE_OPS:
                if ins.op == kind or ins.op == kind + "-start":
                    base = kind
                    break
            if base is not None:
                nb = 0
                resolved = [table[o] for o in ins.operands
                            if o in table and table[o]]
                if resolved:
                    for shapes in resolved:
                        nb += _shape_list_bytes(shapes)
                else:
                    nb = _shape_list_bytes(ins.shapes)
                cost.collective_bytes += nb * s
                cost.collective_bytes_by_kind[base] = \
                    cost.collective_bytes_by_kind.get(base, 0.0) + nb * s
                cost.collective_count_by_kind[base] = \
                    cost.collective_count_by_kind.get(base, 0.0) + s
    return cost
