"""Findings engine for the static kernel auditor (docs/analysis.md §Rules).

Each rule has a stable ID and severity; `--strict` (the CI lint gate) fails
on any `error`-severity finding:

  VMEM001  error    config VMEM working set exceeds the hw budget
  BLK001   error    clamped config still cannot tile the problem dims
  DTYPE001 error    traced jaxpr touches a float dtype outside the
                    version's declared compute-path dtypes (promotion leak)
  DUP001   warning  >= DUP_FRACTION of census FLOPs recompute identical
                    expensive equations (CSE/remat waste)
  CACHE001 error    tune-cache entry is stale: kernel/version gone, config
                    unparseable, or config outside the current space
  MODEL001 error    declared model_step_s below DRIFT_TOL x the
                    census-derived roofline bound (model drift: the model
                    promises more than the hardware ceilings allow)
  KV001    error    kernel declares block-table gather buffers
                    (`gather_buffer_bytes`) its `config_vmem_bytes`
                    working set does not cover — the config would pass
                    VMEM001 while overflowing VMEM at runtime

Adding a rule: give it an ID here in `RULES`, emit `Finding`s from
`audit_kernel` (per-kernel rules) or a new collector wired into
`audit_registry`, and document it in docs/analysis.md.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.analyze.census import KernelCensus, census_kernel, resolve_config
from repro.core.hw import TPU_V5E

SEV_ERROR = "error"
SEV_WARNING = "warning"

RULES: Dict[str, Tuple[str, str]] = {
    "VMEM001": (SEV_ERROR, "config VMEM working set exceeds budget"),
    "BLK001": (SEV_ERROR, "block config cannot tile problem dims"),
    "DTYPE001": (SEV_ERROR, "float dtype outside declared compute path"),
    "DUP001": (SEV_WARNING, "duplicate expensive computation"),
    "CACHE001": (SEV_ERROR, "stale tuned-config cache entry"),
    "MODEL001": (SEV_ERROR, "model drift vs census roofline bound"),
    "KV001": (SEV_ERROR, "VMEM model ignores block-table gather buffers"),
}

# DUP001 fires when recomputed FLOPs exceed this fraction of the census
DUP_FRACTION = 0.10
# MODEL001 fires when model_step_s < DRIFT_TOL * bound_s. The census is an
# upper estimate (cond counts its most expensive branch), so the tolerance
# is generous; only a model promising to beat the hardware ceilings by
# >2.5x is drift. No upper-bound check: models legitimately sit far above
# the bound (lane under-fill, grid overhead).
DRIFT_TOL = 0.4


@dataclasses.dataclass(frozen=True)
class Finding:
    """One audit finding, addressable by stable rule ID."""
    rule: str
    severity: str
    kernel: str
    version: str
    key_dims: str
    message: str
    data: Tuple[Tuple[str, Any], ...] = ()

    def row(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["data"] = dict(self.data)
        return d


def _finding(rule: str, kernel: str, version: str, key_dims: str,
             message: str, **data) -> Finding:
    sev, _ = RULES[rule]
    return Finding(rule=rule, severity=sev, kernel=kernel, version=version,
                   key_dims=key_dims, message=message,
                   data=tuple(sorted(data.items())))


def audit_kernel(kernel, version: str, key, *, hw=TPU_V5E
                 ) -> Tuple[KernelCensus, List[Finding]]:
    """Census one `(kernel, version, key)` and run every per-kernel rule
    against it. Returns the census plus findings (possibly empty)."""
    from repro.kernels import api
    k = api.get_kernel(kernel) if isinstance(kernel, str) else kernel
    census = census_kernel(k, version, key)
    kd = census.key_dims
    findings: List[Finding] = []

    cfg = resolve_config(k, version, key)
    if cfg is not None:
        clamped = k.clamp(cfg, key)
        vmem = k.config_vmem_bytes(clamped, key)
        if vmem is not None and vmem > hw.vmem_bytes:
            findings.append(_finding(
                "VMEM001", k.name, version, kd,
                f"config needs {vmem} B VMEM > budget {hw.vmem_bytes} B",
                vmem_bytes=vmem, budget_bytes=hw.vmem_bytes))
        for violation in k.config_divides(clamped, key):
            findings.append(_finding(
                "BLK001", k.name, version, kd,
                f"clamped config cannot tile problem: {violation}"))
        gather = k.gather_buffer_bytes(clamped, key)
        if gather is not None and (vmem is None or vmem < gather):
            findings.append(_finding(
                "KV001", k.name, version, kd,
                f"declared gather buffers need {gather} B but the config "
                f"VMEM model covers "
                f"{'nothing' if vmem is None else f'only {vmem} B'}",
                gather_bytes=gather, vmem_bytes=vmem))

    allowed = k.allowed_float_dtypes(version)
    if allowed:
        leaked = sorted(set(census.float_dtypes) - set(allowed))
        if leaked:
            findings.append(_finding(
                "DTYPE001", k.name, version, kd,
                f"jaxpr touches {leaked} outside declared "
                f"{sorted(allowed)}", leaked=leaked))

    if (census.flops > 0 and census.duplicate_eqns > 0
            and census.duplicate_flops / census.flops > DUP_FRACTION):
        frac = census.duplicate_flops / census.flops
        findings.append(_finding(
            "DUP001", k.name, version, kd,
            f"{census.duplicate_eqns} duplicate eqns recompute "
            f"{100 * frac:.0f}% of census FLOPs",
            duplicate_eqns=census.duplicate_eqns,
            duplicate_flops=census.duplicate_flops))

    if census.model_s is not None and census.bound_s > 0 \
            and census.model_s < DRIFT_TOL * census.bound_s:
        findings.append(_finding(
            "MODEL001", k.name, version, kd,
            f"model_step_s {census.model_s:.3g}s < {DRIFT_TOL} x census "
            f"roofline bound {census.bound_s:.3g}s",
            model_s=census.model_s, bound_s=census.bound_s,
            ratio=census.model_s / census.bound_s))

    return census, findings


def audit_tune_cache(cache_dir: Optional[str] = None) -> List[Finding]:
    """CACHE001 over the tuned-config cache, via the read-only half of the
    `repro.tune` hygiene tooling (`cache_tools.validate_cache`)."""
    from repro.tune import cache_tools
    out = []
    for issue in cache_tools.validate_cache(cache_dir):
        out.append(_finding(
            "CACHE001", issue.kernel or "?", issue.version or "?",
            issue.dims or "?",
            f"stale cache entry {issue.key!r}: {issue.detail}",
            cache_key=issue.key, reason=issue.reason))
    return out


@dataclasses.dataclass
class AuditReport:
    """The full registry audit: every census row + every finding."""
    censuses: List[KernelCensus]
    findings: List[Finding]

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == SEV_ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == SEV_WARNING]

    def to_json(self) -> Dict[str, Any]:
        return {
            "schema": "repro-analyze/v1",
            "rules": {rid: {"severity": sev, "title": title}
                      for rid, (sev, title) in RULES.items()},
            "censuses": [c.row() for c in self.censuses],
            "findings": [f.row() for f in self.findings],
            "n_errors": len(self.errors),
            "n_warnings": len(self.warnings),
        }


def audit_registry(kernels: Optional[List[str]] = None, *,
                   cache_dir: Optional[str] = None, hw=TPU_V5E,
                   skip_cache: bool = False) -> AuditReport:
    """Audit every registered kernel family at its canonical shapes, every
    version, plus the tune cache — the engine behind `python -m
    repro.analyze` and the CI `static-analysis` gate.

    Example::

        from repro.analyze import audit_registry
        report = audit_registry(["gpp"], skip_cache=True)
        assert not report.errors       # registry is lint-clean
    """
    from repro.kernels import api
    names = kernels if kernels is not None else api.list_kernels()
    censuses: List[KernelCensus] = []
    findings: List[Finding] = []
    for name in names:
        k = api.get_kernel(name)
        for key in k.canonical_keys():
            for version in k.versions:
                census, fs = audit_kernel(k, version, key, hw=hw)
                censuses.append(census)
                findings.extend(fs)
    if not skip_cache:
        findings.extend(audit_tune_cache(cache_dir))
    return AuditReport(censuses=censuses, findings=findings)
