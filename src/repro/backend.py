"""Shared backend policy: one place that decides whether Pallas kernels
compile for a real TPU or run in interpret mode.

Before the kernel registry, `kernels/gpp/ops.py` and `kernels/flash/ops.py`
each carried a private `_on_tpu()` and `kernels/ssm/ssm_scan.py` hardcoded
`interpret=True` — three policies that could (and did) drift. Every kernel
entry point now resolves its `interpret` default through this module.

Env override: `REPRO_INTERPRET=1` forces interpret mode even on TPU (kernel
debugging), `REPRO_INTERPRET=0` forces compiled mode (fails fast on CPU
rather than silently interpreting). Unset: autodetect (interpret iff no TPU).
"""

from __future__ import annotations

import os
from typing import Optional

INTERPRET_ENV = "REPRO_INTERPRET"


def backend_name() -> str:
    """jax.default_backend(), with a safe fallback when jax can't init."""
    try:
        import jax
        return jax.default_backend()
    except Exception:
        return "cpu"


def on_tpu() -> bool:
    try:
        import jax
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def default_interpret() -> bool:
    """Interpret-mode default for Pallas calls: the REPRO_INTERPRET env
    override when set ('1'/'true' -> True, '0'/'false' -> False),
    otherwise autodetect (interpret iff not on a TPU)."""
    env = os.environ.get(INTERPRET_ENV)
    if env is not None:
        v = env.strip().lower()
        if v in ("1", "true", "yes"):
            return True
        if v in ("0", "false", "no"):
            return False
        raise ValueError(f"{INTERPRET_ENV}={env!r}: expected 0/1")
    return not on_tpu()


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """An explicit caller choice wins; None defers to default_interpret()."""
    return default_interpret() if interpret is None else bool(interpret)
