"""Sharded checkpointing (no orbax): npz-per-leaf-group + JSON manifest,
atomic directory rename, async save thread, auto-resume, elastic re-shard.

Layout:
    <dir>/step_000100/manifest.json    {step, leaves: {path: {shape, dtype}}}
    <dir>/step_000100/data.npz         one entry per flattened leaf path
    <dir>/LATEST                       text file -> "step_000100"

Fault-tolerance contract (trainer relies on this):
  * a checkpoint is visible only after the atomic rename of its tmp dir and
    the LATEST pointer update — a host dying mid-save never corrupts state;
  * restore() works onto ANY mesh: values are materialized as numpy and
    re-sharded by device_put against the new sharding tree (elastic
    re-shard, tested 8 -> 4 devices in tests/test_checkpoint.py);
  * save is fire-and-forget from the train loop (async thread), with a
    barrier() to drain before exit.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Dict:
    root: Dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save

    def save(self, step: int, tree: PyTree, *, blocking: bool = False):
        """Snapshot to host memory synchronously, write to disk async."""
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        self.barrier()
        if blocking:
            self._write(step, flat)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat), daemon=True)
            self._thread.start()

    def _write(self, step: int, flat: Dict[str, np.ndarray]):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, f".tmp_{name}")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        # npz can't serialize ml_dtypes bfloat16 — store as uint16 view,
        # dtype recorded in the manifest for the restore path.
        store = {}
        dtypes = {}
        for k, v in flat.items():
            dtypes[k] = str(v.dtype)
            if v.dtype.name == "bfloat16":
                v = v.view(np.uint16)
            store[k.replace("/", "\x1f")] = v
        np.savez(os.path.join(tmp, "data.npz"), **store)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(v.shape), "dtype": dtypes[k]}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as fh:
            json.dump(manifest, fh, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic visibility
        self._write_latest(name)
        self._gc()

    def _write_latest(self, name: str):
        # mkstemp (unique name, same dir => same filesystem) + fsync +
        # os.replace, mirroring tune/tuner.py: a fixed-name tmp file could
        # be torn by two concurrent writers, and an unflushed pointer could
        # survive the rename as an empty/truncated LATEST after a crash.
        # Readers therefore see either the old pointer or the new one,
        # never a partial write; latest_step() additionally falls back to
        # a directory scan for the rename-to-pointer crash window.
        fd, tmp_ptr = tempfile.mkstemp(dir=self.dir, prefix=".LATEST_",
                                       suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                fh.write(name)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp_ptr, os.path.join(self.dir, "LATEST"))
        except BaseException:
            try:
                os.remove(tmp_ptr)
            except FileNotFoundError:
                pass
            raise

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    def barrier(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore

    def latest_step(self) -> Optional[int]:
        """Newest COMPLETE checkpoint step, or None. Trusts the LATEST
        pointer when it names a complete step directory, but also scans
        the directory: a crash in the window between the atomic step_*
        rename and the pointer update leaves LATEST one step behind (or,
        on a first save, absent) even though the newer checkpoint is fully
        on disk — resume must find it."""
        candidates = []
        latest = os.path.join(self.dir, "LATEST")
        if os.path.exists(latest):
            with open(latest) as fh:
                name = fh.read().strip()
            if self._complete(name):
                candidates.append(int(name.split("_")[1]))
        for d in os.listdir(self.dir):
            if d.startswith("step_") and self._complete(d):
                candidates.append(int(d.split("_")[1]))
        return max(candidates) if candidates else None

    def _complete(self, name: str) -> bool:
        """A step directory is complete iff it was atomically renamed into
        place with both its files (in-progress .tmp_ dirs never match)."""
        if not name.startswith("step_"):
            return False
        try:
            int(name.split("_")[1])
        except (IndexError, ValueError):
            return False
        d = os.path.join(self.dir, name)
        return (os.path.isdir(d)
                and os.path.exists(os.path.join(d, "manifest.json"))
                and os.path.exists(os.path.join(d, "data.npz")))

    def restore(self, step: Optional[int] = None, *,
                shardings: Optional[PyTree] = None
                ) -> Optional[Tuple[int, PyTree]]:
        """Load the given (or latest) step. With `shardings` (a pytree of
        NamedSharding matching the saved structure) values are device_put
        onto the CURRENT mesh — this is the elastic-reshard path."""
        if step is None:
            step = self.latest_step()
            if step is None:
                return None
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as fh:
            manifest = json.load(fh)
        with np.load(os.path.join(path, "data.npz")) as z:
            flat = {k.replace("\x1f", "/"): z[k] for k in z.files}
        import ml_dtypes
        for k, meta in manifest["leaves"].items():
            if meta["dtype"] == "bfloat16" and k in flat:
                flat[k] = flat[k].view(ml_dtypes.bfloat16)
        tree = _unflatten(flat)
        if shardings is not None:
            flat_sh = _flatten(shardings)
            tree = _unflatten({
                k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
                for k, v in flat.items()})
        return step, tree
