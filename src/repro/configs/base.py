"""Model/run configuration system.

One frozen dataclass covers all 10 assigned architecture families (dense /
moe / ssm / hybrid / encdec / vlm). Every src/repro/configs/<arch>.py exports
`CONFIG` built from this; the registry resolves `--arch <id>` strings.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None          # default: d_model // n_heads
    qkv_bias: bool = False                  # qwen-family
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    act: str = "swiglu"                     # swiglu | gelu
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: Optional[int] = None          # expert width (deepseek fine-grained)
    first_k_dense: int = 0                  # leading dense layers (deepseek=1)
    router_aux_coef: float = 0.01           # load-balance loss

    # --- SSM / RWKV / hybrid ---
    ssm_state: int = 0                      # mamba state size (hymba)
    rwkv_head_dim: int = 64                 # rwkv6 head size
    attn_window: int = 0                    # sliding-window attn (hymba); 0=full

    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 1500                     # stub frontend frames
    enc_d_model: Optional[int] = None

    # --- VLM ---
    n_vis_tokens: int = 0                   # stub patch embeddings prepended

    # --- training-time knobs (defaults; launch flags override) ---
    use_flash_attention: bool = False       # Pallas flash kernel (§Perf)
    ssm_impl: str = "chunked"               # chunked | scan (hymba §Perf)
    remat: str = "full"                     # none | dots | full
    optimizer: str = "adamw"                # adamw | adafactor
    # long_500k applicability: quadratic full-attention archs must skip
    subquadratic: bool = False

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family == "moe":
            assert self.n_experts > 0 and self.experts_per_token > 0
        if self.family == "ssm":
            object.__setattr__(self, "subquadratic", True)
        if self.family == "hybrid":
            object.__setattr__(self, "subquadratic", True)

    # ---- parameter counting (for the 6ND model-FLOPs convention) ----------

    def param_count(self) -> int:
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        n = 0
        n += self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                   # lm head
        dec_layers = self.n_layers

        def attn_params():
            p = d * h * hd + 2 * d * kv * hd + h * hd * d
            if self.qkv_bias:
                p += (h + 2 * kv) * hd
            return p

        def dense_ffn(ff):
            if self.act == "swiglu":
                return 3 * d * ff
            return 2 * d * ff

        if self.family in ("dense", "vlm"):
            n += dec_layers * (attn_params() + dense_ffn(self.d_ff) + 2 * d)
        elif self.family == "moe":
            ff = self.moe_d_ff or self.d_ff
            moe_layers = dec_layers - self.first_k_dense
            n += dec_layers * (attn_params() + 2 * d)
            n += self.first_k_dense * dense_ffn(self.d_ff)
            per_moe = self.n_experts * dense_ffn(ff) + self.n_shared_experts * dense_ffn(ff)
            per_moe += d * self.n_experts               # router
            n += moe_layers * per_moe
        elif self.family == "ssm":                      # rwkv6
            heads = d // self.rwkv_head_dim
            tm = 4 * d * d + d * heads * 0              # r,k,v,g? see rwkv6.py
            n += dec_layers * (5 * d * d + dense_ffn_rwkv(d, self.d_ff) + 4 * d)
        elif self.family == "hybrid":                   # hymba
            ssm_inner = d  # mamba path inner width
            mamba = 2 * d * ssm_inner + ssm_inner * (2 * self.ssm_state + 1) + ssm_inner * d
            n += dec_layers * (attn_params() + mamba + dense_ffn(self.d_ff) + 2 * d)
        elif self.family == "encdec":
            enc_d = self.enc_d_model or d
            n += self.n_enc_layers * (attn_params() + dense_ffn(self.d_ff) + 2 * d)
            # decoder self-attn + cross-attn + ffn
            n += dec_layers * (2 * attn_params() + dense_ffn(self.d_ff) + 3 * d)
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        ff = self.moe_d_ff or self.d_ff
        full = self.param_count()
        moe_layers = self.n_layers - self.first_k_dense
        inactive = moe_layers * (self.n_experts - self.experts_per_token) * (3 * d * ff)
        return int(full - inactive)

    def model_flops_per_token(self, training: bool) -> float:
        """6*N_active per token trained; 2*N_active per token decoded."""
        n = self.active_param_count()
        return (6.0 if training else 2.0) * n


def dense_ffn_rwkv(d, ff):
    # rwkv channel-mix: key d->ff, value ff->d, receptance d->d
    return d * ff + ff * d + d * d


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned set: train_4k / prefill_32k /
    decode_32k / long_500k)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str   # train | prefill | decode

    @property
    def is_training(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "rwkv6_7b",
    "llama4_maverick_400b_a17b",
    "deepseek_moe_16b",
    "phi4_mini_3_8b",
    "qwen2_1_5b",
    "codeqwen1_5_7b",
    "qwen2_5_32b",
    "whisper_small",
    "internvl2_26b",
    "hymba_1_5b",
)

# CLI aliases (--arch accepts either form)
ALIASES = {
    "rwkv6-7b": "rwkv6_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen2-1.5b": "qwen2_1_5b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "qwen2.5-32b": "qwen2_5_32b",
    "whisper-small": "whisper_small",
    "internvl2-26b": "internvl2_26b",
    "hymba-1.5b": "hymba_1_5b",
}


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    assert arch in ARCH_IDS, f"unknown arch {arch}; known: {ARCH_IDS}"
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """Which of the 4 assigned shapes a given arch runs (skips documented in
    DESIGN.md §Arch-applicability: long_500k needs sub-quadratic attention)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return tuple(names)


def reduce_config(cfg: ModelConfig, *, layers: int = 2, d_model: int = 128,
                  vocab: int = 512) -> ModelConfig:
    """Shrink a config to smoke-test size, preserving family & structure
    (ratios like GQA grouping, expert counts scaled down)."""
    head_dim = 32
    n_heads = max(2, d_model // head_dim)
    # keep the kv:q ratio if possible
    ratio = max(1, cfg.n_heads // max(1, cfg.n_kv_heads))
    n_kv = max(1, n_heads // ratio)
    while n_heads % n_kv:
        n_kv -= 1
    kw = dict(
        name=cfg.name + "-smoke",
        family=cfg.family,
        n_layers=layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_ff=d_model * 3,
        vocab_size=vocab,
        head_dim=head_dim,
        qkv_bias=cfg.qkv_bias,
        rope_theta=cfg.rope_theta,
        act=cfg.act,
        tie_embeddings=cfg.tie_embeddings,
        remat="none",
        optimizer=cfg.optimizer,
        subquadratic=cfg.subquadratic,
    )
    if cfg.family == "moe":
        kw.update(n_experts=8, experts_per_token=min(2, cfg.experts_per_token),
                  n_shared_experts=cfg.n_shared_experts, moe_d_ff=d_model * 2,
                  first_k_dense=min(1, cfg.first_k_dense))
    if cfg.family == "ssm":
        kw.update(rwkv_head_dim=32, n_heads=d_model // 32,
                  n_kv_heads=d_model // 32)
    if cfg.family == "hybrid":
        kw.update(ssm_state=cfg.ssm_state, attn_window=64)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=layers, enc_seq=64)
    if cfg.family == "vlm":
        kw.update(n_vis_tokens=8)
    return ModelConfig(**kw)
