"""DeepSeekMoE 16B — fine-grained experts: 2 shared + 64 routed top-6
[arXiv:2401.06066; hf].

Assignment d_ff=1408 is the fine-grained expert width (moe_d_ff). The first
layer is dense (first_k_dense=1) with the paper's dense FFN width 10944.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,          # assignment: GQA kv=16 (= MHA)
    d_ff=10944,             # dense-layer FFN width (paper)
    vocab_size=102400,
    n_experts=64,
    experts_per_token=6,
    n_shared_experts=2,
    moe_d_ff=1408,          # assignment's d_ff: fine-grained expert width
    first_k_dense=1,
    optimizer="adamw",
)
