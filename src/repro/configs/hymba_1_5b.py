"""Hymba 1.5B — hybrid: parallel attention + Mamba heads per layer
[arXiv:2411.13676; hf].

25 attention heads (GQA kv=5, head_dim=64) in parallel with a selective-SSM
(state=16) path; outputs are mean-fused after per-path norm, as in the paper.
Sliding-window attention (Hymba uses SWA in all but 3 layers) + full-history
SSM state makes the arch sub-quadratic -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    attn_window=1024,
    subquadratic=True,
    optimizer="adamw",
)
