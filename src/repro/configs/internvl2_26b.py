"""InternVL2-26B — InternViT frontend STUB + InternLM2-20B backbone
[arXiv:2404.16821; hf].

Assignment specifies the transformer BACKBONE only (48L d=6144 48H kv=8
d_ff=16384 vocab=92553); input_specs() supplies precomputed patch embeddings
(n_vis_tokens) prepended to the text tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    n_vis_tokens=256,
    optimizer="adafactor",
)
