"""Llama-4 Maverick 400B-A17B — MoE 128e top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Assignment config taken verbatim: 48L, d_model=5120, 40H (GQA kv=8),
d_ff=8192 per expert, vocab=202048, 128 experts top-1. Every layer is MoE
(the assignment does not specify interleaving), plus 1 shared expert as in
the Llama-4 design. Optimizer: adafactor (factored 2nd moments — required to
fit optimizer state for a 0.77T-param total config; see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    experts_per_token=1,
    n_shared_experts=1,
    moe_d_ff=8192,
    optimizer="adafactor",
)
