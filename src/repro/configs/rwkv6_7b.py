"""RWKV-6 "Finch" 7B — attention-free, data-dependent decay [arXiv:2404.05892; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,            # 4096 / rwkv_head_dim(64)
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv_head_dim=64,
    subquadratic=True,     # state is O(1) in sequence length -> runs long_500k
    optimizer="adamw",
)
