"""Whisper-small — enc-dec, conv frontend STUBBED [arXiv:2212.04356; unverified].

input_specs() supplies precomputed frame embeddings (enc_seq=1500, d=768) in
place of the log-mel conv frontend (DESIGN.md §Arch-applicability). decode
shapes exercise the decoder + cross-attention; the 32k cache length is a
shape-stress configuration beyond real Whisper's 448-token decoder cap.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,             # decoder layers
    n_enc_layers=12,
    enc_seq=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    act="gelu",
    rope_theta=0.0,          # whisper uses learned/sinusoidal pos, not RoPE
    optimizer="adamw",
)
