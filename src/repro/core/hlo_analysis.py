"""Back-compat shim: the HLO-text parsing/census layer moved to
`repro.analyze.hlo` (the parsing layer of the `repro.analyze` static
auditor), so the roofline bench paths and the registry-wide kernel auditor
share one census implementation. Every public name — and the private
helpers tests exercise — re-exports from there; new code should import
`repro.analyze.hlo` directly.
"""

from repro.analyze.hlo import *                          # noqa: F401,F403
from repro.analyze.hlo import (                          # noqa: F401
    _EW_OPS, _FREE_OPS, _Instr, _instr_bytes, _instr_flops, _operand_names,
    _parse_def, _parse_instructions, _parse_shapes, _shape_list_bytes,
    _split_computations,
)
