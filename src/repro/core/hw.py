"""Hardware model for the roofline analysis (target: TPU v5e).

The container is CPU-only; TPU v5e is the *target* machine. All roofline terms
are derived from compiled HLO artifacts against these constants, mirroring the
paper's use of machine peaks (V100: 6.7 TFLOP/s FP64 @ 1312 MHz, ~900 GB/s HBM)
as the denominators of its roofline charts.

The paper's "customized ceiling" (58% FMA ratio => 5.3 TFLOP/s attainable) is
generalized here to the MXU/VPU split: matmul FLOPs run at MXU peak, everything
else at VPU peak, and the attainable ceiling is the harmonic combination
(see roofline.customized_ceiling).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    # Peak matrix-unit throughput, FLOP/s (bf16 inputs, f32 accumulate).
    mxu_flops: float
    # Peak vector-unit throughput, FLOP/s (f32). Convention: 8x128 lanes x
    # 2 (FMA) x 4 ALU pipes x ~0.94 GHz ~= 7.7e12. Elementwise/reduction work
    # (e.g. the GPP kernel) is bounded by this roof, not the MXU roof --
    # this is the TPU analogue of the paper's FMA-ratio-customized peak.
    vpu_flops: float
    # HBM bandwidth per chip, bytes/s.
    hbm_bw: float
    # ICI link bandwidth, bytes/s per link (one direction).
    ici_bw: float
    # VMEM capacity per core, bytes (the "cache" level of the hierarchy).
    vmem_bytes: int
    # HBM capacity per chip, bytes.
    hbm_bytes: int
    # Data-path interconnect bandwidth between pods (DCN), bytes/s per host.
    dcn_bw: float = 25e9 / 8  # 25 Gb/s NIC per host, conservative

    @property
    def machine_balance(self) -> float:
        """FLOP/byte at which HBM bandwidth and MXU peak intersect.

        The paper quotes 7.4 FLOPs/Byte for V100 FP64; for v5e bf16 this is
        197e12 / 819e9 ~= 240 FLOPs/Byte -- the compute-bound bar is far
        higher on TPUs, which is why bandwidth terms dominate most of the
        baseline table.
        """
        return self.mxu_flops / self.hbm_bw

    @property
    def vpu_machine_balance(self) -> float:
        return self.vpu_flops / self.hbm_bw


# Per-chip numbers, TPU v5e (the assignment's stated constants).
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    mxu_flops=197e12,
    vpu_flops=7.7e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    vmem_bytes=16 * 2**20,  # ~16 MiB usable scratch half? full VMEM budget
    hbm_bytes=16 * 2**30,
)

# The paper's machine, kept for the GPP journey's "paper units" columns.
NVIDIA_V100 = HardwareSpec(
    name="nvidia-v100",
    mxu_flops=6.7e12,   # FP64 theoretical peak @1312MHz (paper Sec. II-C)
    vpu_flops=6.7e12,   # no MXU/VPU split on V100 FP64
    hbm_bw=900e9,
    ici_bw=25e9,        # NVLink gen2 per link
    vmem_bytes=6 * 2**20,   # L2 size as the mid-hierarchy level
    hbm_bytes=16 * 2**30,
)

DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2,
    "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}
