"""The GPP optimization journey — reproduces the paper's Table I + roofline
trajectory (Figs. 1/3/5/6) on the TPU-v5e machine model, then extends it
beyond the paper: v9 (fused VMEM scratch accumulation + parallel grid
semantics) and v10 (v9 under the repro.tune autotuner's per-size pick).

Per version v0..v10 this harness reports:
  * correctness vs the complex128 oracle (TINY problem, CPU);
  * measured CPU wall-clock at BENCH size (secondary signal — the container
    is CPU-only; the pure-JAX variants really execute, Pallas in interpret);
  * the modeled v5e roofline: VPU compute seconds from an instruction-class
    census (mul/add=1 pass, rcp=4, sqrt=8, div=8 — the TPU analogue of the
    paper's instruction-latency ledger), HBM seconds from each version's
    traffic model, plus grid/DMA issue overhead for the Pallas versions;
  * achieved TFLOP/s and the two ceilings the paper reports against:
    %-of-theoretical (VPU peak) and %-of-customized (pass-mix attainable,
    the FMA-ratio-ceiling analogue).

The machine model and instruction census live in core.vpu_model (shared
with the tuner); the names below are re-exported for compatibility.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core import roofline, vpu_model
from repro.core.hw import TPU_V5E
# re-exports: the public model-constant surface predates vpu_model
from repro.core.vpu_model import (  # noqa: F401
    FLOP_PEAK, FLOPS, GRID_OVERHEAD_FUSED_S, GRID_OVERHEAD_S, OP_MIX, PASSES,
    PASS_RATE, SCAN_OVERHEAD_S, OpMix)
from repro.kernels import api
from repro.kernels.gpp import kernel_def, pallas_gpp, problem, ref, variants
from repro.tune import tuner

VERSIONS = ("v0", "v1", "v2", "v3", "v4", "v5", "v6", "v7", "v8", "v9",
            "v10")


def _igp_stream_bytes(s: problem.GppSize) -> float:
    """v0–v3 traffic: scan over igp re-reads aqsn (and wx) every step."""
    b = s.ngpown * (2 * 4 * s.ncouls * s.nbands)        # aqsn per igp step
    b += 2 * 4 * s.ncouls * s.ngpown * 2                # wt/eps once
    b += 2 * 4 * s.ngpown * s.nbands                    # aqsm once
    b += s.ngpown * 4 * s.nw * s.nbands                 # wx per step
    return float(b)


def _ideal_cache_bytes(s: problem.GppSize) -> float:
    """v4/v5 traffic: band-serial with (ig,igp) planes assumed cache-resident
    (ideal-cache model — the GPU's L2 gave the paper this for free; the
    Pallas versions below make the same reuse explicit and exact)."""
    return s.min_hbm_bytes()


def _version_config(version: str,
                    size: problem.GppSize) -> pallas_gpp.BlockConfig:
    """The BlockConfig a journey version runs under at `size`: static for
    v6–v9, the tuner's model-ranked pick for v10 (measurement is the ops
    dispatch path's job — the journey models sizes far beyond CPU timing)."""
    if version == "v10":
        return tuner.rank(size, version="v10")[0][0]
    return pallas_gpp.CONFIGS[version]


@dataclasses.dataclass
class JourneyRow:
    version: str
    cpu_ms: Optional[float]
    rel_err: float
    report: roofline.RooflineReport
    note: str = ""

    @property
    def modeled_tflops(self) -> float:
        t = self.report.modeled_step_s
        return (self.report.flops_per_chip / t / 1e12) if t else 0.0


def _model_report(version: str, size: problem.GppSize) -> roofline.RooflineReport:
    mix = OP_MIX[version]
    iters = size.inner_iters
    flops = iters * mix.flops
    compute_s = iters * mix.passes / PASS_RATE
    overhead_s = 0.0
    extra = {}

    if version in ("v0", "v1", "v2", "v3"):
        hbm = _igp_stream_bytes(size)
        overhead_s = size.ngpown * SCAN_OVERHEAD_S
    elif version in ("v4", "v5"):
        hbm = _ideal_cache_bytes(size)
        overhead_s = size.nbands * SCAN_OVERHEAD_S
    else:
        # the SAME model the tuner ranks with (incl. lane-fill): a config
        # selected under one model must be reported under it too
        cfg = _version_config(version, size)
        hbm = vpu_model.pallas_bytes(size, cfg)
        with_ovh, _, overhead_s = vpu_model.pallas_step_terms(size, cfg, mix)
        compute_s = with_ovh - overhead_s
        extra["block_config"] = (cfg.blk_ig, cfg.blk_igp, cfg.blk_band)

    # customized attainable ceiling = flops at the pass-mix rate
    attainable = flops / (iters * mix.passes / PASS_RATE)

    extra.update({
        "overhead_s": overhead_s, "passes_per_iter": mix.passes,
        "flops_per_iter": mix.flops,
        # hierarchical roofline: the VMEM level (the paper's L1/L2
        # analogue). per-iter VMEM traffic ~= operand reads + select
        # intermediates spilled to VMEM between VPU ops (~24 f32
        # touches) — constant across versions, so AI_VMEM tracks the
        # flops-per-iter; AI_HBM is what the blocking steps move.
        "vmem_bytes": iters * 24 * 4.0,
        "ai_vmem": flops / (iters * 24 * 4.0),
        "ai_hbm": flops / hbm})

    rep = roofline.RooflineReport(
        name=f"gpp-{version}-{size.name}",
        mesh_shape=(1,),
        chips=1,
        flops_per_chip=flops,
        bytes_per_chip=hbm,
        collective_bytes_per_chip=0.0,
        mxu_flops_per_chip=0.0,
        compute_s=compute_s + overhead_s,
        memory_s=hbm / TPU_V5E.hbm_bw,
        collective_s=0.0,
        customized_peak_flops=attainable,
        mxu_fraction=0.0,
        extra=extra,
    )
    return rep


def _run_version(version: str, inputs_bench, inputs_tiny, ref_tiny,
                 measure_cpu: bool = True):
    if version in variants.VARIANTS:
        fn = kernel_def.jitted_variant(version)   # cached — no re-jit
        runner = lambda x: fn(x)
    else:
        cfg = pallas_gpp.CONFIGS.get(version, pallas_gpp.V9)

        def runner(x):
            return api.dispatch("gpp", x, version=version, config=cfg,
                                interpret=True)

    # correctness at TINY (pallas configs need divisibility: use tiny cfg)
    if version in variants.VARIANTS:
        a, x = runner(inputs_tiny)
    else:
        base = pallas_gpp.CONFIGS.get(version, pallas_gpp.V9)
        tiny_cfg = dataclasses.replace(base, blk_ig=32, blk_igp=4, blk_band=4)
        a, x = api.dispatch("gpp", inputs_tiny, version=version,
                            config=tiny_cfg, interpret=True)
    ar, xr = ref_tiny
    rel = max(
        float(np.max(np.abs(np.asarray(a) - ar)) / np.max(np.abs(ar))),
        float(np.max(np.abs(np.asarray(x) - xr)) / np.max(np.abs(xr))))

    cpu_ms = None
    if measure_cpu and version in variants.VARIANTS:
        out = runner(inputs_bench)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            out = runner(inputs_bench)
            jax.block_until_ready(out)
        cpu_ms = (time.perf_counter() - t0) / reps * 1e3
    return rel, cpu_ms


NOTES = {
    "v0": "baseline: divides, abs(), 3-way branch, igp-stream",
    "v1": "divides -> reciprocals",
    "v2": "3-way branch -> masked selects",
    "v3": "abs() -> squared-magnitude compares",
    "v4": "serialize band: AI up (ideal-cache bytes)",
    "v5": "hoist mat across iw",
    "v6": "Pallas blocking, small blocks + wrong aqsm layout (regression)",
    "v7": "aqsm index swap (lane-aligned)",
    "v8": "block-size tuning (sweep): overhead amortized",
    "v9": "fused VMEM scratch accumulation + parallel grid dims",
    "v10": "autotuned v9 (repro.tune per-size pick)",
}


def run_journey(size_name: str = "si214", *, measure_cpu: bool = True,
                verbose: bool = True) -> List[JourneyRow]:
    """Replay the paper's v0–v10 optimization journey (Table I) at one
    problem size ("tiny" / "bench" / "si214" / "si510"): every version is
    verified against the numpy oracle at TINY size, modeled on the v5e
    roofline, and (measure_cpu=True) wall-clocked at BENCH size. Returns
    one JourneyRow per version with the modeled TFLOP/s and roofline
    terms; the README journey table and `benchmarks/run.py gpp_journey`
    are formatted from these rows.

    Example::

        import repro
        rows = repro.run_journey("si214", measure_cpu=False, verbose=False)
        rows[-1].version, rows[-1].modeled_tflops     # ('v10', 4.09...)
    """
    size = problem.SIZES[size_name]
    inputs_bench = problem.make_inputs(problem.BENCH)
    inputs_tiny = problem.make_inputs(problem.TINY)
    ref_tiny = ref.ref_numpy(inputs_tiny)

    rows = []
    for v in VERSIONS:
        rel, cpu_ms = _run_version(v, inputs_bench, inputs_tiny, ref_tiny,
                                   measure_cpu=measure_cpu)
        rep = _model_report(v, size)
        rows.append(JourneyRow(v, cpu_ms, rel, rep, NOTES[v]))
        if verbose:
            r = rows[-1]
            print(f"{v}: err={rel:.1e} cpu={cpu_ms and f'{cpu_ms:.1f}ms'} "
                  f"compute={rep.compute_s:.3f}s mem={rep.memory_s*1e3:.1f}ms "
                  f"-> {r.modeled_tflops:.2f} TF/s ({NOTES[v]})")
    return rows


def sweep_blocks(size_name: str = "si214",
                 igs=(128, 256, 512, 1024), igps=(128, 256),
                 bbs=(8, 16, 32, 64, 128)) -> List[Dict]:
    """v8 tuning: evaluate the analytic model over a block-size grid.
    Returns rows sorted by modeled step time (the hillclimb artifact).
    Superseded by repro.tune (which generalizes the space to any size and
    adds the measurement pass) but kept as the paper-step artifact."""
    size = problem.SIZES[size_name]
    mix = OP_MIX["v8"]
    out = []
    for big in igs:
        for bigp in igps:
            for bb in bbs:
                if size.ncouls % big or size.ngpown % bigp or size.nbands % bb:
                    continue
                cfg = pallas_gpp.BlockConfig("sweep", big, bigp, bb, True)
                if cfg.vmem_bytes() > TPU_V5E.vmem_bytes:
                    continue
                hbm = vpu_model.pallas_bytes(size, cfg)
                n_inst = vpu_model.grid_instances(size, cfg)
                compute = size.inner_iters * mix.passes / PASS_RATE
                t = max(compute + n_inst * GRID_OVERHEAD_S,
                        hbm / TPU_V5E.hbm_bw)
                out.append({"blk_ig": big, "blk_igp": bigp, "blk_band": bb,
                            "vmem_mib": cfg.vmem_bytes() / 2**20,
                            "hbm_gib": hbm / 2**30, "instances": n_inst,
                            "modeled_s": t,
                            "tflops": size.inner_iters * mix.flops / t / 1e12})
    return sorted(out, key=lambda r: r["modeled_s"])


def format_journey(rows: List[JourneyRow], size_name: str) -> str:
    """Markdown table mirroring the paper's Table I."""
    lines = [
        f"GPP journey — {size_name} (modeled TPU v5e; CPU ms at BENCH size)",
        "| ver | CPU ms | rel err | compute_s | memory_s | dominant | "
        "modeled TF/s | %VPU peak | %customized | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        rep = r.report
        tf = r.modeled_tflops
        lines.append(
            f"| {r.version} | {f'{r.cpu_ms:.1f}' if r.cpu_ms else '—'} "
            f"| {r.rel_err:.1e} | {rep.compute_s:.3f} "
            f"| {rep.memory_s:.4f} | {rep.dominant} | {tf:.2f} "
            f"| {tf * 1e12 / FLOP_PEAK:.0%} "
            f"| {tf * 1e12 / rep.customized_peak_flops:.0%} | {r.note} |")
    return "\n".join(lines)
