"""Hierarchical roofline model — the paper's contribution as a library.

The paper (Yang 2020) analyzes one kernel with a three-level hierarchical
roofline (L1/L2/HBM) plus a customized compute ceiling derived from the
measured FMA fraction. This module generalizes that to the multi-chip TPU
setting used by the rest of the framework:

  compute term     = HLO_FLOPs_per_chip / peak_FLOP/s          (seconds)
  memory term      = HLO_bytes_per_chip / HBM_bw               (seconds)
  collective term  = collective_bytes_per_chip / ICI link bw   (seconds)

The dominant term is the bottleneck; modeled step time = max of the three
(perfect-overlap assumption — reported alongside the no-overlap sum), and the
roofline fraction is compute_term / modeled_time.

The customized ceiling generalizes the paper's FMA-ratio ceiling: with a
fraction r of FLOPs on the MXU and (1-r) on the VPU, the attainable peak is
    F_total / (F_mxu/P_mxu + F_vpu/P_vpu)
— the paper's (2r + (1-r))/2 formula is exactly this with P_fma = 2 * P_nonfma.

Sources: compiled.cost_analysis() for FLOPs/bytes (per-device program after
SPMD partitioning), compiled.as_text() parsed by hlo_analysis for collective
bytes and MXU-dot FLOPs. compiled.memory_analysis() proves per-device fit.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

from repro.analyze import hlo as hlo_analysis
from repro.core.hw import TPU_V5E, HardwareSpec


@dataclasses.dataclass
class RooflineReport:
    name: str
    mesh_shape: tuple
    chips: int

    # raw per-chip quantities (per-device SPMD program)
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    mxu_flops_per_chip: float

    # derived seconds
    compute_s: float
    memory_s: float
    collective_s: float

    # ceilings
    customized_peak_flops: float  # paper's FMA-ratio analogue (MXU/VPU mix)
    mxu_fraction: float

    # memory fit (per-device, bytes)
    device_memory_bytes: Optional[int] = None

    # semantic model FLOPs (6ND convention), total across chips, per step
    model_flops_total: Optional[float] = None

    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # ---- derived properties -------------------------------------------------

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def modeled_step_s(self) -> float:
        """Perfect-overlap model: step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def modeled_step_s_noverlap(self) -> float:
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def roofline_fraction(self) -> float:
        """How close modeled time is to the pure-compute bound (1.0 = at roof)."""
        t = self.modeled_step_s
        return (self.compute_s / t) if t > 0 else 0.0

    @property
    def customized_fraction(self) -> float:
        """Fraction of the customized (MXU/VPU-mix) peak achieved at modeled time."""
        t = self.modeled_step_s
        if t <= 0 or self.customized_peak_flops <= 0:
            return 0.0
        return (self.flops_per_chip / t) / self.customized_peak_flops

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste (<1 means
        the compiler executes more FLOPs than the math requires, e.g. remat)."""
        if self.model_flops_total is None:
            return None
        hlo_total = self.flops_per_chip * self.chips
        return self.model_flops_total / hlo_total if hlo_total > 0 else None

    @property
    def achieved_tflops_per_chip(self) -> float:
        t = self.modeled_step_s
        return (self.flops_per_chip / t) / 1e12 if t > 0 else 0.0

    def row(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "mesh": "x".join(map(str, self.mesh_shape)),
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s": self.modeled_step_s,
            "roofline_frac": self.roofline_fraction,
            "mxu_frac": self.mxu_fraction,
            "achieved_tflops_chip": self.achieved_tflops_per_chip,
            "useful_ratio": self.useful_flops_ratio,
            "hbm_gib_per_chip": (self.device_memory_bytes or 0) / 2**30,
            "model_flops": self.model_flops_total,
        }

    def to_json(self) -> str:
        return json.dumps(self.row(), default=float)


def customized_ceiling(total_flops: float, mxu_flops: float,
                       hw: HardwareSpec = TPU_V5E) -> float:
    """Attainable FLOP/s peak given the measured MXU fraction.

    Paper analogue: 58% FMA => (2*.58 + .42)/2 = 79% of 6.7 TF = 5.3 TF.
    Here: time-weighted mix of MXU-rate and VPU-rate FLOPs.
    """
    total_flops = max(total_flops, 1.0)
    mxu = min(mxu_flops, total_flops)
    vpu = total_flops - mxu
    t = mxu / hw.mxu_flops + vpu / hw.vpu_flops
    return total_flops / t if t > 0 else hw.mxu_flops


def analyze_compiled(
    name: str,
    compiled,
    mesh_shape: tuple,
    *,
    hw: HardwareSpec = TPU_V5E,
    model_flops_total: Optional[float] = None,
    hlo_text: Optional[str] = None,
) -> RooflineReport:
    """Build a RooflineReport from a jax compiled executable.

    `compiled` is the result of jit(...).lower(...).compile(). With SPMD
    partitioning the module is the per-device program, so cost_analysis()
    yields per-chip FLOPs/bytes directly.
    """
    chips = 1
    for d in mesh_shape:
        chips *= d

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax returns [dict]
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    text = hlo_text if hlo_text is not None else compiled.as_text()
    # loop-aware census: XLA's cost_analysis counts while-loop (scan) bodies
    # once; module_cost scales by trip count (hlo_analysis docstring).
    mc = hlo_analysis.module_cost(text)
    flops = max(mc.flops, xla_flops)
    nbytes = mc.hbm_bytes
    coll = hlo_analysis.CollectiveStats(
        {k: int(v) for k, v in mc.collective_bytes_by_kind.items()},
        {k: int(v) for k, v in mc.collective_count_by_kind.items()}, [])
    mxu = min(mc.dot_flops, flops) if flops > 0 else mc.dot_flops

    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = int(
            getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            + getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "generated_code_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:
        pass

    compute_s = flops / hw.mxu_flops
    # VPU-aware compute term: the same FLOPs at the customized mix rate.
    cpeak = customized_ceiling(flops, mxu, hw)
    compute_s_customized = flops / cpeak if cpeak > 0 else compute_s

    report = RooflineReport(
        name=name,
        mesh_shape=tuple(mesh_shape),
        chips=chips,
        flops_per_chip=flops,
        bytes_per_chip=nbytes,
        collective_bytes_per_chip=float(coll.total_bytes),
        mxu_flops_per_chip=mxu,
        compute_s=compute_s_customized,
        memory_s=nbytes / hw.hbm_bw,
        collective_s=float(coll.total_bytes) / hw.ici_bw,
        customized_peak_flops=cpeak,
        mxu_fraction=(mxu / flops) if flops > 0 else 0.0,
        device_memory_bytes=mem,
        model_flops_total=model_flops_total,
        extra={
            "collective_bytes_by_kind": coll.bytes_by_kind,
            "collective_count_by_kind": coll.count_by_kind,
            "mxu_peak_compute_s": compute_s,
            "xla_flat_flops": xla_flops,
            "xla_flat_bytes": xla_bytes,
            "while_trips": mc.while_trips,
        },
    )
    return report


def analyze_counts(
    name: str,
    *,
    flops: float,
    hbm_bytes: float,
    collective_bytes: float = 0.0,
    mxu_flops: float = 0.0,
    mesh_shape: tuple = (1,),
    hw: HardwareSpec = TPU_V5E,
    model_flops_total: Optional[float] = None,
    vmem_bytes: Optional[float] = None,
) -> RooflineReport:
    """Roofline from analytic counts (used by the GPP journey, where the
    kernel's FLOPs/bytes are derived from the algorithm + BlockSpec tiling
    rather than a compiled TPU module)."""
    chips = 1
    for d in mesh_shape:
        chips *= d
    cpeak = customized_ceiling(flops, mxu_flops, hw)
    extra: Dict[str, Any] = {"mxu_peak_compute_s": flops / hw.mxu_flops}
    if vmem_bytes is not None:
        extra["vmem_bytes"] = vmem_bytes
        extra["vmem_ai"] = flops / vmem_bytes if vmem_bytes else float("inf")
    return RooflineReport(
        name=name,
        mesh_shape=tuple(mesh_shape),
        chips=chips,
        flops_per_chip=flops,
        bytes_per_chip=hbm_bytes,
        collective_bytes_per_chip=collective_bytes,
        mxu_flops_per_chip=mxu_flops,
        compute_s=flops / cpeak if cpeak > 0 else 0.0,
        memory_s=hbm_bytes / hw.hbm_bw,
        collective_s=collective_bytes / hw.ici_bw,
        customized_peak_flops=cpeak,
        mxu_fraction=(mxu_flops / flops) if flops > 0 else 0.0,
        model_flops_total=model_flops_total,
        extra=extra,
    )


def format_table(reports, *, extra_cols=()) -> str:
    """Markdown table of roofline rows (used by EXPERIMENTS.md generators)."""
    cols = [
        ("cell", "name", "{}"),
        ("mesh", "mesh", "{}"),
        ("compute_s", "compute_s", "{:.4g}"),
        ("memory_s", "memory_s", "{:.4g}"),
        ("collective_s", "collective_s", "{:.4g}"),
        ("dominant", "dominant", "{}"),
        ("step_s", "step_s", "{:.4g}"),
        ("roofline", "roofline_frac", "{:.2%}"),
        ("mxu%", "mxu_frac", "{:.1%}"),
        ("TF/chip", "achieved_tflops_chip", "{:.1f}"),
        ("useful", "useful_ratio", "{}"),
        ("GiB/chip", "hbm_gib_per_chip", "{:.2f}"),
    ]
    lines = ["| " + " | ".join(c[0] for c in cols) + " |",
             "|" + "|".join("---" for _ in cols) + "|"]
    for r in reports:
        row = r.row()
        vals = []
        for _, key, fmt in cols:
            v = row.get(key)
            if v is None:
                vals.append("—")
            elif key == "useful_ratio":
                vals.append(f"{v:.2f}" if v is not None else "—")
            else:
                vals.append(fmt.format(v))
        lines.append("| " + " | ".join(vals) + " |")
    return "\n".join(lines)
