"""VPU machine model + GPP instruction census, shared by `core.journey`
(the paper's Table-I harness) and `repro.tune` (the autotuner).

Extracted from journey.py so the tuner can rank block configs with the same
analytic model the journey reports against, without a core<->tune import
cycle. journey.py re-exports everything here for backward compatibility.

Model constants (documented assumptions):
  VPU issue rate 4 ops/lane-cycle x 1024 lanes x 0.94 GHz = 3.85e12 pass/s
  (an all-FMA stream then sustains 7.7e12 FLOP/s = hw.TPU_V5E.vpu_flops);
  grid-step issue overhead 0.3 us per grid instance (DMA issue + sequencing
  when the block is too small to hide it) for the band-serialized kernels;
  0.12 us for the fused-accumulator kernels (v9+), where the igp/ig axes are
  declared `parallel` (dimension_semantics) and the output read-modify-write
  is off the critical path, so sequencing overlaps the VPU work;
  lane-granularity DMA inflation: an array whose minor (lane) dim tiles
  below 128 pays 128/dim in traffic (v6's aqsm layout).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.hw import TPU_V5E
from repro.kernels.gpp import pallas_gpp, problem

PASS_RATE = 4 * 1024 * 0.94e9          # VPU passes/s (4 ALUs x 8x128 lanes)
FLOP_PEAK = TPU_V5E.vpu_flops          # all-FMA ceiling (2 flops/pass)
GRID_OVERHEAD_S = 0.3e-6               # per grid instance (band-serialized)
GRID_OVERHEAD_FUSED_S = 0.12e-6        # per instance, fused acc + parallel dims
SCAN_OVERHEAD_S = 1.0e-6               # per XLA scan step (loop latency)
# passes per op class: fma pairs mul+add in one pass (2 flops); divides and
# sqrt are multi-pass NR sequences on the VPU (the paper's long-latency ops).
PASSES = {"basic": 1.0, "fma": 1.0, "rcp": 4.0, "sqrt": 8.0, "div": 8.0}
FLOPS = {"basic": 1.0, "fma": 2.0, "rcp": 1.0, "sqrt": 1.0, "div": 1.0}


@dataclasses.dataclass(frozen=True)
class OpMix:
    """Instruction census per inner (ig,igp,band,iw) iteration."""
    basic: float
    fma: float = 0.0
    rcp: float = 0.0
    sqrt: float = 0.0
    div: float = 0.0

    def _dot(self, table) -> float:
        return (self.basic * table["basic"] + self.fma * table["fma"]
                + self.rcp * table["rcp"] + self.sqrt * table["sqrt"]
                + self.div * table["div"])

    @property
    def passes(self) -> float:
        return self._dot(PASSES)

    @property
    def flops(self) -> float:
        return self._dot(FLOPS)


# censuses audited against the planar-f32 arithmetic in variants.py /
# pallas_gpp.py (complex mul = 2 fma + 2 mul; |z|^2 = 1 fma + 1 mul; the
# select/compare chain is pass-only "basic" work):
OP_MIX = {
    # divides + abs() + 3-way branch + per-iw mat recompute
    "v0": OpMix(basic=58, fma=14, sqrt=2, div=4),
    # divides -> reciprocals (3 rcp/iter: wdiffr, cden1, cden2)
    "v1": OpMix(basic=60, fma=14, rcp=3, sqrt=2),
    # 3-way -> zero-init + masked selects (2 fewer selects)
    "v2": OpMix(basic=58, fma=14, rcp=3, sqrt=2),
    # abs()/sqrt -> squared-magnitude compares
    "v3": OpMix(basic=58, fma=14, rcp=3),
    # band-serial: same mix, memory-side change
    "v4": OpMix(basic=58, fma=14, rcp=3),
    # mat hoisted across iw: one cmul + 2 vcoul muls amortized over nw
    "v5": OpMix(basic=54, fma=14, rcp=3),
    "v6": OpMix(basic=54, fma=14, rcp=3),
    "v7": OpMix(basic=54, fma=14, rcp=3),
    "v8": OpMix(basic=54, fma=14, rcp=3),
    # v9/v10: fused accumulation is a memory/sequencing change — the per-iter
    # arithmetic census is identical to v8
    "v9": OpMix(basic=54, fma=14, rcp=3),
    "v10": OpMix(basic=54, fma=14, rcp=3),
}


def grid_instances(size: problem.GppSize, cfg: pallas_gpp.BlockConfig) -> int:
    return ((size.ncouls // cfg.blk_ig) * (size.ngpown // cfg.blk_igp)
            * (size.nbands // cfg.blk_band))


def pallas_bytes(size: problem.GppSize, cfg: pallas_gpp.BlockConfig) -> float:
    """HBM traffic for a Pallas config, including lane-granularity DMA
    inflation (a tile whose minor/lane dim is below the 128-lane DMA
    granularity pays 128/dim on that array's traffic):
      * aqsm in v6 layout (minor dim = band) — the journey's v6 regression;
      * any config tiling igp below 128 (minor dim of wtilde/eps, and of
        aqsm in the transposed layout) — keeps the tuner honest about
        lane-misaligned candidates.
    The inflation only applies when the array itself is wide enough to tile
    at 128 (a problem with ngpown < 128 pays it unavoidably, equally for
    every candidate)."""
    b = pallas_gpp.hbm_traffic_model(size, cfg)
    if not cfg.aqsm_transposed and cfg.blk_band < 128:
        n_ig = size.ncouls // cfg.blk_ig
        base = n_ig * 2 * 4 * size.ngpown * size.nbands
        b += base * (128.0 / cfg.blk_band - 1.0)
    if cfg.blk_igp < min(128, size.ngpown):
        infl = 128.0 / cfg.blk_igp - 1.0
        wt_eps = 4 * 4 * size.ncouls * size.ngpown
        b += wt_eps * infl
        if cfg.aqsm_transposed:
            n_ig = size.ncouls // cfg.blk_ig
            b += n_ig * 2 * 4 * size.ngpown * size.nbands * infl
    return float(b)


def pallas_overhead_s(size: problem.GppSize,
                      cfg: pallas_gpp.BlockConfig) -> float:
    per = GRID_OVERHEAD_FUSED_S if cfg.fused_acc else GRID_OVERHEAD_S
    return grid_instances(size, cfg) * per


def lane_fill(size: problem.GppSize, cfg: pallas_gpp.BlockConfig) -> float:
    """Fraction of the 128 VREG lanes a tile fills (lanes = igp). A block
    narrower than the achievable lane width wastes the rest of every VPU
    pass — the compute-side cost of lane misalignment (the traffic side is
    in pallas_bytes). Relative to what the problem allows: ngpown < 128
    caps every candidate equally."""
    achievable = min(128, size.ngpown)
    return min(cfg.blk_igp, achievable) / achievable


def pallas_step_terms(size: problem.GppSize, cfg: pallas_gpp.BlockConfig,
                      mix: OpMix) -> Tuple[float, float, float]:
    """(compute_s incl. overhead, memory_s, overhead_s) for a Pallas config."""
    compute = size.inner_iters * mix.passes / PASS_RATE / lane_fill(size, cfg)
    overhead = pallas_overhead_s(size, cfg)
    memory = pallas_bytes(size, cfg) / TPU_V5E.hbm_bw
    return compute + overhead, memory, overhead


def pallas_step_s(size: problem.GppSize, cfg: pallas_gpp.BlockConfig,
                  mix: OpMix = OP_MIX["v9"]) -> float:
    """Modeled step time: max(compute+overhead, memory) — the perfect-overlap
    roofline the journey reports."""
    compute, memory, _ = pallas_step_terms(size, cfg, mix)
    return max(compute, memory)
