"""Data pipeline: deterministic synthetic tokens + memmap binary token files,
shard-aware reads, background prefetch with double buffering.

Design for 1000+ hosts: every host computes its own slice of the global
batch from (step, dp_rank, dp_size) alone — no coordinator, no shared
filesystem contention, bit-exact resume from any step (the trainer persists
only the step number). The memmap source reads fixed-length windows from a
flat uint16/uint32 token file (the standard "packed tokens" format).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    token_file: Optional[str] = None     # flat binary tokens; None=synthetic
    token_dtype: str = "uint16"
    prefetch: int = 2


class TokenSource:
    """Deterministic per-(step, rank) batch generation."""

    def __init__(self, cfg: DataConfig, dp_rank: int = 0, dp_size: int = 1):
        self.cfg = cfg
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        assert cfg.global_batch % dp_size == 0
        self.local_batch = cfg.global_batch // dp_size
        self._mm = None
        if cfg.token_file:
            self._mm = np.memmap(cfg.token_file, dtype=cfg.token_dtype,
                                 mode="r")
            self._n_windows = (len(self._mm) - 1) // cfg.seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """tokens/labels (local_batch, seq_len) for a given global step."""
        c = self.cfg
        if self._mm is None:
            rng = np.random.default_rng(
                np.random.SeedSequence([c.seed, step, self.dp_rank]))
            toks = rng.integers(0, c.vocab_size,
                                (self.local_batch, c.seq_len + 1),
                                dtype=np.int32)
        else:
            # global window ids for this step, sliced per rank
            rng = np.random.default_rng(np.random.SeedSequence([c.seed, step]))
            wins = rng.integers(0, self._n_windows, (c.global_batch,))
            mine = wins[self.dp_rank::self.dp_size][: self.local_batch]
            toks = np.stack([
                np.asarray(self._mm[w * c.seq_len: w * c.seq_len + c.seq_len + 1],
                           dtype=np.int32)
                for w in mine])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class PrefetchIterator:
    """Background-thread prefetch with a bounded queue (double buffering)."""

    def __init__(self, source: TokenSource, start_step: int = 0):
        self.source = source
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=source.cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def make_stub_frontend_batch(cfg: ModelConfig, batch: Dict[str, np.ndarray],
                             rng_seed: int = 0) -> Dict[str, np.ndarray]:
    """Attach the stub modality inputs (whisper frames / vlm patches)."""
    b = batch["tokens"].shape[0]
    rng = np.random.default_rng(rng_seed)
    if cfg.family == "encdec":
        batch = dict(batch)
        batch["frames"] = rng.standard_normal(
            (b, cfg.enc_seq, cfg.d_model)).astype(np.float32) * 0.02
    elif cfg.family == "vlm":
        batch = dict(batch)
        batch["vis"] = rng.standard_normal(
            (b, cfg.n_vis_tokens, cfg.d_model)).astype(np.float32) * 0.02
        batch["tokens"] = batch["tokens"][:, : -cfg.n_vis_tokens] \
            if batch["tokens"].shape[1] > cfg.n_vis_tokens else batch["tokens"]
        batch["labels"] = batch["labels"][:, : batch["tokens"].shape[1]]
    return batch
