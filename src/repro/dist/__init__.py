"""Distribution layer: sharding assignment, fault tolerance, pipelining.

Submodules:
  * sharding — logical-axis -> mesh-axis assignment (ShardingPlan, spec_for,
    params/cache/batch sharding trees). Divisibility-safe by construction:
    a mesh axis is only ever assigned to a dim it divides, and never twice.
  * fault    — heartbeat file, step watchdog (straggler EWMA), checkpoint
    resume-or-init; the pieces the trainer's restart-idempotence contract
    is built from.
  * pipeline — microbatched pipeline parallelism over a mesh axis
    (GPipe-style schedule under shard_map) + the bubble-fraction model.
"""

from repro.dist import fault, pipeline, sharding  # noqa: F401
