"""Fault-tolerance primitives for the training loop.

Three small pieces, composed by train/trainer.py:

  * HeartbeatFile — atomically-updated liveness file next to the
    checkpoints. An external supervisor (or another host in the fleet)
    reads it to decide whether this worker is alive; `stale()` is the
    poll the supervisor would run.
  * StepWatchdog — EWMA straggler detector over per-step wall-clock. On a
    real fleet a sustained straggler triggers re-slicing; here it fires a
    callback and records the event (asserted on by tests).
  * resume_or_init — the restart-idempotence entry point: restore the
    latest valid checkpoint onto the current mesh (elastic re-shard via
    the shardings tree) or build fresh state. Combined with step-keyed
    data order, kill + rerun resumes bit-identically
    (tests/test_system.py::test_trainer_restart_idempotent).
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, List, Optional, Tuple

PyTree = Any


def _boot_id() -> Optional[str]:
    """Identity of the current boot (Linux); None where unavailable."""
    try:
        with open("/proc/sys/kernel/random/boot_id") as fh:
            return fh.read().strip()
    except OSError:
        return None


class HeartbeatFile:
    """Liveness beacon: {"step", "time", "mono", "boot"} JSON, atomically
    replaced.

    Staleness math runs on `mono` (time.monotonic(), CLOCK_MONOTONIC —
    shared by every process within one boot and immune to NTP steps); the
    wall-clock "time" field is kept purely for human-readable logs. A
    wall clock that jumps backwards under NTP skew must never make a live
    worker look stale (or a dead one look fresh). CLOCK_MONOTONIC is
    per-boot, so `mono` is only trusted when the beat's `boot` id matches
    the reader's (same host, same boot); a supervisor on another host, or
    a read across a reboot, falls back to the wall clock — the only
    cross-boot-comparable timestamp. A same-boot beat whose `mono` sits in
    the reader's future is non-monotonic — impossible for a beat this
    kernel produced, so the file was deserialized/copied — and clamps to
    the wall-clock fallback without the fresh-forever benefit of a
    future wall time (age_s() returns None: presumed stale)."""

    def __init__(self, directory: str, name: str = "HEARTBEAT"):
        self.dir = directory
        self.path = os.path.join(directory, name)
        os.makedirs(directory, exist_ok=True)

    def beat(self, step: int) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump({"step": int(step), "time": time.time(),
                       "mono": time.monotonic(), "boot": _boot_id()}, fh)
        os.replace(tmp, self.path)       # atomic: readers never see a torn beat

    def read(self) -> Optional[dict]:
        try:
            with open(self.path) as fh:
                return json.load(fh)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def age_s(self) -> Optional[float]:
        b = self.read()
        if b is None:
            return None
        same_boot = ("mono" in b and b.get("boot") is not None
                     and b["boot"] == _boot_id())
        wall = b.get("time")
        if not isinstance(wall, (int, float)) or isinstance(wall, bool):
            wall = None                      # beat without a usable wall time
        if same_boot:
            age = time.monotonic() - b["mono"]
            if age >= 0.0:
                return age
            # A same-boot mono from the FUTURE is impossible for a beat
            # this kernel produced: the file was deserialized/copied (a
            # restored legacy beat, a hand-edited file). Such a beat must
            # clamp to the wall-clock fallback — and its wall time gets no
            # freshness benefit of the doubt either: if that is ALSO from
            # the future, the beat is wholly untrustworthy and must read
            # as never-beaten (stale), not fresh-forever (the max(0, ...)
            # clamp below would have pinned its age at 0 indefinitely).
            now = time.time()
            if wall is None or wall > now:
                return None
            return now - wall
        # legacy beat (no mono/boot), another host, or across a reboot:
        # wall clock is all we have. Clamp negative to 0 — NTP stepping
        # the reader's clock backwards must not make a live worker stale.
        if wall is None:
            return None
        return max(0.0, time.time() - wall)

    def stale(self, timeout_s: float = 300.0) -> bool:
        """True when the worker should be presumed dead (no beat within
        timeout, or no beat ever written)."""
        age = self.age_s()
        return age is None or age > timeout_s

    def clear(self) -> None:
        """Remove the beat file (idempotent). A supervisor calls this when
        it hands a worker's identity to a replacement process (rolling
        restart / replica recovery): the fresh process must not inherit
        the predecessor's liveness — it reads as never-beaten until its
        own first beat()."""
        try:
            os.remove(self.path)
        except FileNotFoundError:
            pass


def backoff_ticks(attempt: int, *, base: int = 1, cap: int = 32) -> int:
    """Deterministic exponential backoff: the delay before retry `attempt`
    (1-indexed) is base * 2**(attempt-1), capped at `cap`. Pure arithmetic
    on integers — no jitter, no wall clock — so schedulers built on a
    virtual tick clock (repro.serve.router) stay seed-reproducible while
    still spreading re-admission pressure out exponentially.

        >>> [backoff_ticks(k, base=2, cap=12) for k in (1, 2, 3, 4)]
        [2, 4, 8, 12]
    """
    if attempt < 1:
        raise ValueError(f"attempt is 1-indexed, got {attempt}")
    if base < 0 or cap < 0:
        raise ValueError(f"base/cap must be >= 0, got {base}/{cap}")
    return min(base * (1 << (attempt - 1)), cap)


class StepWatchdog:
    """Straggler detection on step wall-clock: alarm when a step exceeds
    `factor` x the EWMA of previous steps. The first `warmup` observations
    only train the EWMA (they include compile time)."""

    def __init__(self, on_straggler: Optional[Callable] = None, *,
                 factor: float = 3.0, warmup: int = 3, alpha: float = 0.2):
        self.on_straggler = on_straggler
        self.factor = factor
        self.warmup = warmup
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.count = 0
        self.stragglers: List[Tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        """Record one step time; returns True if it was flagged."""
        flagged = False
        if (self.count >= self.warmup and self.ewma is not None
                and dt > self.factor * self.ewma):
            flagged = True
            self.stragglers.append((step, dt, self.ewma))
            if self.on_straggler is not None:
                self.on_straggler(step, dt, self.ewma)
        if self.ewma is None:
            self.ewma = dt
        else:
            # fold flagged steps in clamped at the alarm threshold: one
            # outlier can't poison the baseline, but a sustained slowdown
            # re-baselines instead of alarming forever
            d = min(dt, self.factor * self.ewma)
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * d
        self.count += 1
        return flagged


def resume_or_init(ckpt, init_fn: Callable[[], PyTree], *,
                   shardings: Optional[PyTree] = None
                   ) -> Tuple[int, PyTree]:
    """(start_step, state): restore the latest checkpoint re-sharded onto
    the current mesh, else (0, init_fn()). `ckpt` is a
    repro.ckpt.checkpoint.CheckpointManager."""
    step = ckpt.latest_step()
    if step is None:
        return 0, init_fn()
    return ckpt.restore(step, shardings=shardings)
