"""Pipeline parallelism: microbatched scan-over-stages on a mesh axis.

GPipe schedule under shard_map: the layer stack (leading `layers` dim)
is split into S = mesh.shape[pp_axis] contiguous stages, one per device
along the pipeline axis. Microbatches enter stage 0 one per tick and
flow stage-to-stage over `ppermute`; after M + S - 1 ticks every
microbatch has traversed all layers. The (S-1)-tick fill/drain bubble is
the schedule's idle fraction — `bubble_fraction` is the analytic model
the roofline uses to discount pipeline FLOP/s.

The weight-placement argument vs FSDP holds as in production pipelines:
each stage keeps its L/S layers resident, no per-layer all-gather. Note
this REFERENCE implementation trades activation-side frugality for
schedule clarity — the (M, B, ...) microbatch stream is replicated to
every stage and the output psum moves the full stream once, rather than
streaming single (B, ...) edges per tick. Per-tick inter-stage traffic is
still one activation edge (the ppermute).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """Idle fraction of the GPipe schedule: (S-1) of the M+S-1 ticks each
    device spends filling/draining."""
    s, m = n_stages, n_microbatches
    return (s - 1) / (m + s - 1)


def pipelined_apply(fn: Callable, stage_params: PyTree, x: jax.Array, *,
                    mesh, pp_axis: str) -> jax.Array:
    """Run x through a scanned layer stack, pipelined over `pp_axis`.

    fn: (layer_params, h) -> h, one layer's apply.
    stage_params: pytree whose leaves lead with the layers dim L
        (L % mesh.shape[pp_axis] == 0); stage s holds layers
        [s*L/S, (s+1)*L/S).
    x: (M, B, ...) — M microbatches.

    Returns (M, B, ...), numerically identical to scanning all L layers
    over each microbatch (tests/test_dist.py::test_pipeline_parallel_
    matches_dense).
    """
    s_count = int(mesh.shape[pp_axis])
    m_count = int(x.shape[0])
    l_total = int(jax.tree.leaves(stage_params)[0].shape[0])
    assert l_total % s_count == 0, (l_total, s_count)

    def local(wl, xl):
        # per-device view: wl leads with L/S local layers; xl is the full
        # (M, B, ...) microbatch stream (replicated).
        stage = jax.lax.axis_index(pp_axis)

        def run_stage(h):
            def body(c, p):
                return fn(p, c), None
            y, _ = jax.lax.scan(body, h, wl)
            return y

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (clip keeps the gather legal
            # during drain; the value is masked off by the schedule).
            inp = jax.lax.dynamic_index_in_dim(
                xl, jnp.clip(t, 0, m_count - 1), 0, keepdims=False)
            buf = jnp.where(stage == 0, inp, buf)
            y = run_stage(buf)
            # the last stage finishes microbatch m = t - (S-1) this tick
            m = t - (s_count - 1)
            mi = jnp.clip(m, 0, m_count - 1)
            write = jnp.logical_and(stage == s_count - 1, m >= 0)
            cur = jax.lax.dynamic_index_in_dim(outs, mi, 0, keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(write, y, cur), mi, 0)
            # hand this tick's activation to the next stage
            buf = jax.lax.ppermute(
                y, pp_axis, [(i, (i + 1) % s_count) for i in range(s_count)])
            return buf, outs

        buf0 = jnp.zeros_like(xl[0])
        outs0 = jnp.zeros_like(xl)
        _, outs = jax.lax.fori_loop(
            0, m_count + s_count - 1, tick, (buf0, outs0))
        # only the last stage wrote; psum replicates its copy everywhere
        outs = jnp.where(stage == s_count - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, pp_axis)

    run = jax.shard_map(
        local, mesh=mesh, in_specs=(P(pp_axis), P()), out_specs=P(),
        axis_names=frozenset({pp_axis}), check_vma=False)
    return run(stage_params, x)
