"""Logical-axis -> mesh-axis assignment (the sharding engine).

Every param/cache/batch leaf carries *logical axes* (recorded by
ParamBuilder / cache_logical_axes, e.g. ("layers", "d_model", "d_ff")).
`spec_for` maps those names onto mesh axes under a ShardingPlan, enforcing
two invariants the rest of the stack relies on (and tests/test_dist.py
property-checks):

  * divisibility — a mesh axis (or axis group) is only assigned to a dim
    whose size it divides; a non-divisible candidate REPLICATES instead
    (e.g. qwen2-1.5b's 12 heads / kv=2 on a 16-way model axis);
  * no reuse — a mesh axis appears at most once per spec.

Assignment order (first claim wins):
  1. TP: the `model` axis goes to the highest-priority tensor dim — with
     `kv_seq_shard`, the KV-cache seq dim steals it (distributed
     flash-decode) ahead of the usual last-to-first scan over
     d_ff / heads / kv_heads / vocab dims;
  2. EP: with `ep_data`, MoE expert dims take the dp axes (weights stay
     resident, tokens move — see train/step.make_plan);
  3. DP: batch dims (activations only) take the longest prefix of the dp
     axes whose product divides the batch;
  4. FSDP: params additionally scatter the dp axes onto the LARGEST dim
     that still divides (ZeRO-3-style weight sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

LogicalAxes = Tuple[Optional[str], ...]
PyTree = Any

# Tensor dims eligible for the model (TP) axis. The scan runs over dims
# last-to-first, so the output-feature dim of a projection wins over its
# input dim (column-parallel wq/wi; row-parallel wo claims via d_ff/heads
# being its dim 1 -> the contraction stays sharded, matching matmul_rp).
_TP_NAMES = ("d_ff", "heads", "kv_heads", "vocab")


@dataclasses.dataclass
class ShardingPlan:
    """How one (model x mesh x cell-kind) combination maps onto the mesh.

    dp_axes: data-parallel mesh axes in outer-to-inner order, e.g.
        ("pod", "data") on the 2x16x16 multi-pod mesh.
    fsdp: scatter params/optimizer over the dp axes (train, >8B dense).
    kv_seq_shard: decode-time KV seq dim takes the model axis
        (distributed flash-decode).
    ep_data: MoE expert dims shard over the dp axes (EP).
    """
    mesh: Any
    dp_axes: Tuple[str, ...] = ()
    fsdp: bool = False
    kv_seq_shard: bool = False
    ep_data: bool = False
    # Params take the model axis ONLY on their last (output-feature) dim —
    # column-parallel everywhere, no row-parallel weights. Every cross-
    # device combine is then a concatenation (all-gather), never a psum,
    # so floating-point reductions keep their single-device association
    # order and sharded execution is BIT-EXACT by construction (the
    # serving plan's contract; see serve_specs / layers.exact_tp_scope).
    tp_out_dims_only: bool = False

    @property
    def tp_axis(self) -> Optional[str]:
        return "model" if "model" in self.mesh.shape else None

    def axis_size(self, name: str) -> int:
        return int(self.mesh.shape[name])

    def dp_size(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= self.axis_size(a)
        return n


def spec_for(plan: ShardingPlan, axes: Sequence[Optional[str]],
             shape: Sequence[int], *, is_param: bool = True) -> P:
    """PartitionSpec for one leaf with logical `axes` and concrete `shape`.

    is_param=True leaves are weights (TP + EP + FSDP apply); False leaves
    are activations / caches (TP + DP apply). Every rule falls back to
    replication when divisibility fails.
    """
    axes = tuple(axes)
    assert len(axes) == len(shape), (axes, shape)
    n = len(axes)
    assigned: list = [[] for _ in range(n)]
    used: set = set()

    def divisor(i: int) -> int:
        d = 1
        for a in assigned[i]:
            d *= plan.axis_size(a)
        return d

    def fits(i: int, names: Tuple[str, ...]) -> bool:
        if any(a in used for a in names):
            return False
        d = divisor(i)
        for a in names:
            d *= plan.axis_size(a)
        return shape[i] % d == 0

    def take(i: int, names: Tuple[str, ...]) -> None:
        assigned[i].extend(names)
        used.update(names)

    def longest_dp_prefix(i: int) -> Tuple[str, ...]:
        for k in range(len(plan.dp_axes), 0, -1):
            names = tuple(plan.dp_axes[:k])
            if fits(i, names):
                return names
        return ()

    # 1. TP — the model axis goes to exactly one tensor dim.
    tp = plan.tp_axis
    if tp is not None:
        candidates = []
        if not is_param and plan.kv_seq_shard:
            candidates += [i for i in reversed(range(n))
                           if axes[i] == "kv_seq"]
        if is_param and plan.tp_out_dims_only:
            # column-parallel only: a weight may shard its LAST dim (the
            # output features); contraction dims replicate (exact-TP)
            if n and axes[n - 1] in _TP_NAMES:
                candidates.append(n - 1)
        else:
            candidates += [i for i in reversed(range(n))
                           if axes[i] in _TP_NAMES]
        for i in candidates:
            if fits(i, (tp,)):
                take(i, (tp,))
                break

    # 2. EP — expert dims over the dp axes (params only).
    if is_param and plan.ep_data:
        for i in range(n):
            if axes[i] == "experts":
                names = longest_dp_prefix(i)
                if names:
                    take(i, names)
                break

    # 3. DP — batch dims over the dp axes (activations only).
    if not is_param:
        for i in range(n):
            if axes[i] == "batch":
                names = longest_dp_prefix(i)
                if names:
                    take(i, names)
                break

    # 4. FSDP — params scatter the dp axes onto the largest dividing dim.
    # Draw from the still-unused dp axes (EP may have claimed a prefix) so
    # ep_data+fsdp plans don't silently lose the ZeRO-3 scatter.
    if is_param and plan.fsdp and plan.dp_axes:
        avail = tuple(a for a in plan.dp_axes if a not in used)
        for k in range(len(avail), 0, -1):
            names = tuple(avail[:k])
            eligible = [i for i in range(n) if fits(i, names)]
            if eligible:
                take(max(eligible, key=lambda i: shape[i]), names)
                break

    entries = []
    for names in assigned:
        if not names:
            entries.append(None)
        elif len(names) == 1:
            entries.append(names[0])
        else:
            entries.append(tuple(names))
    return P(*entries)


# ---------------------------------------------------------------------------
# tree builders
# ---------------------------------------------------------------------------

def params_shardings(plan: ShardingPlan,
                     param_axes: Dict[str, LogicalAxes],
                     ab_params: PyTree) -> PyTree:
    """NamedSharding tree for a params tree; `param_axes` maps "a/b/c"
    nesting paths to logical axes (ParamBuilder.axes). Leaves without a
    recorded path replicate."""

    def walk(node, path=""):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}" if path else k)
                    for k, v in node.items()}
        axes = param_axes.get(path) or (None,) * len(node.shape)
        return NamedSharding(plan.mesh,
                             spec_for(plan, axes, node.shape, is_param=True))

    return walk(ab_params)


def cache_shardings(plan: ShardingPlan, cache_axes: PyTree,
                    abstract_cache: PyTree) -> PyTree:
    """NamedSharding tree for a decode cache; `cache_axes` mirrors the cache
    structure with logical-axes tuples (registry.cache_logical_axes)."""

    def walk(ax_node, ab_node):
        if isinstance(ab_node, dict):
            return {k: walk(ax_node[k], v) for k, v in ab_node.items()}
        axes = tuple(ax_node) if ax_node else (None,) * len(ab_node.shape)
        return NamedSharding(
            plan.mesh, spec_for(plan, axes, ab_node.shape, is_param=False))

    return walk(cache_axes, abstract_cache)


@dataclasses.dataclass
class ServeShardings:
    """How a ServeEngine lays its state out on a serving mesh.

    params / cache are NamedSharding trees matching the model's param tree
    and the slot scheduler's batched decode cache (whose "pos" is a (B,)
    per-slot vector). replicated is the P() sharding for everything the
    host-side scheduler owns (tokens, active masks, logits) — scheduler
    state is replicated so the FIFO slot loop stays device-count-agnostic.
    """
    plan: ShardingPlan
    params: PyTree
    cache: PyTree
    replicated: NamedSharding


def serve_specs(cfg, mesh, *, max_batch: int, cache_len: int,
                model=None) -> ServeShardings:
    """Sharding layout for tensor-parallel serving of `cfg` over `mesh`.

    The plan is TP-only (no dp axes) and **exact-TP** (tp_out_dims_only):
    the `model` mesh axis shards weights column-parallel on their output
    dims (heads / d_ff / vocab) and the per-slot K/V cache head-wise via
    its "kv_heads" logical axis; contraction dims never shard, and the
    row-parallel matmuls all-gather their activation under
    `layers.exact_tp_scope` instead of psum-combining partials. Every
    cross-device combine is therefore a concatenation of values computed
    whole on one device — no float reduction changes association order —
    which is what makes sharded serving BIT-EXACT vs the single-device
    engine (tests/test_serve_sharded.py pins it token-for-token), at the
    cost of computing the down-projections redundantly per device.
    Everything the scheduler mutates on the host (per-slot pos vector,
    sampled tokens, logits) replicates, so slot admission order and
    refill behaviour are identical on 1 device and N.

    Dims the mesh does not divide (e.g. 2 kv heads on an 8-way axis) fall
    back to replication per the spec_for invariants; the engine still runs,
    just without that dim's shard savings.

    model: optionally the already-built Model for cfg (ServeEngine passes
    its own), saving a second build here.
    """
    if model is None:
        from repro.models.registry import build_model   # lazy: models imports stay optional here
        model = build_model(cfg)
    plan = ShardingPlan(mesh=mesh, dp_axes=(), tp_out_dims_only=True)
    params = params_shardings(plan, model.param_axes,
                              model.abstract_params())
    cache = cache_shardings(plan, model.cache_axes(),
                            model.init_cache(max_batch, cache_len,
                                             abstract=True))
    # the slot scheduler's per-row write position: host-owned, replicated
    cache["pos"] = NamedSharding(mesh, P())
    return ServeShardings(plan=plan, params=params, cache=cache,
                          replicated=NamedSharding(mesh, P()))


def batch_shardings(plan: ShardingPlan, batch: PyTree) -> PyTree:
    """NamedSharding tree for an input batch: dim 0 is the global batch
    (sharded over the dp axes when divisible), the rest replicate."""

    def leaf(x):
        if not x.shape:                       # scalar leaf -> replicated
            return NamedSharding(plan.mesh, P())
        axes = ("batch",) + (None,) * (len(x.shape) - 1)
        return NamedSharding(
            plan.mesh, spec_for(plan, axes, x.shape, is_param=False))

    return jax.tree.map(leaf, batch)
