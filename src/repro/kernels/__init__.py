"""Custom-kernel layer. Each family (gpp, flash, ssm) registers itself with
the unified registry in `repro.kernels.api` via its kernel_def module —
`api.dispatch(name, *args, version=..., config=...)` is the one public
entry point; the per-family ops modules are deprecation shims (gpp, flash)
or thin wrappers (ssm)."""

import warnings

_WARNED = set()


def warn_once(message: str) -> None:
    """Emit one DeprecationWarning per message per process (shared by the
    legacy ops shims; tests reset by clearing _WARNED). stacklevel=3 points
    at the shim's caller."""
    if message not in _WARNED:
        _WARNED.add(message)
        warnings.warn(message, DeprecationWarning, stacklevel=3)
