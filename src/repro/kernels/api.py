"""Unified kernel registry: one dispatch/tune/bench API for every kernel
family (DESIGN.md §Kernel registry).

The paper's 8-step methodology — census the kernel, model it on the
roofline, tune block shapes, verify by measurement — was only wired up for
the GPP kernel; flash attention shipped frozen blocks and the ssm scan had
no public op layer. This module makes the journey a *protocol*:

  * `Kernel` — descriptor for one kernel family: named, versioned
    implementations (pure-JAX reference → Pallas), a `ProblemKey` for
    cache keying, a tunable config space with clamping rules, and an
    analytic roofline-model hook (the tuner's ranking function).
  * `ProblemKey` — the protocol replacing the GPP-only `GppSize` in the
    tune cache: anything with a `.name` and `.key_dims()`.
  * a process-wide registry: `register(kernel)`, `get_kernel(name)`,
    `list_kernels()`, and `dispatch(name, *args, version=, config=,
    interpret=, **kwargs)` — the single public entry point.

A kernel registered here joins `repro.tune` (generalized cache keyed
`(kernel, ProblemKey, backend, version)`) and the bench trajectory
(`benchmarks/run.py kernel_tuner` + per-row config provenance) for free.
Backend policy (interpret autodetect + REPRO_INTERPRET) is shared via
`repro.backend` — kernels never carry a private `_on_tpu()`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Protocol, Tuple, runtime_checkable


@runtime_checkable
class ProblemKey(Protocol):
    """What the tune cache keys on: a named problem instance whose
    `key_dims()` string is stable across processes (dims only — never
    id()s or hashes that vary per run)."""

    name: str

    def key_dims(self) -> str:
        """e.g. '8192x1024x1024x2' — joined into the JSON cache key."""
        ...


class Kernel:
    """Descriptor for one kernel family. Subclasses fill in the class
    attributes and override the hooks their family supports; everything a
    kernel leaves at the default still dispatches — it just won't tune
    (empty config space) or model (no roofline hook).

    Class attributes:
      name             registry key ('gpp', 'flash', 'ssm')
      versions         ordered implementation names, reference → fastest
      default_version  what dispatch runs when version=None
      tunable          versions whose config comes from repro.tune when
                       dispatch is called without an explicit config
    """

    name: str = ""
    versions: Tuple[str, ...] = ()
    default_version: str = ""
    tunable: Tuple[str, ...] = ()

    # -- identity / cache keying ------------------------------------------
    def problem_key(self, *args, **kwargs) -> ProblemKey:
        """Recover the ProblemKey from a dispatch call's arguments."""
        raise NotImplementedError

    # -- config space (the tuner's menu) ----------------------------------
    def config_space(self, key: ProblemKey, version: str) -> List[Any]:
        """Feasible configs for `key` (divisibility-exact, VMEM-feasible),
        deterministic order. Empty = nothing to tune."""
        return []

    def clamp(self, config: Any, key: ProblemKey) -> Any:
        """Shrink a config to fit a smaller problem."""
        return config

    def static_config(self, key: ProblemKey, version: str) -> Optional[Any]:
        """The frozen per-version config (e.g. GPP v6–v9), clamped to
        `key`; None when the version takes no config or must be tuned."""
        return None

    def tie_break(self, config: Any) -> Tuple:
        """Deterministic sort tail for model-score ties (gpp: bigger
        blocks first — fewer grid instances)."""
        return ()

    def finalize_config(self, config: Any, version: str) -> Any:
        """Stamp the winning config before it is cached (gpp renames it
        to the version)."""
        return config

    # -- roofline model hook ----------------------------------------------
    def model_step_s(self, key: ProblemKey, config: Any,
                     version: str) -> float:
        """Analytic modeled step seconds — the tuner's ranking function
        and the journey's reporting model."""
        raise NotImplementedError(f"{self.name} has no roofline model")

    # -- measurement hooks -------------------------------------------------
    def measure_ok(self, key: ProblemKey) -> bool:
        """Whether CPU (interpret-mode) timing is cheap enough for this
        problem; on TPU the tuner always measures."""
        return False

    def make_example(self, key: ProblemKey, seed: int = 0
                     ) -> Tuple[tuple, dict]:
        """(args, kwargs) for a representative dispatch of `key`, for the
        tuner's measurement pass."""
        raise NotImplementedError(f"{self.name} cannot synthesize inputs")

    # -- config (de)serialization for the JSON tune cache ------------------
    def config_to_json(self, config: Any) -> Dict:
        return dataclasses.asdict(config)

    def config_from_json(self, d: Dict) -> Any:
        raise NotImplementedError

    # -- static-analysis hooks (repro.analyze, docs/analysis.md) -----------
    def canonical_keys(self) -> List["ProblemKey"]:
        """Representative shapes the `repro.analyze` auditor censuses this
        family at (small enough to trace on CPU; one golden shape per
        family is pinned in tests). Empty = the auditor skips the family."""
        return []

    def key_from_dims(self, dims: str) -> "ProblemKey":
        """Inverse of `ProblemKey.key_dims()` — rebuild the key from its
        cache-dims string so the tune-cache validator can re-derive the
        current config space for a cached entry. Kernels that don't
        implement it only get existence (not config-space) validation."""
        raise NotImplementedError(f"{self.name} cannot parse key dims")

    def config_vmem_bytes(self, config: Any, key: "ProblemKey"
                          ) -> Optional[int]:
        """Analytic VMEM working set of `config` at `key` (double-buffered
        inputs + live intermediates), checked against the hw budget by the
        auditor's VMEM001 rule. None = no VMEM model for this family."""
        return None

    def gather_buffer_bytes(self, config: Any, key: "ProblemKey"
                            ) -> Optional[int]:
        """For kernels that gather operands through an index (paged decode's
        block-table K/V fetch): the double-buffered gather-block bytes that
        MUST be part of `config_vmem_bytes`. The auditor's KV001 rule flags
        a kernel that declares gather buffers its VMEM model doesn't cover
        (the working set would pass VMEM001 while overflowing at runtime).
        None = the family gathers nothing (no check)."""
        return None

    def config_divides(self, config: Any, key: "ProblemKey") -> List[str]:
        """Divisibility violations of `config` at `key` — one human-readable
        string per axis the blocks cannot tile (BLK001 is raised for each).
        Called on the *clamped* config: non-empty means the clamp rules
        cannot repair this (config, problem) pair."""
        return []

    def allowed_float_dtypes(self, version: str) -> frozenset:
        """Float/complex dtype names this version's compute path may touch;
        any other float dtype in the traced jaxpr is a DTYPE001 leak (f32
        ops inside a declared-f64 path and vice versa). Empty = unchecked."""
        return frozenset()

    # -- execution ---------------------------------------------------------
    def run(self, *args, version: str, config: Any,
            interpret: Optional[bool], **kwargs) -> Any:
        """Run `version` under `config` (already resolved by dispatch;
        config may be None for versions that need none). Must resolve
        interpret through repro.backend, never a private check."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# process-wide registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Kernel] = {}
_BUILTINS_LOADED = False


def register(kernel: Kernel) -> Kernel:
    """Add a kernel to the registry (last registration wins, so tests can
    shadow a builtin). Returns the kernel for decorator-ish use."""
    if not kernel.name:
        raise ValueError("kernel.name must be set")
    if kernel.default_version not in kernel.versions:
        raise ValueError(f"{kernel.name}: default_version "
                         f"{kernel.default_version!r} not in versions")
    _REGISTRY[kernel.name] = kernel
    return kernel


def _ensure_builtins() -> None:
    """Import the builtin kernel families exactly once. Deferred so that
    `import repro.kernels.api` stays cheap and the kernel_def modules can
    import repro.tune/backend without a cycle. The flag is only set on
    success — a failed import stays visible (and retryable) instead of
    leaving a silently partial registry."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    from repro.kernels.flash import kernel_def as _f    # noqa: F401
    from repro.kernels.gpp import kernel_def as _g      # noqa: F401
    from repro.kernels.paged import kernel_def as _p    # noqa: F401
    from repro.kernels.ssm import kernel_def as _s      # noqa: F401
    _BUILTINS_LOADED = True


def get_kernel(name: str) -> Kernel:
    """Look up a registered Kernel descriptor by name — the object that
    knows a family's versions, problem keys, config space, and roofline
    model (docs/kernels.md documents the full protocol). Raises KeyError
    listing what IS registered for an unknown name.

    Example::

        import repro
        gpp = repro.get_kernel("gpp")
        gpp.versions            # ('v0', ..., 'v10')
        gpp.default_version     # 'v10'
    """
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown kernel {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def list_kernels() -> List[str]:
    """Sorted names of every registered kernel family. Importing this
    module registers the builtins lazily, so the list is complete without
    importing the kernel packages yourself.

    Example::

        import repro
        repro.list_kernels()    # ['flash', 'gpp', 'ssm']
    """
    _ensure_builtins()
    return sorted(_REGISTRY)


def dispatch(name: str, *args, version: Optional[str] = None,
             config: Any = None, interpret: Optional[bool] = None,
             problem_key: Any = None, **kwargs) -> Any:
    """Run kernel `name` on `args` — the one public entry point for every
    registered kernel family.

    version=None uses the kernel's default; config=None resolves per
    version — the frozen static config (clamped) for static versions, the
    repro.tune cached winner for tunable ones. interpret=None defers to
    repro.backend (REPRO_INTERPRET override). Extra kwargs are the
    kernel's own (e.g. flash's causal=); a name the kernel doesn't accept
    raises TypeError rather than being swallowed.

    problem_key: optional pre-built ProblemKey overriding the one derived
    from args — SPMD callers use it to tune for the LOCAL shard of a
    problem whose operands are still global at trace time (e.g. the
    sharded ServeEngine keys the ssm scan on channels/tp so cached block
    configs match what each device actually executes).

    Example::

        import repro
        from repro.kernels.gpp import problem
        ach, asx = repro.dispatch("gpp", problem.make_inputs(problem.TINY))
    """
    k = get_kernel(name)
    version = version or k.default_version
    if version not in k.versions:
        raise ValueError(f"unknown {k.name} version {version!r}; "
                         f"have {list(k.versions)}")
    if config is None:
        key = problem_key if problem_key is not None \
            else k.problem_key(*args, **kwargs)
        if version in k.tunable and k.config_space(key, version):
            from repro.tune import tuner    # deferred: tune is optional here
            config = tuner.tune_kernel(k.name, key, version=version).config
        else:
            # static versions, and tunable ones at shapes the candidate
            # menu can't tile (empty space): the clamped static config —
            # the legacy entry points' behavior for odd sizes
            config = k.static_config(key, version)
            if config is None and version in k.tunable:
                raise ValueError(f"no feasible {k.name} config for {key}")
    return k.run(*args, version=version, config=config, interpret=interpret,
                 **kwargs)
