"""Flash attention (causal, GQA) as a Pallas TPU kernel.

This is the paper's cache-blocking methodology (v6–v8) applied to the
framework's hottest memory term: the baseline XLA attention materializes the
(B,H,S,S) score tensor in HBM three times per layer (scores, softmax, probs)
— the dominant §Roofline memory term for every train/prefill cell. The flash
kernel streams KV blocks through VMEM with an online softmax, so HBM traffic
drops to O(S·Hd) per head: exactly the "declare the block, keep it in VMEM"
move the GPP kernel makes (DESIGN.md §2).

Blocking (v8-style reasoning):
  grid = (B*H, n_q_blocks, n_kv_blocks), kv innermost (sequential) so the
  q-indexed output block is revisited and accumulated in place;
  q block (BLK_Q, Hd): lanes = Hd (128-aligned for the assigned archs);
  k/v blocks (BLK_KV, Hd) stream; GQA is expressed in the kv index_map
  (head h reads kv head h // group) — no materialized KV replication.
  Causal blocks with q_idx < kv_idx are skipped via pl.when (the TPU grid
  is sequential, so skipped instances cost only the grid step).

Outputs are (acc, l, m) — unnormalized weighted values plus softmax stats;
ops.flash_attention divides outside the kernel (keeps the kernel free of a
lane-broadcast divide). Validated against ref.reference (the chunked-softmax
oracle) in interpret mode by tests/test_flash_kernel.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, acc_ref, l_ref, m_ref, *,
            blk_q: int, blk_kv: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        l_ref[...] = jnp.zeros_like(l_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    def body():
        q = q_ref[0].astype(jnp.float32)              # (BQ, Hd)
        k = k_ref[0].astype(jnp.float32)              # (BKV, Hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * blk_q + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_kv), 0)
            k_pos = ki * blk_kv + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_kv), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)

        m_prev = m_ref[0]                             # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                        # (BQ, BKV)
        l_ref[0] = l_ref[0] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[0] = acc_ref[0] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    if causal:
        # skip fully-masked blocks: kv block strictly after the q block
        pl.when(ki * blk_kv <= qi * blk_q + blk_q - 1)(body)
    else:
        body()


def flash_attention_bhsd(q, k, v, *, blk_q: int = 256, blk_kv: int = 256,
                         causal: bool = True, interpret: bool = True
                         ) -> jax.Array:
    """q: (BH, S, Hd); k/v: (BKvH, S, Hd) with BH = BKvH * group.
    Returns (BH, S, Hd) f32-accurate attention output (cast to q.dtype)."""
    bh, sq, hd = q.shape
    bkv, skv, _ = k.shape
    group = bh // bkv
    blk_q = min(blk_q, sq)
    blk_kv = min(blk_kv, skv)
    assert sq % blk_q == 0 and skv % blk_kv == 0, (sq, blk_q, skv, blk_kv)
    n_q, n_kv = sq // blk_q, skv // blk_kv
    scale = hd ** -0.5

    kern = functools.partial(_kernel, blk_q=blk_q, blk_kv=blk_kv,
                             scale=scale, causal=causal)
    grid = (bh, n_q, n_kv)
    out_shape = [
        jax.ShapeDtypeStruct((bh, sq, hd), jnp.float32),   # acc
        jax.ShapeDtypeStruct((bh, sq, 1), jnp.float32),    # l
        jax.ShapeDtypeStruct((bh, sq, 1), jnp.float32),    # m
    ]
    out_spec = [
        pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, blk_q, 1), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, blk_q, 1), lambda b, i, j: (b, i, 0)),
    ]
    acc, l, m = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
            # GQA: head b reads kv head b // group — no KV replication
            pl.BlockSpec((1, blk_kv, hd), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, blk_kv, hd), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(q, k, v)
    out = acc / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype)


def vmem_bytes(blk_q: int, blk_kv: int, hd: int) -> int:
    """Working set: q/k/v blocks (x2 double buffer) + acc/l/m + p."""
    io = 2 * (blk_q * hd + 2 * blk_kv * hd) * 2
    live = (blk_q * hd + 2 * blk_q) * 4 + blk_q * blk_kv * 4 * 3
    return io + live


# ===========================================================================
# backward kernels + custom VJP (training path)
#
# fwd saves (q, k, v, out, L = m + log l). bwd recomputes p per block:
#   D   = rowsum(dout * out)
#   p   = exp(q k^T * scale - L)
#   ds  = p * (dout v^T - D) * scale
#   dq  = sum_kv ds k        (grid: kv innermost, dq block revisited)
#   dk  = sum_q  ds^T q      (grid: q innermost, dk/dv blocks revisited)
#   dv  = sum_q  p^T dout
# ===========================================================================

def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   *, blk_q, blk_kv, scale, causal):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    def body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]                                  # (BQ, 1)
        delta = delta_ref[0]                              # (BQ, 1)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * blk_q + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_kv), 0)
            k_pos = ki * blk_kv + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_kv), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        p = jnp.exp(s - lse)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        dq_ref[0] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        pl.when(ki * blk_kv <= qi * blk_q + blk_q - 1)(body)
    else:
        body()


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, *, blk_q, blk_kv, scale, causal, group):
    ki, qi = pl.program_id(1), pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    def body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * blk_q + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_kv), 0)
            k_pos = ki * blk_kv + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_kv), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        p = jnp.exp(s - lse)                              # (BQ, BKV)
        dv_ref[0] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        dk_ref[0] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        pl.when(ki * blk_kv <= qi * blk_q + blk_q - 1)(body)
    else:
        body()


def _fwd_with_stats(q, k, v, blk_q, blk_kv, causal, interpret):
    bh, sq, hd = q.shape
    bkv, skv, _ = k.shape
    group = bh // bkv
    n_q, n_kv = sq // blk_q, skv // blk_kv
    scale = hd ** -0.5
    kern = functools.partial(_kernel, blk_q=blk_q, blk_kv=blk_kv,
                             scale=scale, causal=causal)
    acc, l, m = pl.pallas_call(
        kern,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_kv, hd), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, blk_kv, hd), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_q, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_q, 1), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, sq, 1), jnp.float32),
            jax.ShapeDtypeStruct((bh, sq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_diff(q, k, v, blk_q=256, blk_kv=256, causal=True,
                         interpret=True):
    out, _ = _fwd_with_stats(q, k, v, blk_q, blk_kv, causal, interpret)
    return out


def _flash_fwd(q, k, v, blk_q, blk_kv, causal, interpret):
    out, lse = _fwd_with_stats(q, k, v, blk_q, blk_kv, causal, interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd(blk_q, blk_kv, causal, interpret, res, dout):
    q, k, v, out, lse = res
    bh, sq, hd = q.shape
    bkv, skv, _ = k.shape
    group = bh // bkv
    n_q, n_kv = sq // blk_q, skv // blk_kv
    scale = hd ** -0.5
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1, keepdims=True)              # (BH, S, 1)

    q_spec = pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0))
    kv_spec = pl.BlockSpec((1, blk_kv, hd), lambda b, i, j: (b // group, j, 0))
    st_spec = pl.BlockSpec((1, blk_q, 1), lambda b, i, j: (b, i, 0))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, blk_q=blk_q, blk_kv=blk_kv,
                          scale=scale, causal=causal),
        grid=(bh, n_q, n_kv),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, st_spec, st_spec],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), jnp.float32),
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    # dkv: grid over (BH, kv, q); outputs indexed by (b, kv) accumulate over
    # q steps. GQA: each q head contributes to its kv head's gradient —
    # sum the per-q-head partials afterwards.
    q_spec2 = pl.BlockSpec((1, blk_q, hd), lambda b, j, i: (b, i, 0))
    kv_spec2 = pl.BlockSpec((1, blk_kv, hd), lambda b, j, i: (b // group, j, 0))
    st_spec2 = pl.BlockSpec((1, blk_q, 1), lambda b, j, i: (b, i, 0))
    dkv_spec = pl.BlockSpec((1, blk_kv, hd), lambda b, j, i: (b, j, 0))
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, blk_q=blk_q, blk_kv=blk_kv,
                          scale=scale, causal=causal, group=group),
        grid=(bh, n_kv, n_q),
        in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, st_spec2, st_spec2],
        out_specs=[dkv_spec, dkv_spec],
        out_shape=[jax.ShapeDtypeStruct((bh, skv, hd), jnp.float32)] * 2,
        interpret=interpret,
    )(q, k, v, dout, lse, delta)
    # reduce the group dim into kv heads
    dk = dk_h.reshape(bkv, group, skv, hd).sum(1)
    dv = dv_h.reshape(bkv, group, skv, hd).sum(1)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention_diff.defvjp(_flash_fwd, _flash_bwd)
