"""Flash-attention family registration for the unified kernel registry.

Before the registry, `kernels/flash/ops.py` froze `blk_q = blk_kv = 256`
for every problem — exactly the hand-picked-constant the paper's v8 step
warns against. This descriptor gives flash the same journey GPP got: a
`FlashKey` ProblemKey, a power-of-two `(blk_q, blk_kv)` config space with
VMEM clamping, and an analytic MXU/VPU/HBM roofline hook so `repro.tune`
can rank per size. Causality is part of the key (the causal skip changes
both the traffic and the masked-compute waste the model charges).

Model assumptions (documented, mirroring core.vpu_model's style):
  * bf16 operands (2 B) — the model path's dtype; f32 outputs/stats;
  * MXU time = 4·elems·hd / mxu_flops (two matmuls over every computed
    score element, 2 FLOPs each); masked halves of diagonal blocks still
    compute — smaller blocks waste less on the causal wedge but pay more
    per-instance grid overhead (the tuner's tradeoff);
  * softmax/online-rescale ≈ 12 VPU passes per score element (exp ≈ 8);
  * q is resident across the kv sweep (index map ignores the kv axis),
    k/v re-fetch per visited (q, kv) block pair.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import backend
from repro.core.hw import TPU_V5E
from repro.core.vpu_model import GRID_OVERHEAD_S, PASS_RATE
from repro.kernels import api
from repro.kernels.flash import flash as flash_lib

BLK_MENU = (32, 64, 128, 256, 512)
SOFTMAX_PASSES = 12.0          # exp + max/sum/corr per score element
BF16 = 2                       # operand bytes


@dataclasses.dataclass(frozen=True)
class FlashKey:
    """ProblemKey for one attention call, model-native (B,S,H,Hd) layout."""
    b: int
    h: int
    kvh: int
    sq: int
    skv: int
    hd: int
    causal: bool = True
    name: str = "attn"

    def key_dims(self) -> str:
        return (f"{self.b}x{self.h}x{self.kvh}x{self.sq}x{self.skv}"
                f"x{self.hd}{'c' if self.causal else 'f'}")


def _div_clamp(blk: int, s: int) -> int:
    """Largest block <= blk that exactly tiles s. A plain min() clamp on a
    non-dividing length would make the kernel's grid skip the tail rows
    and return NaN garbage silently (n_q = s // blk drops the remainder)."""
    blk = min(blk, s)
    while s % blk:
        blk -= 1
    return blk


@dataclasses.dataclass(frozen=True)
class FlashBlockConfig:
    name: str = "flash"
    blk_q: int = 256
    blk_kv: int = 256

    def clamped(self, key: FlashKey) -> "FlashBlockConfig":
        return dataclasses.replace(self, blk_q=_div_clamp(self.blk_q, key.sq),
                                   blk_kv=_div_clamp(self.blk_kv, key.skv))

    def vmem_bytes(self, hd: int) -> int:
        return flash_lib.vmem_bytes(self.blk_q, self.blk_kv, hd)


def _visited_pairs(key: FlashKey, cfg: FlashBlockConfig) -> int:
    """(q, kv) block pairs the grid actually runs (causal skips the
    strictly-upper wedge via pl.when)."""
    n_q, n_kv = key.sq // cfg.blk_q, key.skv // cfg.blk_kv
    if not key.causal:
        return n_q * n_kv
    return sum(min(n_kv, (qi * cfg.blk_q + cfg.blk_q - 1) // cfg.blk_kv + 1)
               for qi in range(n_q))


class FlashKernel(api.Kernel):
    name = "flash"
    versions = ("ref", "pallas")
    default_version = "pallas"
    tunable = ("pallas",)

    def problem_key(self, q, k, v, *, causal: bool = True) -> FlashKey:
        b, sq, h, hd = q.shape
        _, skv, kvh, _ = k.shape
        return FlashKey(b=b, h=h, kvh=kvh, sq=sq, skv=skv, hd=hd,
                        causal=causal)

    def config_space(self, key: FlashKey, version: str
                     ) -> List[FlashBlockConfig]:
        out = []
        for bq in BLK_MENU:
            if bq > key.sq or key.sq % bq:
                continue
            for bkv in BLK_MENU:
                if bkv > key.skv or key.skv % bkv:
                    continue
                cfg = FlashBlockConfig("tune", bq, bkv)
                if cfg.vmem_bytes(key.hd) <= TPU_V5E.vmem_bytes:
                    out.append(cfg)
        return out

    def clamp(self, config: FlashBlockConfig, key: FlashKey
              ) -> FlashBlockConfig:
        return config.clamped(key)

    def static_config(self, key: FlashKey, version: str
                      ) -> Optional[FlashBlockConfig]:
        return FlashBlockConfig().clamped(key)     # the legacy 256/256

    def tie_break(self, config: FlashBlockConfig) -> Tuple:
        return (-config.blk_q, -config.blk_kv)

    def finalize_config(self, config: FlashBlockConfig, version: str
                        ) -> FlashBlockConfig:
        return dataclasses.replace(config, name=version)

    def model_step_s(self, key: FlashKey, config: FlashBlockConfig,
                     version: str) -> float:
        cfg = config.clamped(key)
        bh = key.b * key.h
        pairs = _visited_pairs(key, cfg)
        elems = pairs * cfg.blk_q * cfg.blk_kv       # computed score elements
        mxu_s = 4.0 * bh * elems * key.hd / TPU_V5E.mxu_flops
        vpu_s = bh * elems * SOFTMAX_PASSES / PASS_RATE
        overhead_s = bh * pairs * GRID_OVERHEAD_S
        bytes_ = bh * (key.sq * key.hd * BF16              # q (resident)
                       + pairs * 2 * cfg.blk_kv * key.hd * BF16   # k, v
                       + key.sq * key.hd * 4 + key.sq * 2 * 4)    # acc, l, m
        return max(mxu_s + vpu_s + overhead_s, bytes_ / TPU_V5E.hbm_bw)

    def measure_ok(self, key: FlashKey) -> bool:
        # interpret-mode attention is slow: only time truly tiny problems
        return key.b * key.h * key.sq * key.skv * key.hd <= 1 << 20

    def make_example(self, key: FlashKey, seed: int = 0
                     ) -> Tuple[tuple, dict]:
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (key.b, key.sq, key.h, key.hd),
                              jnp.bfloat16)
        k = jax.random.normal(ks[1], (key.b, key.skv, key.kvh, key.hd),
                              jnp.bfloat16)
        v = jax.random.normal(ks[2], (key.b, key.skv, key.kvh, key.hd),
                              jnp.bfloat16)
        return (q, k, v), {"causal": key.causal}

    def config_from_json(self, d: Dict) -> FlashBlockConfig:
        return FlashBlockConfig(**d)

    # -- static-analysis hooks (repro.analyze) -----------------------------
    def canonical_keys(self) -> List[FlashKey]:
        return [FlashKey(b=2, h=2, kvh=2, sq=128, skv=128, hd=32,
                         causal=True)]

    def key_from_dims(self, dims: str) -> FlashKey:
        causal = dims.endswith("c")
        b, h, kvh, sq, skv, hd = (int(d) for d in dims[:-1].split("x"))
        return FlashKey(b=b, h=h, kvh=kvh, sq=sq, skv=skv, hd=hd,
                        causal=causal)

    def config_vmem_bytes(self, config: FlashBlockConfig, key: FlashKey
                          ) -> int:
        return config.vmem_bytes(key.hd)

    def config_divides(self, config: FlashBlockConfig, key: FlashKey
                       ) -> List[str]:
        out = []
        for axis, n, blk in (("sq", key.sq, config.blk_q),
                             ("skv", key.skv, config.blk_kv)):
            if blk <= 0 or n % blk:
                out.append(f"{axis}={n} not tiled by block {blk}")
        return out

    def allowed_float_dtypes(self, version: str) -> frozenset:
        # bf16 operands, f32 stats/accumulator/output
        return frozenset({"bfloat16", "float32"})

    def run(self, q, k, v, *, version: str,
            config: Optional[FlashBlockConfig], interpret: Optional[bool],
            causal: bool = True):
        """q: (B,S,H,Hd); k/v: (B,S,KvH,Hd) -> (B,S,H,Hd). Reshapes to
        planar heads, runs the kernel, restores the layout (the contract
        the old ops.flash_attention had)."""
        b, sq, h, hd = q.shape
        _, skv, kvh, _ = k.shape
        qp = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
        kp = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
        vp = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
        if version == "ref":
            from repro.kernels.flash.ref import reference
            out = reference(qp, kp, vp, causal=causal)
        else:
            cfg = (config or FlashBlockConfig()).clamped(
                self.problem_key(q, k, v, causal=causal))
            out = flash_lib.flash_attention_diff(
                qp, kp, vp, cfg.blk_q, cfg.blk_kv, causal,
                backend.resolve_interpret(interpret))
        return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


KERNEL = api.register(FlashKernel())
