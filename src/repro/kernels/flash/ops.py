"""Public flash attention API used by models/attention.py.

flash_attention(q, k, v): (B, S, H, Hd) x (B, S, KvH, Hd) layout (the
model's native layout); reshapes to planar heads, runs the Pallas kernel
(interpret on CPU), restores the layout.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash.flash import flash_attention_bhsd, flash_attention_diff


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def flash_attention(q, k, v, *, causal: bool = True,
                    blk_q: int = 256, blk_kv: int = 256,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B,S,H,Hd); k/v: (B,S,KvH,Hd) -> (B,S,H,Hd)."""
    b, s, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    if interpret is None:
        interpret = not _on_tpu()
    blk_q = min(blk_q, s)
    blk_kv = min(blk_kv, skv)
    qp = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kp = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    vp = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    out = flash_attention_diff(qp, kp, vp, blk_q, blk_kv, causal, interpret)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
