"""Legacy flash-attention entry point — a thin deprecation shim over the
unified kernel registry.

    from repro.kernels import api
    out = api.dispatch("flash", q, k, v, causal=True)       # new API
    # config=None -> the repro.tune cached (blk_q, blk_kv) for this size

`ops.flash_attention(...)` forwards to `dispatch` (the explicit
blk_q/blk_kv arguments become a FlashBlockConfig) and emits one
DeprecationWarning per process. Bit-identical at every shape the requested
blocks tile; at non-dividing shapes the clamp now rounds down to a
dividing block (the old min() clamp silently dropped the tail rows —
NaN output — so exact equivalence there is deliberately not preserved).
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import api, warn_once
from repro.kernels.flash.kernel_def import FlashBlockConfig

_DEPRECATION = ("repro.kernels.flash.ops.flash_attention is deprecated; "
                "use repro.kernels.api.dispatch('flash', q, k, v, ...)")


def flash_attention(q, k, v, *, causal: bool = True,
                    blk_q: int = 256, blk_kv: int = 256,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B,S,H,Hd); k/v: (B,S,KvH,Hd) -> (B,S,H,Hd).
    Deprecated: use api.dispatch("flash", ...)."""
    warn_once(_DEPRECATION)
    return api.dispatch("flash", q, k, v, causal=causal,
                        config=FlashBlockConfig("legacy", blk_q, blk_kv),
                        interpret=interpret)
