"""Pure-jnp oracle for the flash kernel: exact (one-shot) softmax attention
in f32 over (BH, S, Hd) planar heads."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def reference(q, k, v, *, causal: bool = True) -> jax.Array:
    """q: (BH, S, Hd); k/v: (BKvH, S, Hd). Exact attention, f32."""
    bh, sq, hd = q.shape
    bkv = k.shape[0]
    group = bh // bkv
    kk = jnp.repeat(k, group, axis=0)
    vv = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * hd ** -0.5
    if causal:
        i = jnp.arange(sq)[:, None]
        j = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(j <= i, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
