"""GPP family registration for the unified kernel registry
(`repro.kernels.api`). The versioned dispatch that used to live in
`kernels/gpp/ops.py` — v0–v5 pure-JAX variants, v6–v9 static Pallas
configs, v10 autotuned — expressed as a `Kernel` descriptor so gpp shares
the dispatch/tune/bench plumbing with flash and ssm.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro import backend
from repro.core import vpu_model
from repro.kernels import api
from repro.kernels.gpp import pallas_gpp, problem, variants
from repro.tune import measure, space


@functools.lru_cache(maxsize=None)
def jitted_variant(version: str):
    """One jitted callable per pure-JAX variant for the process lifetime
    (jax.jit at every dispatch would rebuild the wrapper and re-hash the
    pytree structure each time)."""
    return jax.jit(variants.VARIANTS[version])


def size_of_inputs(inputs: Dict) -> problem.GppSize:
    """Recover the GppSize of a planar input dict (named if it matches a
    registered size, else 'custom')."""
    ncouls, ngpown = inputs["wtilde_re"].shape
    nw, nbands = inputs["wx"].shape
    for s in problem.SIZES.values():
        if (s.ncouls, s.ngpown, s.nbands, s.nw) == (ncouls, ngpown, nbands,
                                                    nw):
            return s
    return problem.GppSize("custom", nbands=nbands, ngpown=ngpown,
                           ncouls=ncouls, nw=nw)


class GppKernel(api.Kernel):
    name = "gpp"
    versions = ("v0", "v1", "v2", "v3", "v4", "v5", "v6", "v7", "v8", "v9",
                "v10")
    default_version = "v10"
    tunable = ("v10",)

    def problem_key(self, inputs: Dict) -> problem.GppSize:
        return size_of_inputs(inputs)

    def config_space(self, key: problem.GppSize, version: str
                     ) -> List[pallas_gpp.BlockConfig]:
        fused = version not in ("v6", "v7", "v8")
        return space.candidates(key, fused=fused)

    def clamp(self, config: pallas_gpp.BlockConfig, key: problem.GppSize
              ) -> pallas_gpp.BlockConfig:
        return config.clamped(key)

    def static_config(self, key: problem.GppSize, version: str
                      ) -> Optional[pallas_gpp.BlockConfig]:
        if version in pallas_gpp.CONFIGS:
            return pallas_gpp.CONFIGS[version].clamped(key)
        return None    # v0–v5 take no config; v10 tunes

    def tie_break(self, config: pallas_gpp.BlockConfig) -> Tuple:
        # bigger blocks first — fewer grid instances
        return (-config.blk_band, -config.blk_ig, -config.blk_igp)

    def finalize_config(self, config: pallas_gpp.BlockConfig, version: str
                        ) -> pallas_gpp.BlockConfig:
        return dataclasses.replace(config, name=version)

    def model_step_s(self, key: problem.GppSize,
                     config: pallas_gpp.BlockConfig, version: str) -> float:
        mix = vpu_model.OP_MIX.get(version, vpu_model.OP_MIX["v9"])
        return vpu_model.pallas_step_s(key, config, mix)

    def measure_ok(self, key: problem.GppSize) -> bool:
        return key.inner_iters <= measure.MEASURE_MAX_ITERS

    def make_example(self, key: problem.GppSize, seed: int = 0
                     ) -> Tuple[tuple, dict]:
        return (problem.make_inputs(key, seed=seed),), {}

    def config_from_json(self, d: Dict) -> pallas_gpp.BlockConfig:
        return pallas_gpp.BlockConfig(**d)

    # -- static-analysis hooks (repro.analyze) -----------------------------
    def canonical_keys(self) -> List[problem.GppSize]:
        return [problem.TINY, problem.BENCH]

    def key_from_dims(self, dims: str) -> problem.GppSize:
        ncouls, ngpown, nbands, nw = (int(d) for d in dims.split("x"))
        for s in problem.SIZES.values():
            if (s.ncouls, s.ngpown, s.nbands, s.nw) == (ncouls, ngpown,
                                                        nbands, nw):
                return s
        return problem.GppSize("custom", nbands=nbands, ngpown=ngpown,
                               ncouls=ncouls, nw=nw)

    def config_vmem_bytes(self, config: pallas_gpp.BlockConfig,
                          key: problem.GppSize) -> int:
        return config.vmem_bytes(key.nw)

    def config_divides(self, config: pallas_gpp.BlockConfig,
                       key: problem.GppSize) -> List[str]:
        out = []
        for axis, n, blk in (("ncouls", key.ncouls, config.blk_ig),
                             ("ngpown", key.ngpown, config.blk_igp),
                             ("nbands", key.nbands, config.blk_band)):
            if blk <= 0 or n % blk:
                out.append(f"{axis}={n} not tiled by block {blk}")
        return out

    def allowed_float_dtypes(self, version: str) -> frozenset:
        # planar f32 arithmetic; outputs assemble to complex64
        return frozenset({"float32", "complex64"})

    def run(self, inputs: Dict, *, version: str,
            config: Optional[pallas_gpp.BlockConfig],
            interpret: Optional[bool]) -> Tuple[Any, Any]:
        if version in variants.VARIANTS:
            return jitted_variant(version)(inputs)
        if config is None:
            raise ValueError(f"gpp {version} needs a BlockConfig")
        return pallas_gpp.gpp_pallas(
            inputs, config, interpret=backend.resolve_interpret(interpret))


KERNEL = api.register(GppKernel())
