"""Public GPP kernel API.

    from repro.kernels.gpp import ops
    ach, asx = ops.gpp(inputs, version="v8")

v0–v5 dispatch to the pure-JAX variants; v6–v8 to the Pallas kernel
(interpret=True on CPU — the container has no TPU; on a real TPU pass
interpret=False). `inputs` is the planar dict from problem.make_inputs.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax

from repro.kernels.gpp import pallas_gpp, variants

DEFAULT_VERSION = "v8"


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def gpp(inputs: Dict, version: str = DEFAULT_VERSION, *,
        interpret: Optional[bool] = None,
        block_config: Optional[pallas_gpp.BlockConfig] = None
        ) -> Tuple[jax.Array, jax.Array]:
    """Run the GPP kernel. Returns (achtemp, asxtemp), complex64 (nw,)."""
    if version in variants.VARIANTS:
        return jax.jit(variants.VARIANTS[version])(inputs)
    if version not in pallas_gpp.CONFIGS and block_config is None:
        raise ValueError(f"unknown GPP version {version!r}")
    cfg = block_config or pallas_gpp.CONFIGS[version]
    if interpret is None:
        interpret = not _on_tpu()
    return pallas_gpp.gpp_pallas(inputs, cfg, interpret=interpret)


gpp_v8 = functools.partial(gpp, version="v8")
