"""Public GPP kernel API.

    from repro.kernels.gpp import ops
    ach, asx = ops.gpp(inputs, version="v10")

v0–v5 dispatch to the pure-JAX variants (jitted once per version, cached);
v6–v9 to the Pallas kernel under that version's static BlockConfig (clamped
to small problems); v10 dispatches through the repro.tune autotuner — the
tuned config for (size, backend) is looked up in the JSON cache (and tuned
on a miss: model-ranked, measurement-verified when cheap enough).

Pallas runs interpret=True on CPU — the container has no TPU; on a real TPU
pass interpret=False (or leave None to autodetect). `inputs` is the planar
dict from problem.make_inputs.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax

from repro.kernels.gpp import pallas_gpp, problem, variants

DEFAULT_VERSION = "v10"


@functools.lru_cache(maxsize=None)
def jitted_variant(version: str):
    """One jitted callable per pure-JAX variant for the process lifetime
    (jax.jit at every gpp() call would rebuild the dispatch wrapper and
    re-hash the pytree structure each time)."""
    return jax.jit(variants.VARIANTS[version])


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def size_of_inputs(inputs: Dict) -> problem.GppSize:
    """Recover the GppSize of a planar input dict (named if it matches a
    registered size, else 'custom')."""
    ncouls, ngpown = inputs["wtilde_re"].shape
    nw, nbands = inputs["wx"].shape
    for s in problem.SIZES.values():
        if (s.ncouls, s.ngpown, s.nbands, s.nw) == (ncouls, ngpown, nbands,
                                                    nw):
            return s
    return problem.GppSize("custom", nbands=nbands, ngpown=ngpown,
                           ncouls=ncouls, nw=nw)


def gpp(inputs: Dict, version: str = DEFAULT_VERSION, *,
        interpret: Optional[bool] = None,
        block_config: Optional[pallas_gpp.BlockConfig] = None
        ) -> Tuple[jax.Array, jax.Array]:
    """Run the GPP kernel. Returns (achtemp, asxtemp), complex64 (nw,)."""
    if version in variants.VARIANTS:
        return jitted_variant(version)(inputs)
    cfg = block_config
    if cfg is None:
        if version in pallas_gpp.CONFIGS:
            cfg = pallas_gpp.CONFIGS[version].clamped(size_of_inputs(inputs))
        elif version == "v10":
            from repro.tune import tuner   # deferred: tune is optional here
            cfg = tuner.best_config(size_of_inputs(inputs))
        else:
            raise ValueError(f"unknown GPP version {version!r}")
    if interpret is None:
        interpret = not _on_tpu()
    return pallas_gpp.gpp_pallas(inputs, cfg, interpret=interpret)


gpp_v8 = functools.partial(gpp, version="v8")
gpp_v10 = functools.partial(gpp, version="v10")
