"""Legacy GPP kernel entry point — a thin deprecation shim over the unified
kernel registry.

    from repro.kernels import api
    ach, asx = api.dispatch("gpp", inputs, version="v10")   # new API

`ops.gpp(...)` forwards to `dispatch` bit-identically (same jitted-variant
cache for v0–v5, same static-config clamping for v6–v9, same tuned-config
path for v10) and emits one DeprecationWarning per process. `inputs` is the
planar dict from problem.make_inputs.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax

from repro.kernels import api, warn_once
from repro.kernels.gpp import pallas_gpp
# jitted_variant / size_of_inputs moved to kernel_def; re-exported because
# they are not deprecated (journey + tests use them as the canonical cache)
from repro.kernels.gpp.kernel_def import jitted_variant, size_of_inputs  # noqa: F401

DEFAULT_VERSION = "v10"

_DEPRECATION = ("repro.kernels.gpp.ops.gpp is deprecated; use "
                "repro.kernels.api.dispatch('gpp', inputs, version=...)")


def gpp(inputs: Dict, version: str = DEFAULT_VERSION, *,
        interpret: Optional[bool] = None,
        block_config: Optional[pallas_gpp.BlockConfig] = None
        ) -> Tuple[jax.Array, jax.Array]:
    """Run the GPP kernel. Returns (achtemp, asxtemp), complex64 (nw,).
    Deprecated: use api.dispatch("gpp", ...)."""
    warn_once(_DEPRECATION)
    return api.dispatch("gpp", inputs, version=version, config=block_config,
                        interpret=interpret)


gpp_v8 = functools.partial(gpp, version="v8")
gpp_v10 = functools.partial(gpp, version="v10")
