"""GPP Pallas TPU kernel — the paper's v6 (cache blocking), v7 (index swap)
and v8 (block-size tuning) steps, made *explicit* through BlockSpecs, plus
the beyond-the-paper v9 step (fused VMEM scratch accumulation).

Grid: (n_igp_blocks, n_ig_blocks, n_band_blocks) — band innermost, so the
output block (indexed by igp/ig only) is revisited across band steps and
accumulated in place (@pl.when(band_step == 0) zero-init). The wtilde/eps
tiles' index maps don't depend on the band index, so the Pallas pipeline
keeps them resident in VMEM across the whole in-block band sweep — this IS
the paper's v4/v6 reuse structure, declared rather than hoped-for from a
cache (DESIGN.md §2).

In-kernel layout (TPU 8x128 VREG lanes):
  wtilde/eps tiles: (BLK_IG, BLK_IGP)  — sublanes=ig, lanes=igp
  aqsn: passed transposed (nbands, ncouls), tile (BLK_BAND, BLK_IG):
        row read aqsn[b, :] is a sublane-indexed load (cheap)
  aqsm v6 layout: (ngpown, nbands), tile (BLK_IGP, BLK_BAND): the per-band
        read is a *lane-dim dynamic slice + relayout* — the TPU analogue of
        the paper's non-contiguous aqsmtemp(igp,band) access.
  aqsm v7 layout: transposed (nbands, ngpown), tile (BLK_BAND, BLK_IGP):
        per-band read is a sublane row, broadcast straight onto lanes.
  v8: same code as v7 with tuned (larger) blocks — lanes filled (BLK_IGP>=128),
      VMEM working set sized for double-buffering (see VMEM_MODEL).
  v9: fused accumulation — the per-(igp,ig) partial sums live in a VMEM
      scratch accumulator (pl.pallas_call scratch_shapes) instead of
      read-modify-writing the output block every band step; outputs are
      written once, on the last band step. With the output RMW off the
      critical path the igp/ig grid axes are declared `parallel`
      (dimension_semantics), so Mosaic may overlap grid sequencing with the
      VPU work. v10 is v9 under an autotuned BlockConfig (repro.tune).

Numerics: planar f32; validated in interpret mode against ref.ref_numpy
(complex128) by tests/test_gpp_kernel.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax>=0.5 renames TPUCompilerParams -> CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

from repro.kernels.gpp.problem import LIMITONE, LIMITTWO, TOL_ZERO, GppSize


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    name: str
    blk_ig: int
    blk_igp: int
    blk_band: int
    aqsm_transposed: bool   # v7/v8 layout swap
    fused_acc: bool = False  # v9+: VMEM scratch accumulators + parallel dims

    def vmem_bytes(self, nw: int = 2) -> int:
        """Analytic VMEM working set (×2 for double buffering on inputs)."""
        t_ig_igp = self.blk_ig * self.blk_igp * 4
        inputs = 4 * t_ig_igp                                 # wt/eps re+im
        inputs += 2 * self.blk_band * self.blk_ig * 4         # aqsn tile
        inputs += 2 * self.blk_band * self.blk_igp * 4        # aqsm tile
        inputs += self.blk_band * nw * 4 + self.blk_ig * 4    # wx, vcoul
        live = 14 * t_ig_igp                                  # intermediates
        total = 2 * inputs + live
        if self.fused_acc:
            total += 4 * nw * 4                               # scratch accs
        return total

    def clamped(self, size: GppSize) -> "BlockConfig":
        """Shrink blocks to fit a smaller problem (power-of-two dims keep
        divisibility; gpp_pallas re-asserts it)."""
        return dataclasses.replace(
            self, blk_ig=min(self.blk_ig, size.ncouls),
            blk_igp=min(self.blk_igp, size.ngpown),
            blk_band=min(self.blk_band, size.nbands))


# canonical journey configs. v6: first blocking attempt — small band blocks
# and aqsm in (igp, band) layout, whose lane dim (band=8) is below the 128
# DMA/VREG granularity (traffic inflation + per-band lane relayout). v7:
# index swap fixes the layout. v8: block sizes tuned (core/journey.py sweep)
# so per-instance compute amortizes grid/DMA issue overhead.
V6 = BlockConfig("v6", blk_ig=256, blk_igp=128, blk_band=8, aqsm_transposed=False)
V7 = BlockConfig("v7", blk_ig=256, blk_igp=128, blk_band=8, aqsm_transposed=True)
V8 = BlockConfig("v8", blk_ig=512, blk_igp=128, blk_band=32, aqsm_transposed=True)
# v9: v8's tuned blocks + fused scratch accumulation. v10 has no static
# config — it is v9 under whatever BlockConfig repro.tune picks per size.
V9 = BlockConfig("v9", blk_ig=512, blk_igp=128, blk_band=32,
                 aqsm_transposed=True, fused_acc=True)

CONFIGS = {"v6": V6, "v7": V7, "v8": V8, "v9": V9}


def _band_sweep(wt_re_ref, wt_im_ref, eps_re_ref, eps_im_ref,
                aqsn_re_ref, aqsn_im_ref, aqsm_re_ref, aqsm_im_ref,
                wx_ref, vcoul_ref, *, cfg: BlockConfig, nw: int):
    """The in-block band sweep shared by the v6–v8 and v9 kernels: reduce
    this grid instance's (BLK_IG, BLK_IGP, BLK_BAND) tile down to nw
    (ach_re, ach_im, asx_re, asx_im) scalars."""
    wt_re = wt_re_ref[...]            # (BIG, BIGP) — resident across bands
    wt_im = wt_im_ref[...]
    eps_re = eps_re_ref[...]
    eps_im = eps_im_ref[...]
    vcoul = vcoul_ref[...]            # (BIG, 1)

    # band-invariant subexpressions (the paper's v5 hoist)
    wt2_re = wt_re * wt_re - wt_im * wt_im
    wt2_im = 2.0 * wt_re * wt_im
    om2_re = wt2_re * eps_re - wt2_im * eps_im
    om2_im = wt2_re * eps_im + wt2_im * eps_re

    def band_iter(b, carry):
        accs = carry

        an_re = aqsn_re_ref[b, :][:, None]           # (BIG, 1) sublane row
        an_im = aqsn_im_ref[b, :][:, None]
        if cfg.aqsm_transposed:
            # v7/v8: sublane row read, broadcast onto lanes
            am_re = aqsm_re_ref[b, :][None, :]       # (1, BIGP)
            am_im = aqsm_im_ref[b, :][None, :]
        else:
            # v6: lane-dim dynamic slice + relayout (the "wrong" layout)
            am_re = aqsm_re_ref[:, b][None, :]
            am_im = aqsm_im_ref[:, b][None, :]

        # mat(ig,igp) = conj(aqsm)*aqsn, pre-scaled by vcoul(ig)
        mat_re = an_re * am_re + an_im * am_im
        mat_im = an_im * am_re - an_re * am_im
        wre = vcoul * mat_re
        wim = vcoul * mat_im

        new_accs = []
        for iw in range(nw):
            wxv = wx_ref[b, iw]
            wd_re = wxv - wt_re
            wd_im = -wt_im
            wdiffr = wd_re * wd_re + wd_im * wd_im
            rden = 1.0 / wdiffr
            delw_re = (wt_re * wd_re + wt_im * wd_im) * rden
            delw_im = (wt_im * wd_re - wt_re * wd_im) * rden
            delwr = delw_re * delw_re + delw_im * delw_im
            cond1 = (wdiffr > LIMITTWO) & (delwr < LIMITONE)
            cond2 = (~cond1) & (delwr > TOL_ZERO)

            sch1_re = delw_re * eps_re - delw_im * eps_im
            sch1_im = delw_re * eps_im + delw_im * eps_re
            cden1_re = wxv * wxv - wt2_re
            cden1_im = -wt2_im
            c1sq = cden1_re * cden1_re + cden1_im * cden1_im
            r1 = 1.0 / c1sq
            ssx1_re = (om2_re * cden1_re + om2_im * cden1_im) * r1
            ssx1_im = (om2_im * cden1_re - om2_re * cden1_im) * r1

            f4_re = 4.0 * (delw_re + 0.5)
            f4_im = 4.0 * delw_im
            cd2_re = wt2_re * f4_re - wt2_im * f4_im
            cd2_im = wt2_re * f4_im + wt2_im * f4_re
            c2sq = cd2_re * cd2_re + cd2_im * cd2_im
            c2sq = jnp.where(c2sq == 0, 1.0, c2sq)
            n2_re = -(om2_re * delw_re - om2_im * delw_im)
            n2_im = -(om2_re * delw_im + om2_im * delw_re)
            r2 = 1.0 / c2sq
            ssx2_re = (n2_re * cd2_re + n2_im * cd2_im) * r2
            ssx2_im = (n2_im * cd2_re - n2_re * cd2_im) * r2

            sch_re = jnp.where(cond1, sch1_re, 0.0)
            sch_im = jnp.where(cond1, sch1_im, 0.0)
            ssx_re = jnp.where(cond1, ssx1_re, jnp.where(cond2, ssx2_re, 0.0))
            ssx_im = jnp.where(cond1, ssx1_im, jnp.where(cond2, ssx2_im, 0.0))

            da_re = jnp.sum(wre * sch_re - wim * sch_im)
            da_im = jnp.sum(wre * sch_im + wim * sch_re)
            dx_re = jnp.sum(wre * ssx_re - wim * ssx_im)
            dx_im = jnp.sum(wre * ssx_im + wim * ssx_re)
            a_re, a_im, x_re, x_im = accs[iw]
            new_accs.append((a_re + da_re, a_im + da_im,
                             x_re + dx_re, x_im + dx_im))
        return tuple(new_accs)

    zero = jnp.float32(0.0)
    init = tuple((zero, zero, zero, zero) for _ in range(nw))
    return jax.lax.fori_loop(0, cfg.blk_band, band_iter, init)


def _kernel(wt_re_ref, wt_im_ref, eps_re_ref, eps_im_ref,
            aqsn_re_ref, aqsn_im_ref, aqsm_re_ref, aqsm_im_ref,
            wx_ref, vcoul_ref,
            ach_re_ref, ach_im_ref, asx_re_ref, asx_im_ref,
            *, cfg: BlockConfig, nw: int):
    """v6–v8: the output block is revisited across band steps and
    read-modify-written in place (@pl.when(band_step == 0) zero-init)."""
    band_step = pl.program_id(2)

    @pl.when(band_step == 0)
    def _init():
        ach_re_ref[...] = jnp.zeros_like(ach_re_ref)
        ach_im_ref[...] = jnp.zeros_like(ach_im_ref)
        asx_re_ref[...] = jnp.zeros_like(asx_re_ref)
        asx_im_ref[...] = jnp.zeros_like(asx_im_ref)

    accs = _band_sweep(wt_re_ref, wt_im_ref, eps_re_ref, eps_im_ref,
                       aqsn_re_ref, aqsn_im_ref, aqsm_re_ref, aqsm_im_ref,
                       wx_ref, vcoul_ref, cfg=cfg, nw=nw)
    for iw in range(nw):
        a_re, a_im, x_re, x_im = accs[iw]
        ach_re_ref[0, 0, iw] += a_re
        ach_im_ref[0, 0, iw] += a_im
        asx_re_ref[0, 0, iw] += x_re
        asx_im_ref[0, 0, iw] += x_im


def _kernel_fused(wt_re_ref, wt_im_ref, eps_re_ref, eps_im_ref,
                  aqsn_re_ref, aqsn_im_ref, aqsm_re_ref, aqsm_im_ref,
                  wx_ref, vcoul_ref,
                  ach_re_ref, ach_im_ref, asx_re_ref, asx_im_ref,
                  acc_ref,
                  *, cfg: BlockConfig, nw: int):
    """v9: partial sums accumulate in a (4, nw) VMEM scratch across the
    band steps; the output block is written exactly once, on the last
    step. No output RMW per band step -> igp/ig can be `parallel`."""
    band_step = pl.program_id(2)

    @pl.when(band_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    accs = _band_sweep(wt_re_ref, wt_im_ref, eps_re_ref, eps_im_ref,
                       aqsn_re_ref, aqsn_im_ref, aqsm_re_ref, aqsm_im_ref,
                       wx_ref, vcoul_ref, cfg=cfg, nw=nw)
    for iw in range(nw):
        a_re, a_im, x_re, x_im = accs[iw]
        acc_ref[0, iw] += a_re
        acc_ref[1, iw] += a_im
        acc_ref[2, iw] += x_re
        acc_ref[3, iw] += x_im

    @pl.when(band_step == pl.num_programs(2) - 1)
    def _flush():
        for iw in range(nw):
            ach_re_ref[0, 0, iw] = acc_ref[0, iw]
            ach_im_ref[0, 0, iw] = acc_ref[1, iw]
            asx_re_ref[0, 0, iw] = acc_ref[2, iw]
            asx_im_ref[0, 0, iw] = acc_ref[3, iw]


def gpp_pallas(inputs: Dict, cfg: BlockConfig, *,
               interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Run the blocked GPP kernel. inputs: planar dict (problem.make_inputs).
    Returns (ach (nw,) complex64, asx (nw,) complex64)."""
    f = {k: jnp.asarray(v, jnp.float32) for k, v in inputs.items()}
    ncouls, ngpown = f["wtilde_re"].shape
    nw, nbands = f["wx"].shape

    assert ncouls % cfg.blk_ig == 0, (ncouls, cfg.blk_ig)
    assert ngpown % cfg.blk_igp == 0, (ngpown, cfg.blk_igp)
    assert nbands % cfg.blk_band == 0, (nbands, cfg.blk_band)
    n_ig = ncouls // cfg.blk_ig
    n_igp = ngpown // cfg.blk_igp
    n_b = nbands // cfg.blk_band

    aqsn_re = f["aqsn_re"].T            # (nbands, ncouls)
    aqsn_im = f["aqsn_im"].T
    if cfg.aqsm_transposed:
        aqsm_re = f["aqsm_re"].T        # (nbands, ngpown)
        aqsm_im = f["aqsm_im"].T
        aqsm_spec = pl.BlockSpec((cfg.blk_band, cfg.blk_igp),
                                 lambda i, j, b: (b, i))
    else:
        aqsm_re = f["aqsm_re"]          # (ngpown, nbands)
        aqsm_im = f["aqsm_im"]
        aqsm_spec = pl.BlockSpec((cfg.blk_igp, cfg.blk_band),
                                 lambda i, j, b: (i, b))
    wx = f["wx"].T                      # (nbands, nw)
    vcoul = f["vcoul"][:, None]         # (ncouls, 1)

    ig_igp = pl.BlockSpec((cfg.blk_ig, cfg.blk_igp), lambda i, j, b: (j, i))
    aqsn_spec = pl.BlockSpec((cfg.blk_band, cfg.blk_ig), lambda i, j, b: (b, j))
    wx_spec = pl.BlockSpec((cfg.blk_band, nw), lambda i, j, b: (b, 0))
    vc_spec = pl.BlockSpec((cfg.blk_ig, 1), lambda i, j, b: (j, 0))
    out_spec = pl.BlockSpec((1, 1, nw), lambda i, j, b: (i, j, 0))
    out_shape = jax.ShapeDtypeStruct((n_igp, n_ig, nw), jnp.float32)

    extra = {}
    if cfg.fused_acc:
        kern = functools.partial(_kernel_fused, cfg=cfg, nw=nw)
        extra["scratch_shapes"] = [pltpu.VMEM((4, nw), jnp.float32)]
        # the band axis carries the scratch accumulator -> arbitrary; the
        # igp/ig axes have no cross-instance state -> parallel
        extra["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    else:
        kern = functools.partial(_kernel, cfg=cfg, nw=nw)
    outs = pl.pallas_call(
        kern,
        grid=(n_igp, n_ig, n_b),
        in_specs=[ig_igp, ig_igp, ig_igp, ig_igp,
                  aqsn_spec, aqsn_spec, aqsm_spec, aqsm_spec,
                  wx_spec, vc_spec],
        out_specs=[out_spec, out_spec, out_spec, out_spec],
        out_shape=[out_shape] * 4,
        interpret=interpret,
        **extra,
    )(f["wtilde_re"], f["wtilde_im"], f["eps_re"], f["eps_im"],
      aqsn_re, aqsn_im, aqsm_re, aqsm_im, wx, vcoul)

    ach_re, ach_im, asx_re, asx_im = outs
    ach = jnp.sum(ach_re, (0, 1)) + 1j * jnp.sum(ach_im, (0, 1))
    asx = jnp.sum(asx_re, (0, 1)) + 1j * jnp.sum(asx_im, (0, 1))
    return ach.astype(jnp.complex64), asx.astype(jnp.complex64)


def hbm_traffic_model(size: GppSize, cfg: BlockConfig) -> float:
    """Exact HBM byte count for the Pallas pipeline (deterministic — the
    blocks a pipeline fetches are fully determined by the index maps):
      wtilde/eps: fetched once per (igp, ig) block  -> full arrays once
      aqsn: index (ig, band) — refetched per igp block
      aqsm: index (igp, band) — refetched per ig block
      wx/vcoul/outs: negligible (counted anyway)
    """
    n_ig = size.ncouls // cfg.blk_ig
    n_igp = size.ngpown // cfg.blk_igp
    b = 0.0
    b += 4 * 4 * size.ncouls * size.ngpown                 # wt/eps planes
    b += n_igp * 2 * 4 * size.ncouls * size.nbands         # aqsn
    b += n_ig * 2 * 4 * size.ngpown * size.nbands          # aqsm
    b += n_ig * n_igp * 4 * size.nw * size.nbands          # wx
    b += n_igp * 4 * size.ncouls                           # vcoul
    b += 4 * 4 * n_ig * n_igp * size.nw                    # outputs
    return b
