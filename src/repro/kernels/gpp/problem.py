"""GPP (General Plasmon Pole) problem definition — the paper's kernel.

    do band = 1, nbands        # O(1000)
      do igp = 1, ngpown       # O(1000)
        do ig = 1, ncouls      # O(10000)
          do iw = 1, nw        # nw = 2
            wtilde = wtilde_array(ig,igp)
            wdiff  = wx_array(iw,band) - wtilde
            delw   = wtilde / wdiff
            ...branchy complex arithmetic...
            reduce into achtemp(iw), asxtemp(iw)

Data model (TPU adaptation, DESIGN.md §2): complex double -> PLANAR f32
(separate re/im arrays). The complex128 numpy oracle in ref.py provides the
precision budget.

Inputs:
    wtilde (ncouls, ngpown) complex   I_eps (ncouls, ngpown) complex
    aqsn   (ncouls, nbands) complex   aqsm  (ngpown, nbands) complex
    wx     (nw, nbands)     real      vcoul (ncouls,)        real
Outputs:
    achtemp (nw,) complex   asxtemp (nw,) complex

Branch semantics per (ig, igp, band, iw):
    wdiff  = wx - wtilde ;  rden = 1/(wdiff*conj(wdiff))
    delw   = wtilde * conj(wdiff) * rden ; delwr = |delw|^2 ; wdiffr = |wdiff|^2
    if   wdiffr > limittwo and delwr < limitone:
         sch = delw * I_eps ; cden = wx^2 - wtilde^2 ; ssx = Omega2 / cden
    elif delwr > TOL_Zero:
         sch = 0 ; cden = 4*wtilde2*(delw + 0.5) ; ssx = -Omega2 * delw / cden
    else: sch = 0 ; ssx = 0
    mat = conj(aqsm[igp,band]) * aqsn[ig,band]
    achtemp[iw] += vcoul[ig] * mat * sch
    asxtemp[iw] += vcoul[ig] * mat * ssx
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

LIMITONE = 1.0 / (0.25 * 0.25)   # BerkeleyGW constants (to_f = 1/4)
LIMITTWO = 0.25 * 0.25
TOL_ZERO = 1e-12
NW = 2


@dataclasses.dataclass(frozen=True)
class GppSize:
    name: str
    nbands: int
    ngpown: int
    ncouls: int
    nw: int = NW

    @property
    def inner_iters(self) -> int:
        return self.nbands * self.ngpown * self.ncouls * self.nw

    def key_dims(self) -> str:
        """ProblemKey protocol (repro.kernels.api): the tune-cache dims."""
        return f"{self.ncouls}x{self.ngpown}x{self.nbands}x{self.nw}"

    # analytic per-inner-iteration FLOP count for the branchless (v2+) form,
    # counted on the planar-f32 arithmetic (see variants.py):
    #   wdiff sub 2; |wdiff|^2 3; rcp 1 (div counts 1); delw 2 cmul-ish 8;
    #   |delw|^2 3; branch1: sch cmul 6, cden 5, |cden|^2+rcp 4, ssx 10;
    #   branch2: cden 8, ssx 12; selects ~8; mat cmul 6 (amortized /nw);
    #   accum 2x cmul+add 16.  ~= 90 flops / iter
    FLOPS_PER_ITER = 90.0

    def total_flops(self) -> float:
        return self.inner_iters * self.FLOPS_PER_ITER

    def min_hbm_bytes(self) -> float:
        """Compulsory traffic: read every input once (planar f32)."""
        b = 0
        b += 2 * 4 * self.ncouls * self.ngpown * 2   # wtilde, I_eps
        b += 2 * 4 * self.ncouls * self.nbands       # aqsn
        b += 2 * 4 * self.ngpown * self.nbands       # aqsm
        b += 4 * self.nw * self.nbands               # wx
        b += 4 * self.ncouls                         # vcoul
        b += 2 * 4 * self.nw * 2                     # outputs
        return float(b)


# Si-214 / Si-510 magnitudes per the paper (Sec. II-A: band,igp O(1000),
# ig O(10000); Si-510 is 3-4x larger on band/igp/ig; paper runtime ratio
# v0 Si510/Si214 = 14.6x). Exact BerkeleyGW sizes are not published in the
# paper, so representative magnitudes are used.
SI214 = GppSize("si214", nbands=1024, ngpown=1024, ncouls=8192)
SI510 = GppSize("si510", nbands=2560, ngpown=2560, ncouls=20480)
# CPU-benchable size (journey wall-clock measurements on this container)
BENCH = GppSize("bench", nbands=64, ngpown=64, ncouls=512)
TINY = GppSize("tiny", nbands=8, ngpown=8, ncouls=64)   # tests

SIZES = {s.name: s for s in (SI214, SI510, BENCH, TINY)}


def make_inputs(size: GppSize, seed: int = 0, dtype=np.float64) -> Dict[str, np.ndarray]:
    """Random inputs in planar layout (dict of float arrays, numpy).

    Distributions chosen so all three branches are exercised: wdiff is near
    zero for a fraction of elements (branch 2/3), large otherwise.
    """
    rng = np.random.default_rng(seed)
    c = lambda *s: (rng.standard_normal(s) + 1j * rng.standard_normal(s))
    wtilde = 0.5 * c(size.ncouls, size.ngpown) + 1.0
    i_eps = 0.3 * c(size.ncouls, size.ngpown)
    aqsn = c(size.ncouls, size.nbands) / np.sqrt(size.nbands)
    aqsm = c(size.ngpown, size.nbands) / np.sqrt(size.nbands)
    # wx near wtilde's magnitude so wdiff is sometimes small
    wx = rng.standard_normal((size.nw, size.nbands)) * 1.5 + 1.0
    vcoul = rng.random(size.ncouls) + 0.1
    out = {
        "wtilde_re": wtilde.real, "wtilde_im": wtilde.imag,
        "eps_re": i_eps.real, "eps_im": i_eps.imag,
        "aqsn_re": aqsn.real, "aqsn_im": aqsn.imag,
        "aqsm_re": aqsm.real, "aqsm_im": aqsm.imag,
        "wx": wx, "vcoul": vcoul,
    }
    return {k: v.astype(dtype) for k, v in out.items()}
