"""GPP oracles.

`ref_numpy` — complex128 numpy, the precision reference (the paper's FP64).
`ref_jnp`   — complex64 jnp, jit-able oracle used by the kernel allclose
              sweeps (tests/test_gpp_kernel.py).

Both implement the branch semantics documented in problem.py verbatim, with
divides and 3-way branching — i.e. the *v0 algorithm* in exact arithmetic.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gpp.problem import LIMITONE, LIMITTWO, TOL_ZERO


def _complex_views(inputs: Dict, xp):
    wtilde = inputs["wtilde_re"] + 1j * inputs["wtilde_im"]
    eps = inputs["eps_re"] + 1j * inputs["eps_im"]
    aqsn = inputs["aqsn_re"] + 1j * inputs["aqsn_im"]
    aqsm = inputs["aqsm_re"] + 1j * inputs["aqsm_im"]
    return wtilde, eps, aqsn, aqsm, inputs["wx"], inputs["vcoul"]


def ref_numpy(inputs: Dict[str, np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
    """complex128 oracle. Returns (achtemp (nw,), asxtemp (nw,))."""
    wtilde, eps, aqsn, aqsm, wx, vcoul = _complex_views(inputs, np)
    wtilde = wtilde.astype(np.complex128)
    eps = eps.astype(np.complex128)
    aqsn = aqsn.astype(np.complex128)
    aqsm = aqsm.astype(np.complex128)
    wx = wx.astype(np.float64)
    vcoul = vcoul.astype(np.float64)

    ncouls, ngpown = wtilde.shape
    nbands = aqsn.shape[1]
    nw = wx.shape[0]

    ach = np.zeros(nw, np.complex128)
    asx = np.zeros(nw, np.complex128)

    wtilde2 = wtilde * wtilde                          # (ig, igp)
    omega2 = wtilde2 * eps

    for iw in range(nw):
        for bb in range(nbands):                        # blocked for memory
            wxv = wx[iw, bb]                            # scalar
            wdiff = wxv - wtilde                        # (ig, igp)
            wdiffr = (wdiff * np.conj(wdiff)).real
            delw = wtilde * np.conj(wdiff) / np.maximum(wdiffr, 1e-300)
            delwr = (delw * np.conj(delw)).real

            cond1 = (wdiffr > LIMITTWO) & (delwr < LIMITONE)
            cond2 = (~cond1) & (delwr > TOL_ZERO)

            sch = np.where(cond1, delw * eps, 0.0)
            cden1 = wxv * wxv - wtilde2
            ssx1 = omega2 / np.where(cden1 == 0, 1.0, cden1)
            cden2 = 4.0 * wtilde2 * (delw + 0.5)
            ssx2 = -omega2 * delw / np.where(cden2 == 0, 1.0, cden2)
            ssx = np.where(cond1, ssx1, np.where(cond2, ssx2, 0.0))

            mat = np.conj(aqsm[:, bb])[None, :] * aqsn[:, bb][:, None]  # (ig, igp)
            w = vcoul[:, None] * mat
            ach[iw] += np.sum(w * sch)
            asx[iw] += np.sum(w * ssx)
    return ach, asx


def ref_jnp(inputs: Dict) -> Tuple[jax.Array, jax.Array]:
    """complex64 jnp oracle (same algorithm; scan over bands)."""
    f32 = {k: jnp.asarray(v, jnp.float32) for k, v in inputs.items()}
    wtilde = f32["wtilde_re"] + 1j * f32["wtilde_im"]
    eps = f32["eps_re"] + 1j * f32["eps_im"]
    aqsn = f32["aqsn_re"] + 1j * f32["aqsn_im"]
    aqsm = f32["aqsm_re"] + 1j * f32["aqsm_im"]
    wx = f32["wx"]
    vcoul = f32["vcoul"]
    nw = wx.shape[0]

    wtilde2 = wtilde * wtilde
    omega2 = wtilde2 * eps

    def per_band(carry, inp):
        ach, asx = carry
        wxb, aqsn_b, aqsm_b = inp                      # (nw,), (ig,), (igp,)
        mat = jnp.conj(aqsm_b)[None, :] * aqsn_b[:, None]
        w = vcoul[:, None] * mat

        def per_iw(iw):
            wxv = wxb[iw]
            wdiff = wxv - wtilde
            wdiffr = (wdiff * jnp.conj(wdiff)).real
            delw = wtilde * jnp.conj(wdiff) / jnp.maximum(wdiffr, 1e-30)
            delwr = (delw * jnp.conj(delw)).real
            cond1 = (wdiffr > LIMITTWO) & (delwr < LIMITONE)
            cond2 = (~cond1) & (delwr > TOL_ZERO)
            sch = jnp.where(cond1, delw * eps, 0.0)
            cden1 = wxv * wxv - wtilde2
            ssx1 = omega2 / jnp.where(cden1 == 0, 1.0, cden1)
            cden2 = 4.0 * wtilde2 * (delw + 0.5)
            ssx2 = -omega2 * delw / jnp.where(cden2 == 0, 1.0, cden2)
            ssx = jnp.where(cond1, ssx1, jnp.where(cond2, ssx2, 0.0))
            return jnp.sum(w * sch), jnp.sum(w * ssx)

        da, dx = jax.vmap(per_iw)(jnp.arange(nw))
        return (ach + da, asx + dx), None

    init = (jnp.zeros(nw, jnp.complex64), jnp.zeros(nw, jnp.complex64))
    (ach, asx), _ = jax.lax.scan(
        per_band, init, (wx.T, aqsn.T, aqsm.T))
    return ach, asx
