"""GPP optimization journey, steps v0–v5 (pure JAX, planar f32).

Each step mirrors the paper's optimization (Sec. III) translated to the TPU
execution model (DESIGN.md §2 has the full mapping table):

  v0  baseline: collapse(3)-style evaluation, complex divides (2 real divides
      per complex division), abs()/sqrt in branch conditions, 3-way branch,
      streaming over igp (no reuse of aqsn across igp — the "little to no
      cache reuse" baseline).
  v1  divides -> reciprocals: one rcp per |.|^2 then multiplies.
  v2  3-way branch -> zero-init + 2 masked selects (branchless; on the TPU
      VPU this is the mandatory form — measured as select-count in HLO).
  v3  abs()/sqrt in conditions -> squared-magnitude compares.
  v4  raise arithmetic intensity: serialize *band* (scan over band blocks),
      keeping the (ig,igp) arrays hot across band iterations.
  v5  hoist the iw loop / share subexpressions: mat, wtilde2, omega2 computed
      once per (ig,igp[,band]) instead of per iw; reduction restructured.

v6–v8 (cache blocking / layout swap / block-size tuning) live in the Pallas
kernel: see pallas_gpp.py and ops.py.

All variants take the planar-f32 input dict (problem.make_inputs) and return
(ach (nw,) complex64, asx (nw,) complex64).
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.gpp.problem import LIMITONE, LIMITTWO, TOL_ZERO

SQRT_LIMITONE = LIMITONE ** 0.5
SQRT_LIMITTWO = LIMITTWO ** 0.5


def _f32(inputs: Dict) -> Dict:
    return {k: jnp.asarray(v, jnp.float32) for k, v in inputs.items()}


def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


# ---------------------------------------------------------------------------
# the branch math, parameterized by the optimization step
# ---------------------------------------------------------------------------

def _body(wxv, wt_re, wt_im, eps_re, eps_im, wt2_re, wt2_im, om2_re, om2_im,
          *, use_div: bool, use_abs: bool, three_way: bool):
    """Everything per (iw, band) scalar wxv against the (ig,igp) planes.
    Returns (sch_re, sch_im, ssx_re, ssx_im)."""
    wd_re = wxv - wt_re
    wd_im = -wt_im
    wdiffr = wd_re * wd_re + wd_im * wd_im

    if use_div:
        # v0: two real divides per complex division (the long-latency path)
        delw_re = (wt_re * wd_re + wt_im * wd_im) / wdiffr
        delw_im = (wt_im * wd_re - wt_re * wd_im) / wdiffr
    else:
        # v1: one reciprocal, then multiplies
        rden = 1.0 / wdiffr
        delw_re = (wt_re * wd_re + wt_im * wd_im) * rden
        delw_im = (wt_im * wd_re - wt_re * wd_im) * rden

    delwr = delw_re * delw_re + delw_im * delw_im

    if use_abs:
        # v0–v2: abs() (sqrt) in the condition evaluation
        cond1 = (jnp.sqrt(wdiffr) > SQRT_LIMITTWO) & \
                (jnp.sqrt(delwr) < SQRT_LIMITONE)
    else:
        # v3: squared-magnitude compares
        cond1 = (wdiffr > LIMITTWO) & (delwr < LIMITONE)
    cond2 = delwr > TOL_ZERO

    # branch 1
    sch1_re, sch1_im = _cmul(delw_re, delw_im, eps_re, eps_im)
    cden1_re = wxv * wxv - wt2_re
    cden1_im = -wt2_im
    c1sq = cden1_re * cden1_re + cden1_im * cden1_im
    if use_div:
        ssx1_re = (om2_re * cden1_re + om2_im * cden1_im) / c1sq
        ssx1_im = (om2_im * cden1_re - om2_re * cden1_im) / c1sq
    else:
        r1 = 1.0 / c1sq
        ssx1_re = (om2_re * cden1_re + om2_im * cden1_im) * r1
        ssx1_im = (om2_im * cden1_re - om2_re * cden1_im) * r1

    # branch 2
    cd2_re, cd2_im = _cmul(wt2_re, wt2_im, 4.0 * (delw_re + 0.5), 4.0 * delw_im)
    c2sq = cd2_re * cd2_re + cd2_im * cd2_im
    c2sq = jnp.where(c2sq == 0, 1.0, c2sq)
    n2_re, n2_im = _cmul(-om2_re, -om2_im, delw_re, delw_im)
    if use_div:
        ssx2_re = (n2_re * cd2_re + n2_im * cd2_im) / c2sq
        ssx2_im = (n2_im * cd2_re - n2_re * cd2_im) / c2sq
    else:
        r2 = 1.0 / c2sq
        ssx2_re = (n2_re * cd2_re + n2_im * cd2_im) * r2
        ssx2_im = (n2_im * cd2_re - n2_re * cd2_im) * r2

    if three_way:
        # v0/v1: nested 3-way selection (mirrors the if/elif/else chain)
        sch_re = jnp.where(cond1, sch1_re, jnp.where(cond2, 0.0, 0.0))
        sch_im = jnp.where(cond1, sch1_im, jnp.where(cond2, 0.0, 0.0))
        ssx_re = jnp.where(cond1, ssx1_re, jnp.where(cond2, ssx2_re, 0.0))
        ssx_im = jnp.where(cond1, ssx1_im, jnp.where(cond2, ssx2_im, 0.0))
    else:
        # v2: zero-init + two masked fills (the paper's "After" block)
        m2 = (~cond1) & cond2
        sch_re = jnp.where(cond1, sch1_re, 0.0)
        sch_im = jnp.where(cond1, sch1_im, 0.0)
        ssx_re = jnp.where(cond1, ssx1_re, jnp.where(m2, ssx2_re, 0.0))
        ssx_im = jnp.where(cond1, ssx1_im, jnp.where(m2, ssx2_im, 0.0))
    return sch_re, sch_im, ssx_re, ssx_im


# ---------------------------------------------------------------------------
# v0–v3: stream over igp (collapse(3) analogue), differ in instruction mix
# ---------------------------------------------------------------------------

def _gpp_igp_stream(inputs: Dict, *, use_div, use_abs, three_way,
                    hoist: bool = False) -> Tuple[jax.Array, jax.Array]:
    f = _f32(inputs)
    nw, nbands = f["wx"].shape
    vcoul = f["vcoul"]

    def per_igp(carry, igp_slices):
        ach_re, ach_im, asx_re, asx_im = carry
        wt_re, wt_im, eps_re, eps_im, am_re, am_im = igp_slices  # (ig,),(band,)
        wt2_re, wt2_im = _cmul(wt_re, wt_im, wt_re, wt_im)
        om2_re, om2_im = _cmul(wt2_re, wt2_im, eps_re, eps_im)

        # mat(ig, band) = conj(aqsm[igp,band]) * aqsn[ig,band]
        mat_re, mat_im = _cmul(f["aqsn_re"], f["aqsn_im"],
                               am_re[None, :], -am_im[None, :])
        wre = vcoul[:, None] * mat_re
        wim = vcoul[:, None] * mat_im

        for iw in range(nw):
            wxv = f["wx"][iw]                              # (band,)
            sch_re, sch_im, ssx_re, ssx_im = _body(
                wxv[None, :], wt_re[:, None], wt_im[:, None],
                eps_re[:, None], eps_im[:, None],
                wt2_re[:, None], wt2_im[:, None],
                om2_re[:, None], om2_im[:, None],
                use_div=use_div, use_abs=use_abs, three_way=three_way)
            cr, ci = _cmul(wre, wim, sch_re, sch_im)
            ach_re = ach_re.at[iw].add(jnp.sum(cr))
            ach_im = ach_im.at[iw].add(jnp.sum(ci))
            cr, ci = _cmul(wre, wim, ssx_re, ssx_im)
            asx_re = asx_re.at[iw].add(jnp.sum(cr))
            asx_im = asx_im.at[iw].add(jnp.sum(ci))
        return (ach_re, ach_im, asx_re, asx_im), None

    z = jnp.zeros(nw, jnp.float32)
    slices = (f["wtilde_re"].T, f["wtilde_im"].T, f["eps_re"].T,
              f["eps_im"].T, f["aqsm_re"], f["aqsm_im"])
    (ar, ai, xr, xi), _ = jax.lax.scan(per_igp, (z, z, z, z), slices)
    return ar + 1j * ai, xr + 1j * xi


# ---------------------------------------------------------------------------
# v4/v5: serialize band (scan over band blocks), (ig,igp) planes held hot
# ---------------------------------------------------------------------------

def _gpp_band_blocked(inputs: Dict, *, band_block: int = 32,
                      hoist_iw: bool = True) -> Tuple[jax.Array, jax.Array]:
    f = _f32(inputs)
    nw, nbands = f["wx"].shape
    band_block = min(band_block, nbands)
    while nbands % band_block:
        band_block //= 2
    nblk = nbands // band_block
    vcoul = f["vcoul"]

    wt_re, wt_im = f["wtilde_re"], f["wtilde_im"]          # (ig, igp)
    eps_re, eps_im = f["eps_re"], f["eps_im"]
    # v5: hoist band/iw-invariant subexpressions out of all loops
    wt2_re, wt2_im = _cmul(wt_re, wt_im, wt_re, wt_im)
    om2_re, om2_im = _cmul(wt2_re, wt2_im, eps_re, eps_im)

    def per_block(carry, blk):
        ach_re, ach_im, asx_re, asx_im = carry
        an_re, an_im, am_re, am_im, wxb = blk
        # an: (bb, ig); am: (bb, igp); wx: (nw, bb)

        def per_band(carry2, b):
            ach_re, ach_im, asx_re, asx_im = carry2

            def make_mat():
                mr, mi = _cmul(an_re[b][:, None], an_im[b][:, None],
                               am_re[b][None, :], -am_im[b][None, :])
                return vcoul[:, None] * mr, vcoul[:, None] * mi

            if hoist_iw:
                # v5: mat(ig,igp) computed once, reused across iw
                wre, wim = make_mat()
            for iw in range(nw):
                if not hoist_iw:
                    # v4: mat recomputed per iw (pre-hoist redundancy)
                    wre, wim = make_mat()
                sch_re, sch_im, ssx_re, ssx_im = _body(
                    wxb[iw, b], wt_re, wt_im, eps_re, eps_im,
                    wt2_re, wt2_im, om2_re, om2_im,
                    use_div=False, use_abs=False, three_way=False)
                cr, ci = _cmul(wre, wim, sch_re, sch_im)
                ach_re = ach_re.at[iw].add(jnp.sum(cr))
                ach_im = ach_im.at[iw].add(jnp.sum(ci))
                cr, ci = _cmul(wre, wim, ssx_re, ssx_im)
                asx_re = asx_re.at[iw].add(jnp.sum(cr))
                asx_im = asx_im.at[iw].add(jnp.sum(ci))
            return (ach_re, ach_im, asx_re, asx_im), None

        carry, _ = jax.lax.scan(per_band, carry, jnp.arange(band_block))
        return carry, None

    z = jnp.zeros(nw, jnp.float32)
    blocks = (
        f["aqsn_re"].T.reshape(nblk, band_block, -1),
        f["aqsn_im"].T.reshape(nblk, band_block, -1),
        f["aqsm_re"].T.reshape(nblk, band_block, -1),
        f["aqsm_im"].T.reshape(nblk, band_block, -1),
        f["wx"].reshape(nw, nblk, band_block).transpose(1, 0, 2),
    )
    (ar, ai, xr, xi), _ = jax.lax.scan(per_block, (z, z, z, z), blocks)
    return ar + 1j * ai, xr + 1j * xi


# ---------------------------------------------------------------------------
# public variant table
# ---------------------------------------------------------------------------

v0 = functools.partial(_gpp_igp_stream, use_div=True, use_abs=True,
                       three_way=True)
v1 = functools.partial(_gpp_igp_stream, use_div=False, use_abs=True,
                       three_way=True)
v2 = functools.partial(_gpp_igp_stream, use_div=False, use_abs=True,
                       three_way=False)
v3 = functools.partial(_gpp_igp_stream, use_div=False, use_abs=False,
                       three_way=False)
v4 = functools.partial(_gpp_band_blocked, hoist_iw=False)
v5 = functools.partial(_gpp_band_blocked, hoist_iw=True)

VARIANTS = {"v0": v0, "v1": v1, "v2": v2, "v3": v3, "v4": v4, "v5": v5}
