"""Paged decode attention: block-table K/V gather for the serve pool
(serve/kvcache.py), registered as the `paged_decode` kernel family."""
