"""`paged_decode` family registration for the unified kernel registry.

The paged serving cache (serve/kvcache.py) stores K/V as fixed-size pages
addressed through per-request block tables, so its decode attention is a
GATHER-then-contract problem — a different roofline from the dense-cache
`flash` family: the K/V traffic is the whole visited context again every
step, fetched page-by-page through the table, and the VMEM working set is
the gathered block, not the context. This descriptor gives that route the
same journey the other families got:

  * `PagedKey` — (b, h, kvh, page, npt, hd): the pool page size and the
    block-table length are part of the problem, not the config;
  * `PagedBlockConfig(pages_per_block)` — how many pages each online-
    softmax step gathers: bigger blocks amortize per-step overhead,
    smaller blocks shrink the gather buffer (the tuner's tradeoff);
  * versions ("ref", "gather", "int8", "verify"): full-gather oracle,
    blockwise bf16, blockwise int8 with per-page dequant scales (the
    quantized route the serve pool's `kv_dtype="int8"` feeds), and the
    decode-specialized multi-query verify route (q_len = k+1 ≪ S for
    speculative decoding — the pool-dtype-adaptive loader serves float
    and int8 pools alike). A rank-4 q (B, qlen, H, Hd) selects the
    multi-query problem; every version handles both ranks so the
    auditor's census covers the cross product;
  * `gather_buffer_bytes` — the auditor hook behind the KV001 rule: a
    paged kernel whose VMEM model forgets the gather buffers would pass
    VMEM001 while overflowing VMEM at runtime, so `config_vmem_bytes`
    here includes them and KV001 cross-checks that it does.

Model assumptions: K/V bytes re-fetched per decode step (no residency
across steps — the cache outgrows VMEM by construction), f32 compute on
bf16/int8 operands, SCAN_OVERHEAD_S per gather block (the loop is an XLA
scan, not a Pallas grid).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.hw import TPU_V5E
from repro.core.vpu_model import PASS_RATE, SCAN_OVERHEAD_S
from repro.kernels import api
from repro.kernels.paged import paged as paged_lib

PPB_MENU = (1, 2, 4, 8, 16)
SOFTMAX_PASSES = 12.0          # exp + max/sum/online-rescale per score
BF16 = 2
F32 = 4


@dataclasses.dataclass(frozen=True)
class PagedKey:
    """ProblemKey for one paged decode layer: B rows of `qlen` query
    tokens each (1 for plain decode, k+1 for the speculative verify)
    attending over npt pages of `page` K/V lines from the pool."""
    b: int
    h: int
    kvh: int
    page: int
    npt: int
    hd: int
    qlen: int = 1
    name: str = "paged_decode"

    def key_dims(self) -> str:
        base = (f"{self.b}x{self.h}x{self.kvh}x{self.page}"
                f"x{self.npt}x{self.hd}")
        # qlen==1 keeps the historical 6-part form so existing tune-cache
        # entries keep resolving; multi-query keys append a 7th part
        return base if self.qlen == 1 else f"{base}x{self.qlen}"


def _div_clamp(blk: int, n: int) -> int:
    """Largest block <= blk that exactly tiles n (flash's rule: a plain
    min() on a non-dividing count would drop tail pages silently)."""
    blk = min(blk, n)
    while n % blk:
        blk -= 1
    return blk


@dataclasses.dataclass(frozen=True)
class PagedBlockConfig:
    name: str = "paged"
    pages_per_block: int = 8

    def clamped(self, key: PagedKey) -> "PagedBlockConfig":
        return dataclasses.replace(
            self, pages_per_block=_div_clamp(self.pages_per_block, key.npt))


def _gather_bytes(cfg: PagedBlockConfig, key: PagedKey,
                  itemsize: int = BF16) -> int:
    """Double-buffered K+V gather block: the bytes KV001 exists for."""
    return 2 * key.b * cfg.pages_per_block * key.page * key.kvh \
        * key.hd * itemsize * 2


class PagedDecodeKernel(api.Kernel):
    name = "paged_decode"
    versions = ("ref", "gather", "int8", "verify")
    default_version = "gather"
    tunable = ("gather", "int8", "verify")

    def problem_key(self, q, kpool, vpool, block_table, cache_len,
                    **kwargs) -> PagedKey:
        if q.ndim == 4:
            b, qlen, h, hd = q.shape
        else:
            b, h, hd = q.shape
            qlen = 1
        _, page, kvh, _ = kpool.shape
        return PagedKey(b=b, h=h, kvh=kvh, page=page,
                        npt=block_table.shape[1], hd=hd, qlen=qlen)

    def config_space(self, key: PagedKey, version: str
                     ) -> List[PagedBlockConfig]:
        if version == "ref":
            return []
        out = []
        for ppb in PPB_MENU:
            if ppb > key.npt or key.npt % ppb:
                continue
            cfg = PagedBlockConfig("tune", ppb)
            if self.config_vmem_bytes(cfg, key) <= TPU_V5E.vmem_bytes:
                out.append(cfg)
        return out

    def clamp(self, config: PagedBlockConfig, key: PagedKey
              ) -> PagedBlockConfig:
        return config.clamped(key)

    def static_config(self, key: PagedKey, version: str
                      ) -> Optional[PagedBlockConfig]:
        return PagedBlockConfig().clamped(key)

    def tie_break(self, config: PagedBlockConfig) -> Tuple:
        # bigger blocks first: fewer scan steps at equal modeled time
        return (-config.pages_per_block,)

    def finalize_config(self, config: PagedBlockConfig, version: str
                        ) -> PagedBlockConfig:
        return dataclasses.replace(config, name=version)

    def model_step_s(self, key: PagedKey, config: PagedBlockConfig,
                     version: str) -> float:
        cfg = config.clamped(key)
        ctx = key.npt * key.page                     # gathered context lines
        kv_item = 1 if version == "int8" else BF16
        # qk^T + pv, 2 flops each, per query token (qlen > 1: the verify
        # route re-uses each gathered block for all qlen queries, so the
        # K/V traffic term below does NOT scale with qlen — that is the
        # whole point of batching the verify into one pass)
        flops = 4.0 * key.b * key.qlen * key.h * ctx * key.hd
        mxu_s = flops / TPU_V5E.mxu_flops
        vpu_s = key.b * key.qlen * key.h * ctx * SOFTMAX_PASSES / PASS_RATE
        n_blocks = key.npt // cfg.pages_per_block
        overhead_s = n_blocks * SCAN_OVERHEAD_S
        bytes_ = (2 * key.b * ctx * key.kvh * key.hd * kv_item   # k + v
                  + 2 * key.b * key.qlen * key.h * key.hd * BF16)  # q, out
        return max(mxu_s + vpu_s + overhead_s, bytes_ / TPU_V5E.hbm_bw)

    def measure_ok(self, key: PagedKey) -> bool:
        return (key.b * key.qlen * key.h * key.npt * key.page * key.hd
                <= 1 << 20)

    def make_example(self, key: PagedKey, seed: int = 0
                     ) -> Tuple[tuple, dict]:
        # pool sized exactly b*npt pages with a disjoint identity table:
        # census HBM traffic == the traffic one decode step actually
        # gathers, so the MODEL001 drift check compares like with like
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        n_pages = key.b * key.npt
        qshape = ((key.b, key.h, key.hd) if key.qlen == 1
                  else (key.b, key.qlen, key.h, key.hd))
        q = jax.random.normal(ks[0], qshape, jnp.bfloat16)
        kpool = jax.random.normal(
            ks[1], (n_pages, key.page, key.kvh, key.hd), jnp.bfloat16)
        vpool = jax.random.normal(
            ks[2], (n_pages, key.page, key.kvh, key.hd), jnp.bfloat16)
        table = jnp.arange(n_pages, dtype=jnp.int32).reshape(key.b, key.npt)
        ctx = key.npt * key.page
        cache_len = (ctx - (jnp.arange(key.b, dtype=jnp.int32)
                            % max(ctx - 1, 1)))
        # every query position must exist: cache_len counts the qlen
        # candidate lines already written to the pool
        cache_len = jnp.maximum(cache_len, key.qlen)
        return (q, kpool, vpool, table, cache_len), {}

    def config_from_json(self, d: Dict) -> PagedBlockConfig:
        return PagedBlockConfig(**d)

    # -- static-analysis hooks (repro.analyze) -----------------------------
    def canonical_keys(self) -> List[PagedKey]:
        return [PagedKey(b=2, h=2, kvh=2, page=16, npt=4, hd=32),
                PagedKey(b=2, h=2, kvh=2, page=16, npt=4, hd=32, qlen=4)]

    def key_from_dims(self, dims: str) -> PagedKey:
        parts = [int(d) for d in dims.split("x")]
        b, h, kvh, page, npt, hd = parts[:6]
        qlen = parts[6] if len(parts) > 6 else 1
        return PagedKey(b=b, h=h, kvh=kvh, page=page, npt=npt, hd=hd,
                        qlen=qlen)

    def config_vmem_bytes(self, config: PagedBlockConfig, key: PagedKey
                          ) -> int:
        span = config.pages_per_block * key.page
        qn = key.qlen
        resident = (key.b * qn * key.h * key.hd * F32 * 2  # q (f32), acc
                    + 2 * key.b * qn * key.h * F32         # l, m stats
                    + key.b * qn * key.h * span * F32)     # score block
        return self.gather_buffer_bytes(config, key) + resident

    def gather_buffer_bytes(self, config: PagedBlockConfig, key: PagedKey
                            ) -> int:
        return _gather_bytes(config, key)

    def config_divides(self, config: PagedBlockConfig, key: PagedKey
                       ) -> List[str]:
        ppb = config.pages_per_block
        if ppb <= 0 or key.npt % ppb:
            return [f"npt={key.npt} not tiled by pages_per_block {ppb}"]
        return []

    def allowed_float_dtypes(self, version: str) -> frozenset:
        # bf16 operands, f32 scores/stats/accumulator (all versions; the
        # int8 pool itself is integer, outside the float-leak check)
        return frozenset({"bfloat16", "float32"})

    def run(self, q, kpool, vpool, block_table, cache_len, *, version: str,
            config: Optional[PagedBlockConfig], interpret: Optional[bool],
            kscale=None, vscale=None):
        """q: (B,H,Hd) single-token decode or (B,Q,H,Hd) multi-query
        verify; pools: (P,page,KvH,Hd); block_table: (B,npt) int32;
        cache_len: (B,) -> out matching q's rank. All versions are pure
        JAX (`interpret` accepted for protocol symmetry, nothing to
        toggle) and all handle both q ranks — the census traces every
        (canonical key, version) pair, including the qlen=4 key. The int8
        version takes per-page `kscale`/`vscale` (serve pool layout);
        given a float pool it quantizes on the fly — the self-contained
        form the auditor traces and tests compare against."""
        if version == "ref":
            return paged_lib.paged_decode_ref(q, kpool, vpool, block_table,
                                              cache_len)
        key = self.problem_key(q, kpool, vpool, block_table, cache_len)
        cfg = (config or PagedBlockConfig()).clamped(key)
        if version == "verify":
            if not jnp.issubdtype(kpool.dtype, jnp.floating) \
                    and (kscale is None or vscale is None):
                raise ValueError("paged_decode verify needs kscale/vscale "
                                 "for an int8 pool")
            return paged_lib.paged_decode_verify(
                q, kpool, vpool, block_table, cache_len,
                pages_per_block=cfg.pages_per_block, kscale=kscale,
                vscale=vscale)
        if version == "gather":
            if q.ndim == 4:
                # the single-token gather loop has no query axis; route
                # multi-query problems through the verify scan (same
                # blockwise loader, per-query causal mask)
                return paged_lib.paged_decode_verify(
                    q, kpool, vpool, block_table, cache_len,
                    pages_per_block=cfg.pages_per_block)
            return paged_lib.paged_decode_gather(
                q, kpool, vpool, block_table, cache_len,
                pages_per_block=cfg.pages_per_block)
        if jnp.issubdtype(kpool.dtype, jnp.floating):
            kpool, kscale = paged_lib.quantize_pool(kpool)
            vpool, vscale = paged_lib.quantize_pool(vpool)
        elif kscale is None or vscale is None:
            raise ValueError("paged_decode int8 needs kscale/vscale for an "
                             "int8 pool")
        if q.ndim == 4:
            return paged_lib.paged_decode_verify(
                q, kpool, vpool, block_table, cache_len,
                pages_per_block=cfg.pages_per_block, kscale=kscale,
                vscale=vscale)
        return paged_lib.paged_decode_int8(
            q, kpool, vpool, block_table, cache_len, kscale, vscale,
            pages_per_block=cfg.pages_per_block)


KERNEL = api.register(PagedDecodeKernel())
