"""Paged decode-attention implementations (pure JAX, registry-routed).

The serving engine's page pool (serve/kvcache.py) stores K/V lines as
fixed-size pages; a request's context is the concatenation of the pages
its block table names. Decode attention over that layout must GATHER
before it can contract — these implementations are the kernel side of
that contract, one layer at a time:

  q            (B, H, Hd)            one decode token per row, OR
               (B, Q, H, Hd)         Q = k+1 verify queries per row
                                     (speculative decoding; query j sits
                                     at position cache_len - Q + j)
  kpool/vpool  (P, page, KvH, Hd)    the page pool (bf16; int8 + scales
                                     for the quantized route)
  block_table  (B, npt) int32        page ids per row, in context order
                                     (entries past the valid length may
                                     be any in-range id — masking wins)
  cache_len    (B,) int32            valid context tokens per row

Four versions, reference -> fastest (kernel_def.py registers them):

  * `paged_decode_ref`    — gather the WHOLE table, then run the exact
    `models.attention` math (rank-polymorphic over q): the oracle the
    blockwise versions are tested against.
  * `paged_decode_gather` — lax.scan over blocks of `pages_per_block`
    pages with an online-softmax accumulator (m, l, acc in f32): only
    one gathered block is live at a time, so the VMEM working set is
    the block, not the context (the tuner's knob).
  * `paged_decode_int8`   — the gather loop over an int8 pool: each
    gathered page dequantizes with its per-page scale
    (serve.kvcache.quantize_page granule) before the contraction.
  * `paged_decode_verify` — the decode-specialized multi-query route for
    speculative decoding: q_len = k+1 queries share every gathered block
    (one context fetch verifies all candidates), with a per-query causal
    mask; the loader adapts to the pool dtype so one version covers the
    bf16 and int8 cache routes.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (NEG_INF, decode_attention,
                                    decode_attention_multi)
from repro.models.layers import PARAM_DTYPE

INT8_MAX = 127.0


def gather_pages(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """(P,page,KvH,Hd)[(B,npt)] -> (B, npt*page, KvH, Hd), context order."""
    b, npt = block_table.shape
    _, page, kvh, hd = pool.shape
    flat = jnp.take(pool, block_table.reshape(-1), axis=0)
    return flat.reshape(b, npt * page, kvh, hd)


def quantize_pool(pool: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Vectorized serve.kvcache.quantize_page over a whole pool: one
    symmetric f32 scale per page. Returns (int8 pool, (P,) scales)."""
    f = pool.astype(jnp.float32)
    amax = jnp.max(jnp.abs(f), axis=(1, 2, 3))
    scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(f / scale[:, None, None, None]),
                 -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def paged_decode_ref(q, kpool, vpool, block_table, cache_len) -> jax.Array:
    """Full-gather oracle: materialize the context, defer to the serving
    path's own decode attention (identical masking and accumulation).
    q rank selects the math: (B,H,Hd) single-token decode, (B,Q,H,Hd)
    multi-query verify (speculative decoding — query j sits at absolute
    position cache_len - Q + j with a per-query causal mask)."""
    k = gather_pages(kpool, block_table)
    v = gather_pages(vpool, block_table)
    if q.ndim == 4:
        return decode_attention_multi(q, k, v, cache_len)
    return decode_attention(q[:, None], k, v, cache_len)[:, 0]


def _online_block_scan(q, block_table, cache_len, load_block, *,
                       pages_per_block: int, page: int, kvh: int):
    """Shared online-softmax loop: `load_block(ids) -> (kb, vb)` yields
    one gathered (B, ppb*page, KvH, Hd) f32 block per step."""
    b, h, hd = q.shape
    npt = block_table.shape[1]
    n_blocks = npt // pages_per_block
    span = pages_per_block * page
    g = h // kvh
    scale = hd ** -0.5
    qr = q.reshape(b, kvh, g, hd).astype(jnp.float32)

    def body(carry, bi):
        m, l, acc = carry
        ids = jax.lax.dynamic_slice_in_dim(
            block_table, bi * pages_per_block, pages_per_block, axis=1)
        kb, vb = load_block(ids)
        pos = bi * span + jnp.arange(span)
        valid = pos[None, :] < cache_len[:, None]                # (B, span)
        s = jnp.einsum("bkgd,bskd->bkgs", qr, kb,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))              # (B,KvH,G)
        e = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(e, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgs,bskd->bkgd", e, vb, preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, kvh, g), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g), jnp.float32),
            jnp.zeros((b, kvh, g, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_blocks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, h, hd).astype(PARAM_DTYPE)


def paged_decode_gather(q, kpool, vpool, block_table, cache_len, *,
                        pages_per_block: int) -> jax.Array:
    """Blockwise gather + online softmax over a full-precision pool."""
    _, page, kvh, hd = kpool.shape

    def load_block(ids):
        kb = gather_pages(kpool, ids).astype(jnp.float32)
        vb = gather_pages(vpool, ids).astype(jnp.float32)
        return kb, vb

    return _online_block_scan(q, block_table, cache_len, load_block,
                              pages_per_block=pages_per_block, page=page,
                              kvh=kvh)


def _online_block_scan_multi(q, block_table, cache_len, load_block, *,
                             pages_per_block: int, page: int, kvh: int):
    """Multi-query twin of _online_block_scan for the verify route
    (speculative decoding): q (B,Q,H,Hd), the Q candidate tokens' rows
    are already in the pool and counted by cache_len, so query j sits at
    absolute position cache_len - Q + j and its per-query causal mask is
    pos <= q_pos — the online-softmax state just grows a Q axis."""
    b, qn, h, hd = q.shape
    npt = block_table.shape[1]
    n_blocks = npt // pages_per_block
    span = pages_per_block * page
    g = h // kvh
    scale = hd ** -0.5
    qr = q.reshape(b, qn, kvh, g, hd).transpose(0, 2, 3, 1, 4)
    qr = qr.astype(jnp.float32)                              # (B,KvH,G,Q,Hd)
    q_pos = cache_len[:, None] - qn + jnp.arange(qn)[None, :]      # (B,Q)

    def body(carry, bi):
        m, l, acc = carry
        ids = jax.lax.dynamic_slice_in_dim(
            block_table, bi * pages_per_block, pages_per_block, axis=1)
        kb, vb = load_block(ids)
        pos = bi * span + jnp.arange(span)
        valid = pos[None, None, :] <= q_pos[:, :, None]      # (B,Q,span)
        s = jnp.einsum("bkgqd,bskd->bkgqs", qr, kb,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))          # (B,KvH,G,Q)
        e = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(e, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", e, vb, preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, kvh, g, qn), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, qn), jnp.float32),
            jnp.zeros((b, kvh, g, qn, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_blocks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, qn, h, hd)
    return out.astype(PARAM_DTYPE)


def paged_decode_verify(q, kpool, vpool, block_table, cache_len, *,
                        pages_per_block: int, kscale=None,
                        vscale=None) -> jax.Array:
    """The decode-specialized verify route: q_len = k+1 ≪ S queries per
    row against the page pool, blockwise with online softmax. The loader
    adapts to the pool dtype — float pools load as-is, int8 pools
    dequantize per page with their scales (the serve pool's quantized
    layout) — so one version serves both cache routes. A rank-3 q is the
    q_len=1 degenerate case (identical math to paged_decode_gather)."""
    _, page, kvh, hd = kpool.shape
    if jnp.issubdtype(kpool.dtype, jnp.floating):
        def load_block(ids):
            return (gather_pages(kpool, ids).astype(jnp.float32),
                    gather_pages(vpool, ids).astype(jnp.float32))
    else:
        if kscale is None or vscale is None:
            raise ValueError("paged_decode verify needs kscale/vscale for "
                             "an int8 pool")

        def load_block(ids):
            b, ppb = ids.shape

            def deq(pool, scales):
                blk = jnp.take(pool, ids.reshape(-1), axis=0)
                s = jnp.take(scales, ids.reshape(-1), axis=0)
                f = blk.astype(jnp.float32) * s[:, None, None, None]
                return f.reshape(b, ppb * page, kvh, hd)

            return deq(kpool, kscale), deq(vpool, vscale)

    if q.ndim == 3:
        out = _online_block_scan_multi(
            q[:, None], block_table, cache_len, load_block,
            pages_per_block=pages_per_block, page=page, kvh=kvh)
        return out[:, 0]
    return _online_block_scan_multi(q, block_table, cache_len, load_block,
                                    pages_per_block=pages_per_block,
                                    page=page, kvh=kvh)


def paged_decode_int8(q, kpool, vpool, block_table, cache_len,
                      kscale, vscale, *, pages_per_block: int) -> jax.Array:
    """Blockwise gather over an int8 pool: per-page dequantization inside
    the loop, so only one block ever exists at full precision."""
    _, page, kvh, hd = kpool.shape

    def load_block(ids):
        b, ppb = ids.shape

        def deq(pool, scales):
            blk = jnp.take(pool, ids.reshape(-1), axis=0)   # (B*ppb,pg,kvh,hd)
            s = jnp.take(scales, ids.reshape(-1), axis=0)   # (B*ppb,)
            f = blk.astype(jnp.float32) * s[:, None, None, None]
            return f.reshape(b, ppb * page, kvh, hd)

        return deq(kpool, kscale), deq(vpool, vscale)

    return _online_block_scan(q, block_table, cache_len, load_block,
                              pages_per_block=pages_per_block, page=page,
                              kvh=kvh)
