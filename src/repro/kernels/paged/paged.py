"""Paged decode-attention implementations (pure JAX, registry-routed).

The serving engine's page pool (serve/kvcache.py) stores K/V lines as
fixed-size pages; a request's context is the concatenation of the pages
its block table names. Decode attention over that layout must GATHER
before it can contract — these implementations are the kernel side of
that contract, one layer at a time:

  q            (B, H, Hd)            one decode token per row
  kpool/vpool  (P, page, KvH, Hd)    the page pool (bf16; int8 + scales
                                     for the quantized route)
  block_table  (B, npt) int32        page ids per row, in context order
                                     (entries past the valid length may
                                     be any in-range id — masking wins)
  cache_len    (B,) int32            valid context tokens per row

Three versions, reference -> fastest (kernel_def.py registers them):

  * `paged_decode_ref`    — gather the WHOLE table, then run the exact
    `models.attention.decode_attention` math: the oracle the blockwise
    versions are tested against.
  * `paged_decode_gather` — lax.scan over blocks of `pages_per_block`
    pages with an online-softmax accumulator (m, l, acc in f32): only
    one gathered block is live at a time, so the VMEM working set is
    the block, not the context (the tuner's knob).
  * `paged_decode_int8`   — the gather loop over an int8 pool: each
    gathered page dequantizes with its per-page scale
    (serve.kvcache.quantize_page granule) before the contraction.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import NEG_INF, decode_attention
from repro.models.layers import PARAM_DTYPE

INT8_MAX = 127.0


def gather_pages(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """(P,page,KvH,Hd)[(B,npt)] -> (B, npt*page, KvH, Hd), context order."""
    b, npt = block_table.shape
    _, page, kvh, hd = pool.shape
    flat = jnp.take(pool, block_table.reshape(-1), axis=0)
    return flat.reshape(b, npt * page, kvh, hd)


def quantize_pool(pool: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Vectorized serve.kvcache.quantize_page over a whole pool: one
    symmetric f32 scale per page. Returns (int8 pool, (P,) scales)."""
    f = pool.astype(jnp.float32)
    amax = jnp.max(jnp.abs(f), axis=(1, 2, 3))
    scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(f / scale[:, None, None, None]),
                 -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def paged_decode_ref(q, kpool, vpool, block_table, cache_len) -> jax.Array:
    """Full-gather oracle: materialize the context, defer to the serving
    path's own decode_attention (identical masking and accumulation)."""
    k = gather_pages(kpool, block_table)
    v = gather_pages(vpool, block_table)
    return decode_attention(q[:, None], k, v, cache_len)[:, 0]


def _online_block_scan(q, block_table, cache_len, load_block, *,
                       pages_per_block: int, page: int, kvh: int):
    """Shared online-softmax loop: `load_block(ids) -> (kb, vb)` yields
    one gathered (B, ppb*page, KvH, Hd) f32 block per step."""
    b, h, hd = q.shape
    npt = block_table.shape[1]
    n_blocks = npt // pages_per_block
    span = pages_per_block * page
    g = h // kvh
    scale = hd ** -0.5
    qr = q.reshape(b, kvh, g, hd).astype(jnp.float32)

    def body(carry, bi):
        m, l, acc = carry
        ids = jax.lax.dynamic_slice_in_dim(
            block_table, bi * pages_per_block, pages_per_block, axis=1)
        kb, vb = load_block(ids)
        pos = bi * span + jnp.arange(span)
        valid = pos[None, :] < cache_len[:, None]                # (B, span)
        s = jnp.einsum("bkgd,bskd->bkgs", qr, kb,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))              # (B,KvH,G)
        e = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(e, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgs,bskd->bkgd", e, vb, preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, kvh, g), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g), jnp.float32),
            jnp.zeros((b, kvh, g, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_blocks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, h, hd).astype(PARAM_DTYPE)


def paged_decode_gather(q, kpool, vpool, block_table, cache_len, *,
                        pages_per_block: int) -> jax.Array:
    """Blockwise gather + online softmax over a full-precision pool."""
    _, page, kvh, hd = kpool.shape

    def load_block(ids):
        kb = gather_pages(kpool, ids).astype(jnp.float32)
        vb = gather_pages(vpool, ids).astype(jnp.float32)
        return kb, vb

    return _online_block_scan(q, block_table, cache_len, load_block,
                              pages_per_block=pages_per_block, page=page,
                              kvh=kvh)


def paged_decode_int8(q, kpool, vpool, block_table, cache_len,
                      kscale, vscale, *, pages_per_block: int) -> jax.Array:
    """Blockwise gather over an int8 pool: per-page dequantization inside
    the loop, so only one block ever exists at full precision."""
    _, page, kvh, hd = kpool.shape

    def load_block(ids):
        b, ppb = ids.shape

        def deq(pool, scales):
            blk = jnp.take(pool, ids.reshape(-1), axis=0)   # (B*ppb,pg,kvh,hd)
            s = jnp.take(scales, ids.reshape(-1), axis=0)   # (B*ppb,)
            f = blk.astype(jnp.float32) * s[:, None, None, None]
            return f.reshape(b, ppb * page, kvh, hd)

        return deq(kpool, kscale), deq(vpool, vscale)

    return _online_block_scan(q, block_table, cache_len, load_block,
                              pages_per_block=pages_per_block, page=page,
                              kvh=kvh)
