"""Selective-scan (ssm) family registration for the unified kernel registry.

The ssm Pallas kernel (`ssm_scan.py`) previously had no public op layer —
consumers reached into the module and hand-picked `blk_c`. This descriptor
registers three versions behind the same contract as models/mamba.ssm_scan
(`x, dt: (B,T,C); bmat/cmat: (B,T,N); a_log: (C,N); d: (C,); h0: (B,C,N)`):

  ref      — the sequential lax.scan oracle (models/mamba.ssm_scan)
  chunked  — the chunk-parallel MXU form (models/mamba.ssm_chunked)
  pallas   — the VMEM-resident-state Pallas kernel, channel-blocked

and exposes the channel block `blk_c` as the tunable config. The model
hook charges the real blk_c tradeoff: a bigger channel block means fewer
grid instances (less per-instance issue overhead and fewer total fori-loop
steps paying sequencing latency) but a larger VMEM slab — the tuner picks
the largest feasible block, per (B, T, C, N), instead of a frozen 128.

Census (per (t, c) element, documented approximation): exp(dt·a) over N
states ≈ 9N passes (exp is an 8-pass NR sequence), state update ≈ 2N+1,
y-reduction ≈ N+1 → ~12N+2 passes; lanes = N (the minor dim), so small
state sizes under-fill the 128-lane VREG equally for every candidate.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import backend
from repro.core.hw import TPU_V5E
from repro.core.vpu_model import GRID_OVERHEAD_S, PASS_RATE
from repro.kernels import api
from repro.kernels.ssm import ssm_scan as scan_lib

BLK_C_MENU = (4, 8, 16, 32, 64, 128, 256, 512)
LOOP_STEP_OVERHEAD_S = 0.02e-6     # per fori-loop iteration (sequencing)


@dataclasses.dataclass(frozen=True)
class SsmKey:
    b: int
    t: int
    c: int
    n: int
    name: str = "ssm"

    def key_dims(self) -> str:
        return f"{self.b}x{self.t}x{self.c}x{self.n}"


def _div_clamp(blk: int, c: int) -> int:
    """Largest block <= blk that exactly tiles c (the kernel asserts
    divisibility — a plain min() clamp would crash on e.g. c=130)."""
    blk = min(blk, c)
    while c % blk:
        blk -= 1
    return blk


@dataclasses.dataclass(frozen=True)
class SsmScanConfig:
    name: str = "ssm"
    blk_c: int = 128

    def clamped(self, key: SsmKey) -> "SsmScanConfig":
        return dataclasses.replace(self, blk_c=_div_clamp(self.blk_c, key.c))

    def vmem_bytes(self, key: SsmKey) -> int:
        """x/dt/y slabs (T, blk_c) + b/c mats (T, N), double-buffered,
        plus the resident state/params (blk_c, N)."""
        io = (3 * key.t * self.blk_c + 2 * key.t * key.n) * 4
        live = (3 * self.blk_c * key.n + self.blk_c) * 4    # h0/hT/a_log, d
        return 2 * io + live


class SsmKernel(api.Kernel):
    name = "ssm"
    versions = ("ref", "chunked", "pallas")
    default_version = "pallas"
    tunable = ("pallas",)

    def problem_key(self, x, dt, bmat, cmat, a_log, d, h0) -> SsmKey:
        b, t, c = x.shape
        return SsmKey(b=b, t=t, c=c, n=a_log.shape[1])

    def config_space(self, key: SsmKey, version: str) -> List[SsmScanConfig]:
        out = []
        for blk in BLK_C_MENU:
            if blk > key.c or key.c % blk:
                continue
            cfg = SsmScanConfig("tune", blk)
            if cfg.vmem_bytes(key) <= TPU_V5E.vmem_bytes:
                out.append(cfg)
        return out

    def clamp(self, config: SsmScanConfig, key: SsmKey) -> SsmScanConfig:
        return config.clamped(key)

    def static_config(self, key: SsmKey, version: str
                      ) -> Optional[SsmScanConfig]:
        return SsmScanConfig().clamped(key)        # the legacy blk_c=128

    def tie_break(self, config: SsmScanConfig) -> Tuple:
        return (-config.blk_c,)

    def finalize_config(self, config: SsmScanConfig, version: str
                        ) -> SsmScanConfig:
        return dataclasses.replace(config, name=version)

    def model_step_s(self, key: SsmKey, config: SsmScanConfig,
                     version: str) -> float:
        cfg = config.clamped(key)
        lane_fill = min(key.n, 128) / 128.0
        passes = key.b * key.t * key.c * (12.0 * key.n + 2.0)
        compute_s = passes / PASS_RATE / lane_fill
        instances = key.b * (key.c // cfg.blk_c)
        loop_s = instances * key.t * LOOP_STEP_OVERHEAD_S
        overhead_s = instances * GRID_OVERHEAD_S
        mem_s = scan_lib.kernel_hbm_bytes(key.b, key.t, key.c,
                                          key.n) / TPU_V5E.hbm_bw
        return max(compute_s + loop_s + overhead_s, mem_s)

    def measure_ok(self, key: SsmKey) -> bool:
        # the interpreted fori loop runs T python steps — tiny problems only
        return key.b * key.t * key.c * key.n <= 1 << 16

    def make_example(self, key: SsmKey, seed: int = 0) -> Tuple[tuple, dict]:
        ks = jax.random.split(jax.random.PRNGKey(seed), 7)
        x = jax.random.normal(ks[0], (key.b, key.t, key.c))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (key.b, key.t, key.c))
                             - 2)
        bm = jax.random.normal(ks[2], (key.b, key.t, key.n))
        cm = jax.random.normal(ks[3], (key.b, key.t, key.n))
        alog = jnp.log(jnp.arange(1, key.n + 1, dtype=jnp.float32)
                       )[None].repeat(key.c, 0)
        d = jax.random.normal(ks[5], (key.c,))
        h0 = 0.1 * jax.random.normal(ks[6], (key.b, key.c, key.n))
        return (x, dt, bm, cm, alog, d, h0), {}

    def config_from_json(self, d: Dict) -> SsmScanConfig:
        return SsmScanConfig(**d)

    # -- static-analysis hooks (repro.analyze) -----------------------------
    def canonical_keys(self) -> List[SsmKey]:
        return [SsmKey(b=2, t=32, c=64, n=8)]

    def key_from_dims(self, dims: str) -> SsmKey:
        b, t, c, n = (int(d) for d in dims.split("x"))
        return SsmKey(b=b, t=t, c=c, n=n)

    def config_vmem_bytes(self, config: SsmScanConfig, key: SsmKey) -> int:
        return config.vmem_bytes(key)

    def config_divides(self, config: SsmScanConfig, key: SsmKey
                       ) -> List[str]:
        if config.blk_c <= 0 or key.c % config.blk_c:
            return [f"c={key.c} not tiled by block {config.blk_c}"]
        return []

    def allowed_float_dtypes(self, version: str) -> frozenset:
        return frozenset({"float32"})

    def run(self, x, dt, bmat, cmat, a_log, d, h0, *, version: str,
            config: Optional[SsmScanConfig], interpret: Optional[bool]):
        if version == "ref":
            from repro.models.mamba import ssm_scan
            return ssm_scan(x, dt, bmat, cmat, a_log, d, h0)
        if version == "chunked":
            from repro.models.mamba import ssm_chunked
            t = x.shape[1]
            chunk = max(cc for cc in range(1, min(64, t) + 1) if t % cc == 0)
            return ssm_chunked(x, dt, bmat, cmat, a_log, d, h0, chunk=chunk)
        cfg = config or SsmScanConfig()
        return scan_lib.ssm_scan_pallas(
            x, dt, bmat, cmat, a_log, d, h0, blk_c=cfg.blk_c,
            interpret=backend.resolve_interpret(interpret))


KERNEL = api.register(SsmKernel())
