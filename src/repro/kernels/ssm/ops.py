"""Public ssm op layer (new with the kernel registry — the scan previously
had no op surface at all; consumers reached into ssm_scan.py and hand-picked
blk_c).

    from repro.kernels.ssm import ops
    y, hT = ops.ssm_scan(x, dt, bmat, cmat, a_log, d, h0)

Thin wrapper over `repro.kernels.api.dispatch("ssm", ...)`: version=None
runs the Pallas kernel under the repro.tune cached blk_c for this
(B, T, C, N); version="ref"/"chunked" run the XLA forms.
"""

from __future__ import annotations

from typing import Optional

from repro.kernels import api


def ssm_scan(x, dt, bmat, cmat, a_log, d, h0, *,
             version: Optional[str] = None, config=None,
             interpret: Optional[bool] = None, problem_key=None):
    """Same contract as models/mamba.ssm_scan: x, dt: (B,T,C);
    bmat/cmat: (B,T,N); a_log: (C,N); d: (C,); h0: (B,C,N).
    Returns (y (B,T,C) f32, hT (B,C,N) f32).

    problem_key: optional SsmKey overriding the shape-derived one — SPMD
    callers (models/transformer.mamba_path under a TP mesh) key the tune
    cache on the per-shard channel count so blk_c matches the local slab
    each device runs."""
    return api.dispatch("ssm", x, dt, bmat, cmat, a_log, d, h0,
                        version=version, config=config, interpret=interpret,
                        problem_key=problem_key)
