"""Selective-scan (Mamba) Pallas TPU kernel — the Hymba §Perf hillclimb.

The XLA chunked scan (models/mamba.ssm_chunked) materializes ~6 (B,S,C,N)
f32 intermediates per chunk in HBM — measured 70s of hymba train_4k's 128s
memory term. This kernel is the Mamba paper's own "hardware-aware scan"
adapted to TPU: the recurrent state h (C_blk, N) lives in VMEM (registers
of the recurrence), x/dt stream through once, y streams out once — HBM
traffic collapses to the kernel's I/O (~0.4s modeled).

Grid: (B, n_c_blocks). Block = the full time axis x (T, C_blk) slab
(T=4096, C_blk=128 -> 2 MiB f32, VMEM-resident), dt same, b/c (T, N).
The kernel fori-loops T steps, carrying h functionally.

ref.py oracle = models/mamba.ssm_scan. Validated in interpret mode by
tests/test_ssm_kernel.py across shape sweeps.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import backend


def _kernel(x_ref, dt_ref, b_ref, c_ref, alog_ref, d_ref, h0_ref,
            y_ref, hT_ref, *, t_len: int):
    a = -jnp.exp(alog_ref[...].astype(jnp.float32))      # (C_blk, N)
    d = d_ref[...].astype(jnp.float32)                   # (C_blk, 1)

    def step(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)          # (C_blk,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)
        bt = b_ref[0, t, :].astype(jnp.float32)          # (N,)
        ct = c_ref[0, t, :].astype(jnp.float32)
        da = jnp.exp(dtt[:, None] * a)                   # (C_blk, N)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=1) + d[:, 0] * xt
        y_ref[0, t, :] = y
        return h

    h = jax.lax.fori_loop(0, t_len, step, h0_ref[0].astype(jnp.float32))
    hT_ref[0] = h


def ssm_scan_pallas(x, dt, bmat, cmat, a_log, d, h0, *, blk_c: int = 128,
                    interpret: Optional[bool] = None):
    """Same contract as models/mamba.ssm_scan:
    x, dt: (B,T,C); bmat/cmat: (B,T,N); a_log: (C,N); d: (C,);
    h0: (B,C,N). Returns (y (B,T,C) f32, hT (B,C,N) f32).
    interpret=None defers to repro.backend (REPRO_INTERPRET override)."""
    interpret = backend.resolve_interpret(interpret)
    b, t, c = x.shape
    n = a_log.shape[1]
    blk_c = min(blk_c, c)
    assert c % blk_c == 0, (c, blk_c)
    n_c = c // blk_c

    kern = functools.partial(_kernel, t_len=t)
    y, hT = pl.pallas_call(
        kern,
        grid=(b, n_c),
        in_specs=[
            pl.BlockSpec((1, t, blk_c), lambda i, j: (i, 0, j)),   # x
            pl.BlockSpec((1, t, blk_c), lambda i, j: (i, 0, j)),   # dt
            pl.BlockSpec((1, t, n), lambda i, j: (i, 0, 0)),       # b
            pl.BlockSpec((1, t, n), lambda i, j: (i, 0, 0)),       # c
            pl.BlockSpec((blk_c, n), lambda i, j: (j, 0)),         # a_log
            pl.BlockSpec((blk_c, 1), lambda i, j: (j, 0)),         # d
            pl.BlockSpec((1, blk_c, n), lambda i, j: (i, j, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((1, t, blk_c), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, blk_c, n), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, c), jnp.float32),
            jax.ShapeDtypeStruct((b, c, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, bmat, cmat, a_log, d[:, None], h0)
    return y, hT


def kernel_hbm_bytes(b: int, t: int, c: int, n: int) -> float:
    """Deterministic kernel I/O: x/dt in, y out (f32) + b/c + states."""
    return float((3 * b * t * c + 2 * b * t * n + 2 * b * c * n
                  + c * n + c) * 4)
