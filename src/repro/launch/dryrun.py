import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# This is the ONLY entry point that forces 512 host devices (dry-run only).

"""Multi-pod dry-run launcher.

For every (architecture x input-shape) cell this lowers + compiles the
appropriate step (train_step / prefill_step / serve_step) against the
production mesh — (16,16)=256 chips single-pod, (2,16,16)=512 chips
multi-pod — and records:

  * compiled.memory_analysis()  (proves the cell fits 16 GiB/chip),
  * compiled.cost_analysis()    (FLOPs / bytes for §Roofline),
  * parsed collective bytes by kind (hlo_analysis),
  * the three roofline terms + dominant bottleneck (core.roofline).

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --all                 # 16x16 baseline table
  python -m repro.launch.dryrun --all --multi-pod     # 2x16x16 proof
  python -m repro.launch.dryrun --all --both
Results land in runs/dryrun/*.json (read by benchmarks & EXPERIMENTS.md).
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.base import (SHAPES, ARCH_IDS, applicable_shapes,
                                get_config)
from repro.core import roofline
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build_model
from repro.train import step as step_lib

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "runs", "dryrun")


def model_flops_for_cell(cfg, shape_name: str) -> float:
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch          # one decode step


def auto_microbatches(cfg, shape, mesh) -> int:
    """Pick grad-accumulation depth so the scan-saved per-layer hidden
    states stay ~<=2.5 GiB/chip (the dominant train-time residency)."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    b_local = max(1, shape.global_batch // dp)
    saved = cfg.n_layers * b_local * shape.seq_len * cfg.d_model * 2
    target = 2.5 * 2 ** 30
    mb = 1
    while saved / mb > target and mb < b_local:
        mb *= 2
    return mb


def lower_cell(arch: str, shape_name: str, mesh, *, fsdp=None,
               kv_seq_shard=None, remat=None, grad_compress="none",
               microbatches=None, ssm_impl=None):
    """Build + lower + compile one cell. Returns (compiled, lowered, plan)."""
    import dataclasses as dc
    cfg = get_config(arch)
    if remat is not None:
        cfg = dc.replace(cfg, remat=remat)
    if ssm_impl is not None:
        cfg = dc.replace(cfg, ssm_impl=ssm_impl)
    # NOTE: `flash` does NOT set cfg.use_flash_attention for the lowering —
    # the Pallas kernel's interpret-mode HLO misstates its traffic (full-
    # array loop-carry copies). The cell is lowered with the XLA attention
    # and the roofline is adjusted analytically in run_cell (the same
    # deterministic-BlockSpec-traffic methodology as the GPP journey).
    model = build_model(cfg)
    cell = specs_lib.input_specs(cfg, shape_name)
    kind = cell["kind"]
    plan = step_lib.make_plan(cfg, mesh, kind=kind, fsdp=fsdp,
                              kv_seq_shard=kv_seq_shard)

    with jax.set_mesh(mesh):
        if kind == "train":
            if microbatches is None:
                microbatches = auto_microbatches(cfg, SHAPES[shape_name], mesh)
            bundle, _ = step_lib.build_train_step(
                model, plan, grad_compress=grad_compress,
                microbatches=microbatches)
            from repro.dist.sharding import batch_shardings
            bs = batch_shardings(plan, cell["batch"])
            bundle.in_shardings = (bundle.in_shardings[0],
                                   bundle.in_shardings[1], bs)
            lowered = bundle.lower(None, None, cell["batch"])
        elif kind == "prefill":
            bundle = step_lib.build_prefill_step(model, plan)
            from repro.dist.sharding import batch_shardings
            bs = batch_shardings(plan, cell["batch"])
            bundle.in_shardings = (bundle.in_shardings[0], bs)
            lowered = bundle.lower(None, cell["batch"])
        else:
            bundle = step_lib.build_decode_step(model, plan, cell["cache"])
            from repro.dist.sharding import batch_shardings
            bs = batch_shardings(plan, cell["batch"])
            bundle.in_shardings = (bundle.in_shardings[0],
                                   bundle.in_shardings[1], bs["tokens"])
            lowered = bundle.lower(None, None, cell["batch"]["tokens"])
        compiled = lowered.compile()
    return compiled, lowered, plan


def _donated_bytes(arch, shape_name, mesh, plan) -> int:
    """Per-chip bytes of donated step inputs (params+opt for train, cache
    for decode) under their shardings."""
    import numpy as np
    from repro.dist import sharding as shd
    cfg = get_config(arch)
    model = build_model(cfg)
    cell = specs_lib.input_specs(cfg, shape_name)
    total = 0

    def add(abstract, shardings):
        nonlocal total
        flat = jax.tree.leaves(abstract)
        shs = jax.tree.leaves(shardings,
                              is_leaf=lambda x: hasattr(x, "spec"))
        for ab, sh in zip(flat, shs):
            n = int(np.prod(ab.shape)) * ab.dtype.itemsize if ab.shape else                 ab.dtype.itemsize
            div = 1
            for axes in sh.spec:
                if axes is None:
                    continue
                for a in (axes if isinstance(axes, tuple) else (axes,)):
                    div *= mesh.shape[a]
            total += n // max(div, 1)

    ab_params = model.abstract_params()
    ps = shd.params_shardings(plan, model.param_axes, ab_params)
    if cell["kind"] == "train":
        add(ab_params, ps)
        from repro.optim.adafactor import make_optimizer
        from repro.train.step import _opt_state_shardings
        opt = make_optimizer(cfg.optimizer, lambda s: 1e-4)
        ab_opt, os_ = _opt_state_shardings(plan, model, opt, ab_params, ps)
        add(ab_opt, os_)
    elif cell["kind"] == "decode":
        cs = shd.cache_shardings(plan, model.cache_axes(), cell["cache"])
        add(cell["cache"], cs)
    return total


def flash_adjustment(cfg, shape_name: str, mesh, plan) -> dict:
    """Analytic traffic delta for replacing the XLA attention score chain
    with the Pallas flash kernel (kernels/flash).

    XLA path per layer-pass per chip: the (B,KvH,G,Sq,Skv) f32 score tensor
    is materialized ~3x (scores+mask, softmax, probs) = c*B*H*Sq*Skv*4 B.
    Flash path: q/out streamed once; k/v re-fetched once per q block
    (n_q = Sq/BLK_Q revisits) — deterministic from the BlockSpecs.
    Passes: train = fwd + remat-fwd + bwd(dq) + bwd(dkv) = 4; prefill = 1.
    """
    shape = SHAPES[shape_name]
    if shape.kind == "decode" or cfg.family in ("ssm",):
        return {"score_bytes": 0.0, "flash_bytes": 0.0}
    tp = mesh.shape["model"]
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    b_loc = max(1, shape.global_batch // dp)
    h_loc = cfg.n_heads // tp if cfg.n_heads % tp == 0 and         cfg.n_kv_heads % tp == 0 else cfg.n_heads
    kv_loc = max(1, h_loc * cfg.n_kv_heads // cfg.n_heads)
    sq = skv = shape.seq_len
    layers = cfg.n_layers
    passes = 4.0 if shape.kind == "train" else 1.0
    causal_frac = 0.5 if shape.kind in ("train", "prefill") else 1.0
    c_mat = 3.0                                   # score-chain materializations
    score = passes * layers * c_mat * b_loc * h_loc * sq * skv * 4 * causal_frac
    blk_q = 256
    n_q = sq // blk_q
    qo = 2 * b_loc * h_loc * sq * cfg.head_dim * 2          # q + out
    kv = 2 * b_loc * kv_loc * skv * cfg.head_dim * 2 * n_q * causal_frac
    flash = passes * layers * (qo + kv)
    return {"score_bytes": float(score), "flash_bytes": float(flash)}


def ssm_kernel_adjustment(cfg, shape_name: str, mesh) -> float:
    """Analytic HBM traffic of the Pallas selective-scan kernel
    (kernels/ssm/ssm_scan.kernel_hbm_bytes), per chip per step — added back
    when the cell is lowered with ssm_impl="stub" (the kernel replaces the
    stubbed scan 1:1; equivalence proven by tests/test_ssm_kernel.py)."""
    from repro.kernels.ssm.ssm_scan import kernel_hbm_bytes
    shape = SHAPES[shape_name]
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    b_loc = max(1, shape.global_batch // dp)
    ci = 2 * cfg.d_model
    passes = 4.0 if shape.kind == "train" else 1.0
    per_layer = kernel_hbm_bytes(b_loc, shape.seq_len, ci, cfg.ssm_state)
    return passes * cfg.n_layers * per_layer


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             save: bool = True, verbose: bool = True, flash: bool = False,
             ssm_kernel: bool = False, **kw):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_shape = tuple(mesh.shape[a] for a in mesh.axis_names)
    cfg = get_config(arch)
    if ssm_kernel:
        kw = dict(kw, ssm_impl="stub")
    t0 = time.time()
    compiled, lowered, plan = lower_cell(arch, shape_name, mesh, **kw)
    compile_s = time.time() - t0

    rep = roofline.analyze_compiled(
        f"{arch}/{shape_name}", compiled, mesh_shape,
        model_flops_total=model_flops_for_cell(cfg, shape_name))
    from repro.core.hw import TPU_V5E
    if flash:
        adj = flash_adjustment(cfg, shape_name, mesh, plan)
        new_bytes = max(0.0, rep.bytes_per_chip - adj["score_bytes"]
                        + adj["flash_bytes"])
        rep.bytes_per_chip = new_bytes
        rep.memory_s = new_bytes / TPU_V5E.hbm_bw
        rep.extra["flash_adjustment"] = adj
    if ssm_kernel:
        kb = ssm_kernel_adjustment(cfg, shape_name, mesh)
        rep.bytes_per_chip += kb
        rep.memory_s = rep.bytes_per_chip / TPU_V5E.hbm_bw
        rep.extra["ssm_kernel_bytes"] = kb
    row = rep.row()
    # CPU XLA implements neither input-output aliasing (donation) nor
    # in-place dynamic-update-slice, so donated buffers (params+opt in
    # train, the KV cache in decode) are double/triple counted in temp.
    # hbm_adjusted removes the donated duplicates — the TPU-resident figure.
    donated = _donated_bytes(arch, shape_name, mesh, plan)
    kind = specs_lib.input_specs(cfg, shape_name)["kind"]
    dup = donated * (2 if kind == "decode" else 1)
    adjusted = max(0, (rep.device_memory_bytes or 0) - dup)
    row.update(
        multi_pod=multi_pod,
        compile_s=compile_s,
        collective_by_kind=rep.extra["collective_bytes_by_kind"],
        collective_counts=rep.extra["collective_count_by_kind"],
        fsdp=plan.fsdp, kv_seq_shard=plan.kv_seq_shard, flash=flash,
        ssm_kernel=ssm_kernel,
        donated_gib=donated / 2 ** 30,
        hbm_adjusted_gib=adjusted / 2 ** 30,
        fits_hbm=bool(adjusted < 16 * 2 ** 30),
    )
    if verbose:
        ma = compiled.memory_analysis()
        print(f"[{arch} x {shape_name} mesh={mesh_shape}] "
              f"compile={compile_s:.1f}s "
              f"mem/chip={(rep.device_memory_bytes or 0)/2**30:.2f}GiB "
              f"terms: compute={rep.compute_s:.4g}s memory={rep.memory_s:.4g}s "
              f"collective={rep.collective_s:.4g}s dominant={rep.dominant} "
              f"useful={rep.useful_flops_ratio and f'{rep.useful_flops_ratio:.2f}'}")
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f} "
              f"out={ma.output_size_in_bytes/2**30:.2f} "
              f"temp={ma.temp_size_in_bytes/2**30:.2f} GiB")
        print(f"  collectives: {row['collective_by_kind']}")
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        tag = "multi" if multi_pod else "single"
        path = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{tag}.json")
        with open(path, "w") as fh:
            json.dump(row, fh, indent=1, default=float)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run each cell on both meshes")
    ap.add_argument("--fsdp", type=int, default=None)
    ap.add_argument("--kv-seq-shard", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--grad-compress", default="none")
    ap.add_argument("--flash", action="store_true",
                    help="use the Pallas flash-attention kernel")
    args = ap.parse_args()

    kw = dict(fsdp=None if args.fsdp is None else bool(args.fsdp),
              kv_seq_shard=(None if args.kv_seq_shard is None
                            else bool(args.kv_seq_shard)),
              remat=args.remat, grad_compress=args.grad_compress)
    flash = args.flash

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for sh in applicable_shapes(get_config(arch)):
                cells.append((arch, sh))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    pods = [args.multi_pod] if not args.both else [False, True]
    failures = []
    for arch, sh in cells:
        for mp in pods:
            try:
                run_cell(arch, sh, multi_pod=mp, flash=flash, **kw)
            except Exception as e:
                failures.append((arch, sh, mp, repr(e)))
                print(f"FAIL [{arch} x {sh} multi_pod={mp}]: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"\nall {len(cells) * len(pods)} cells compiled OK")


if __name__ == "__main__":
    main()
