"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS before any jax import, while tests/benches must see
the single real CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16,16) = 256 chips, axes (data, model).
    Multi-pod:  (2,16,16) = 512 chips, axes (pod, data, model) — `pod` is
    DP across the inter-pod DCN; gradient all-reduce crosses it once/step."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(model: int = 4, data: int = 2, *, multi_pod: bool = False):
    """Small mesh for CPU-host testing (8 forced host devices)."""
    if multi_pod:
        return jax.make_mesh((2, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
