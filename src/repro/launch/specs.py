"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation — these drive jit(...).lower() in the dry-run and the
roofline table. The modality frontends are STUBS per the assignment:
whisper gets precomputed frame embeddings, internvl2 precomputed patch
embeddings (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.models.layers import PARAM_DTYPE
from repro.models.registry import make_cache

PyTree = Any


def _s(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {"frames": _s((b, cfg.enc_seq, cfg.d_model), PARAM_DTYPE),
                "tokens": _s((b, s)), "labels": _s((b, s))}
    if cfg.family == "vlm":
        st = s - cfg.n_vis_tokens          # text tokens; total positions = s
        return {"vis": _s((b, cfg.n_vis_tokens, cfg.d_model), PARAM_DTYPE),
                "tokens": _s((b, st)), "labels": _s((b, st))}
    return {"tokens": _s((b, s)), "labels": _s((b, s))}


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {"frames": _s((b, cfg.enc_seq, cfg.d_model), PARAM_DTYPE),
                "tokens": _s((b, s))}
    if cfg.family == "vlm":
        return {"vis": _s((b, cfg.n_vis_tokens, cfg.d_model), PARAM_DTYPE),
                "tokens": _s((b, s - cfg.n_vis_tokens))}
    return {"tokens": _s((b, s))}


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[Dict, PyTree]:
    """(token specs, abstract cache) for one serve_step against a cache of
    the cell's seq_len."""
    b, s = shape.global_batch, shape.seq_len
    cache = make_cache(cfg, b, s, abstract=True)
    return {"tokens": _s((b, 1))}, cache


def input_specs(cfg: ModelConfig, shape_name: str):
    """Dispatch per the cell kind. Returns a dict describing what the cell
    lowers: {"kind", "batch", "cache"(decode only)}."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return {"kind": "train", "batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"kind": "prefill", "batch": prefill_batch_specs(cfg, shape)}
    batch, cache = decode_specs(cfg, shape)
    return {"kind": "decode", "batch": batch, "cache": cache}
