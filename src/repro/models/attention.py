"""Attention: chunked-causal (train/prefill) and decode (incl. distributed
flash-decode over a sequence-sharded KV cache).

Memory design mirrors the paper's cache-blocking lesson (v6): never
materialize the full (S x S) score matrix — queries are processed in blocks
(lax.scan) with online f32 softmax, so the transient working set is
O(chunk x S) per head group. On TPU the same blocking becomes the Pallas
flash kernel; this jnp version is the XLA path and the oracle.

Distributed decode ("flash decode"): for 32k+ caches the KV cache is sharded
along the *sequence* dim over the `model` mesh axis. Each chip computes
partial attention over its shard and the partials are combined with a psum
of (o*l, l, m)-style logsumexp stats under shard_map — one small collective
instead of gathering the whole cache.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import PARAM_DTYPE, DistCtx

NEG_INF = -1e30


def _gqa_reshape(q, n_kv: int):
    """(B,S,H,Hd) -> (B,S,KvH,G,Hd)"""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def chunked_causal_attention(
    q, k, v, *,
    chunk: int = 512,
    window: int = 0,
    q_offset: int = 0,
    causal: bool = True,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """q: (B,Sq,H,Hd), k/v: (B,Skv,KvH,Hd). Causal by default (causal=False
    gives full bidirectional attention — encoder / cross-attention).
    q_offset: absolute position of q[0] relative to k[0] (prefill=0).
    kv_valid: optional (B,Skv) bool — False columns (padding) are masked out
    of every query's softmax, so pad tokens cannot leak into real rows."""
    b, sq, h, hd = q.shape
    _, skv, n_kv, _ = k.shape
    g = h // n_kv
    scale = hd ** -0.5

    qr = _gqa_reshape(q, n_kv)                       # (B,Sq,KvH,G,Hd)
    chunk = min(chunk, sq)
    if sq % chunk:
        # largest divisor of sq <= requested chunk (e.g. whisper's 1500)
        chunk = max(c for c in range(1, chunk + 1) if sq % c == 0)
    n_chunks = sq // chunk
    qr = qr.reshape(b, n_chunks, chunk, n_kv, g, hd)
    kv_pos = jnp.arange(skv)

    def one_chunk(ci, qc):
        # qc: (B,chunk,KvH,G,Hd); ci is the scan CARRY (a traced counter),
        # not scan xs — this stops XLA hoisting the causal mask out of the
        # loop and materializing (n_chunks, chunk, Skv) masks for all chunks
        # at once (a real pessimization observed in the compiled HLO).
        scores = jnp.einsum(
            "bqkgd,bskd->bkgqs", qc, k,
            preferred_element_type=jnp.float32) * scale   # (B,KvH,G,chunk,Skv)
        if causal:
            q_pos = q_offset + ci * chunk + jnp.arange(chunk)
            mask = kv_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= kv_pos[None, :] > (q_pos[:, None] - window)
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        if kv_valid is not None:
            scores = jnp.where(kv_valid[:, None, None, None, :], scores,
                               NEG_INF)
        p = jax.nn.softmax(scores, axis=-1).astype(PARAM_DTYPE)
        return jnp.einsum("bkgqs,bskd->bqkgd", p, v,
                          preferred_element_type=jnp.float32).astype(PARAM_DTYPE)

    def body(ci, qc):
        # checkpoint per chunk: during the backward pass only ONE chunk's
        # scores/probs are live (instead of the full stacked (n_chunks, ...)
        # residual), which is what keeps train_4k under the 16 GiB/chip HBM
        # budget at B_local=16.
        return ci + 1, jax.checkpoint(one_chunk)(ci, qc)

    _, out = jax.lax.scan(body, jnp.int32(0), qr.swapaxes(0, 1))
    out = out.swapaxes(0, 1).reshape(b, sq, h, hd)
    return out


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: int = 0) -> jax.Array:
    """Single-token decode. q: (B,1,H,Hd); caches: (B,L,KvH,Hd).
    cache_len: number of valid cache positions — a (static or traced)
    scalar shared by every row, or a (B,) vector for per-row lengths
    (the slot-scheduler case, where each slot is mid-flight at its own
    offset)."""
    b, _, h, hd = q.shape
    _, l, n_kv, _ = k_cache.shape
    g = h // n_kv
    scale = hd ** -0.5
    qr = q.reshape(b, n_kv, g, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(l)
    if jnp.ndim(cache_len) == 1:                         # per-row lengths
        mask = pos[None, :] < cache_len[:, None]         # (B, L)
        if window:
            mask &= pos[None, :] >= (cache_len[:, None] - window)
        scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    else:
        mask = pos < cache_len
        if window:
            mask &= pos >= (cache_len - window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(PARAM_DTYPE)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(PARAM_DTYPE)


def decode_attention_multi(q, k_cache, v_cache, cache_len, *,
                           window: int = 0) -> jax.Array:
    """Multi-query decode: Q=k+1 candidate tokens per row attend against a
    cache whose last Q lines are the candidates themselves (speculative
    verify — serve/spec.py). q: (B,Q,H,Hd); caches: (B,L,KvH,Hd).

    cache_len counts valid positions INCLUDING the Q candidate lines, so
    candidate j (0-based) sits at absolute position cache_len - Q + j and
    may attend every cache position <= its own — the per-query causal mask
    that makes verify logits bit-identical to Q sequential decode_attention
    calls at the same positions. cache_len: scalar or (B,) per-row."""
    b, qn, h, hd = q.shape
    _, l, n_kv, _ = k_cache.shape
    g = h // n_kv
    scale = hd ** -0.5
    qr = q.reshape(b, qn, n_kv, g, hd).transpose(0, 2, 3, 1, 4)
    scores = jnp.einsum("bkgqd,bskd->bkgqs", qr, k_cache,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(l)
    clen = cache_len if jnp.ndim(cache_len) == 1 else \
        jnp.full((b,), cache_len, jnp.int32)
    q_pos = clen[:, None] - qn + jnp.arange(qn)[None, :]     # (B,Q) absolute
    mask = pos[None, None, :] <= q_pos[:, :, None]           # (B,Q,L)
    if window:
        mask &= pos[None, None, :] > (q_pos[:, :, None] - window)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(PARAM_DTYPE)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p, v_cache,
                     preferred_element_type=jnp.float32)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, qn, h, hd)
    return out.astype(PARAM_DTYPE)


# ---------------------------------------------------------------------------
# distributed flash-decode: seq-sharded KV cache + logsumexp-combine psum
# ---------------------------------------------------------------------------

def _partial_decode(q, k_shard, v_shard, valid_mask):
    """Partial attention over a KV shard -> (o_unnorm, l, m) f32 stats.
    q: (B,KvH,G,Hd); k/v_shard: (B,Ls,KvH,Hd); valid_mask: (B?,Ls) bool."""
    hd = q.shape[-1]
    scale = hd ** -0.5
    scores = jnp.einsum("bkgd,bskd->bkgs", q, k_shard,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(valid_mask[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1)                                 # (B,KvH,G)
    e = jnp.exp(scores - m[..., None])
    l = jnp.sum(e, axis=-1)                                      # (B,KvH,G)
    o = jnp.einsum("bkgs,bskd->bkgd", e, v_shard.astype(jnp.float32),
                   preferred_element_type=jnp.float32)           # unnormalized
    return o, l, m


def flash_decode_sharded(q, k_cache, v_cache, cache_len, *,
                         ctx: DistCtx, window: int = 0) -> jax.Array:
    """Decode attention with the cache's seq dim sharded over ctx.model_axis.

    Inside shard_map each chip sees its local (B, L/mp, KvH, Hd) shard,
    computes partial (o,l,m), and the global softmax is reconstructed with
    two psums (max then sum) — the classic flash-decoding combine, mapped to
    TPU ICI instead of GPU SM partitioning (DESIGN.md hardware adaptation).
    """
    b, _, h, hd = q.shape
    _, l_total, n_kv, _ = k_cache.shape
    g = h // n_kv
    axis = ctx.model_axis

    def local(qr, ks, vs, clen):
        # shard-local positions: shard index via axis_index
        shard = jax.lax.axis_index(axis)
        ls = ks.shape[1]
        pos = shard * ls + jnp.arange(ls)
        mask = pos < clen
        if window:
            mask = mask & (pos >= clen - window)
        bl = ks.shape[0]
        mask = jnp.broadcast_to(mask[None, :], (bl, ls))
        o, lsum, m = _partial_decode(
            qr[:, 0].reshape(bl, n_kv, g, hd), ks, vs, mask)
        # combine partial softmax stats across the model axis
        m_glob = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_glob)
        l_glob = jax.lax.psum(lsum * corr, axis)
        o_glob = jax.lax.psum(o * corr[..., None], axis)
        out = o_glob / jnp.maximum(l_glob[..., None], 1e-30)
        return out.reshape(bl, 1, h, hd).astype(PARAM_DTYPE)

    # axis_names={model}: manual only over the model axis; batch/data sharding
    # stays automatic (so batch=1 long_500k and batch-sharded decode_32k both
    # flow through the same code path).
    fn = jax.shard_map(
        local, mesh=ctx.mesh,
        in_specs=(P(), P(None, axis, None, None), P(None, axis, None, None), P()),
        out_specs=P(),
        axis_names=frozenset({axis}),
        check_vma=False,
    )
    return fn(q, k_cache, v_cache, cache_len)


def flash_attention_spmd(q, k, v, ctx: Optional[DistCtx], *,
                         causal: bool = True):
    """Pallas flash attention under shard_map: the kernel's grid loop must
    see LOCAL shards — lowering it through SPMD auto-sharding makes XLA
    all-gather the operands per grid step (measured: PB-scale collectives).
    Heads shard over `model` when divisible, batch over the dp axes;
    otherwise that dim replicates (same fallback as the sharding engine).

    Dispatches through the kernel registry, so the (blk_q, blk_kv) come
    from the repro.tune cache per local shard size instead of the old
    frozen 256/256: _dispatch_flash runs INSIDE shard_map, where q/k/v are
    the per-device shards, so the FlashKey it builds carries the per-shard
    head counts (h/tp when the mesh divides them) — the same local-keying
    contract the ssm registry path gets via DistCtx.tp_shards. Tuning here
    is model-only: this runs at trace time inside jit/shard_map, where a
    measurement pass (timed kernel executions on synthetic inputs) would
    stall every first compile of a new shape."""
    if ctx is None or ctx.mesh is None:
        return _dispatch_flash(q, k, v, causal)
    mesh = ctx.mesh
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    tp = mesh.shape[ctx.model_axis]
    dp = 1
    for a in ctx.data_axes:
        dp *= mesh.shape[a]
    hspec = ctx.model_axis if (h % tp == 0 and kvh % tp == 0) else None
    bspec = tuple(ctx.data_axes) if b % max(dp, 1) == 0 and dp > 1 else None
    qs = P(bspec, None, hspec, None)

    fn = jax.shard_map(
        lambda q_, k_, v_: _dispatch_flash(q_, k_, v_, causal),
        mesh=mesh, in_specs=(qs, qs, qs), out_specs=qs,
        axis_names=frozenset(mesh.axis_names), check_vma=False)
    return fn(q, k, v)


def _dispatch_flash(q, k, v, causal):
    """Registry dispatch with a model-only tuned config (no timing pass at
    trace time); shapes the tune menu can't tile fall back to config=None,
    which dispatch resolves to the divisor-clamped static config."""
    from repro.kernels import api
    from repro.tune import tuner
    key = api.get_kernel("flash").problem_key(q, k, v, causal=causal)
    try:
        cfg = tuner.tune_kernel("flash", key, measure_mode=False).config
    except ValueError:            # empty config space at this shape
        cfg = None
    return api.dispatch("flash", q, k, v, causal=causal, config=cfg)
