"""Shared model building blocks.

Conventions:
  * params are nested dicts of jnp arrays; every leaf has *logical axes*
    recorded by ParamBuilder (e.g. ("layers","d_model","d_ff")) which the
    sharding engine (repro.dist.sharding) later maps onto mesh axes.
  * matmuls run in bf16 with f32 accumulation (preferred_element_type),
    norms/softmax in f32 — the production mixed-precision policy.
  * layer stacks are scanned (jax.lax.scan) over a leading "layers" axis so
    the HLO stays compact for 64-layer configs (critical for 40-cell x
    2-mesh dry-run compile times).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
Axes = Tuple[Optional[str], ...]

PARAM_DTYPE = jnp.bfloat16
NORM_DTYPE = jnp.float32


class ParamBuilder:
    """Creates parameters and records their logical axes in a parallel tree.

    Usage:
        b = ParamBuilder(rng)
        w = b.param("attn/wq", (L, D, H*Hd), ("layers","d_model","heads"))
    `b.axes` afterwards maps path -> logical axes for the sharding engine.
    Set `abstract=True` to emit ShapeDtypeStructs (dry-run init, no memory).
    """

    def __init__(self, rng: Optional[jax.Array], abstract: bool = False,
                 scale: float = 0.02):
        self._rng = rng
        self.abstract = abstract
        self.scale = scale
        self.axes: Dict[str, Axes] = {}

    def _next_rng(self):
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def param(self, path: str, shape: Tuple[int, ...], axes: Axes,
              init: str = "normal", dtype=PARAM_DTYPE):
        assert len(shape) == len(axes), (path, shape, axes)
        self.axes[path] = axes
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "normal":
            return (self.scale * jax.random.normal(
                self._next_rng(), shape, jnp.float32)).astype(dtype)
        if init == "uniform":  # for decay-style params
            return jax.random.uniform(
                self._next_rng(), shape, jnp.float32, -1.0, 1.0).astype(dtype)
        if init == "a_log":  # mamba: A = -arange(1..N) broadcast over channels
            n = shape[-1]
            row = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
            return jnp.broadcast_to(row, shape).astype(dtype)
        if init.startswith("const:"):
            return jnp.full(shape, float(init.split(":")[1]), dtype)
        raise ValueError(init)


def set_path(tree: Dict, path: str, leaf):
    parts = path.split("/")
    for p in parts[:-1]:
        tree = tree.setdefault(p, {})
    tree[parts[-1]] = leaf


def build_params(fn: Callable[[ParamBuilder], Dict],
                 rng: Optional[jax.Array], abstract: bool = False):
    """Run a builder fn, returning (params_tree, axes_by_path)."""
    b = ParamBuilder(rng, abstract=abstract)
    tree = fn(b)
    return tree, b.axes


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def matmul(x, w, *, out_dtype=PARAM_DTYPE):
    """bf16 x bf16 -> f32 accumulate -> cast. The MXU-native contraction."""
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


# --- exact-TP (bit-exact sharded serving) ----------------------------------
# Trace-time marker set by ServeEngine while tracing its sharded steps
# (dist.sharding.serve_specs plans). Inside the scope the row-parallel
# matmuls all-gather their activation back to replicated BEFORE
# contracting, instead of letting GSPMD psum per-shard partials: with the
# serve plan's column-parallel-only weights, every float reduction then
# runs in single-device association order and the sharded engine is
# bit-exact vs the unsharded one (the psum's shard-order reduction is the
# one thing that breaks that, by ~1 bf16 ulp — enough to flip an argmax).
_EXACT_TP_MESH = None


class exact_tp_scope:
    """Context manager marking a trace as exact-TP over `mesh` (None is a
    no-op scope, so callers can use it unconditionally)."""

    def __init__(self, mesh):
        self.mesh = mesh

    def __enter__(self):
        global _EXACT_TP_MESH
        self._prev = _EXACT_TP_MESH
        _EXACT_TP_MESH = self.mesh
        return self

    def __exit__(self, *exc):
        global _EXACT_TP_MESH
        _EXACT_TP_MESH = self._prev
        return False


def gather_exact_tp(x):
    """All-gather x to replicated when tracing under exact_tp_scope (the
    pre-contraction gather of the exact-TP combine); identity otherwise."""
    if _EXACT_TP_MESH is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_EXACT_TP_MESH, PartitionSpec()))


def matmul_rp(x, w):
    """Row-parallel projection (contracting dim sharded over `model`): emit
    bf16 so the cross-shard psum XLA inserts moves HALF the bytes (the
    Megatron bf16-allreduce trick; local MXU accumulation is still f32 —
    only the cross-chip combine is bf16). Measured in EXPERIMENTS.md §Perf:
    llama4 prefill collective term 51.5 -> 32.9s, qwen2.5-32b train
    137 -> 88s.

    Under exact_tp_scope (sharded serving) the activation is gathered
    first and the weight is replicated by plan, so this contraction is
    computed whole per device — bit-exact, no psum."""
    x = gather_exact_tp(x)
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.bfloat16)
    return y


def einsum(subscript, *ops, out_dtype=PARAM_DTYPE):
    y = jnp.einsum(subscript, *ops, preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


def rms_norm(x, scale, eps: float):
    xf = x.astype(NORM_DTYPE)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(NORM_DTYPE)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float):
    xf = x.astype(NORM_DTYPE)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(NORM_DTYPE) + bias.astype(NORM_DTYPE)).astype(x.dtype)


def swiglu(x, wi, wg, wo):
    """SwiGLU FFN: silu(x@wg) * (x@wi) @ wo. The down-projection is
    row-parallel (d_ff sharded) -> bf16 before the psum."""
    h = matmul(x, wi)
    g = matmul(x, wg)
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    return matmul_rp(h, wo)


def gelu_ffn(x, wi, bi, wo, bo):
    h = matmul(x, wi) + bi.astype(PARAM_DTYPE)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(PARAM_DTYPE)
    return matmul_rp(h, wo) + bo.astype(PARAM_DTYPE)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, Hd); positions: (S,) or (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (Hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, Hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return y.astype(x.dtype)


def sinusoid_pos(seq: int, d: int, offset=0):
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / d)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(PARAM_DTYPE)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed(tokens, table):
    return jnp.take(table, tokens, axis=0)


def lm_logits(x, table_or_head):
    """Project to vocab in f32 (loss numerics)."""
    return jax.lax.dot_general(
        x, table_or_head, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def softmax_xent(logits_f32, labels, *, z_loss: float = 1e-4):
    """Cross-entropy with optional z-loss (PaLM-style logit regularizer).
    logits: (..., V) f32; labels: (...) int32. Returns per-token loss."""
    lse = jax.scipy.special.logsumexp(logits_f32, axis=-1)
    ll = jnp.take_along_axis(logits_f32, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss


@dataclasses.dataclass
class DistCtx:
    """How a model apply should interact with the mesh (None = single host).
    kv_seq_shard: decode attention uses the distributed flash-decode path
    (KV cache seq dim sharded over `model_axis`, partial-softmax psum).
    ep_data: MoE uses the shard_map all-to-all expert-parallel dispatch
    (moe.moe_ffn_ep) for large token counts."""
    mesh: Any = None
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    kv_seq_shard: bool = False
    ep_data: bool = False

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]

    def tp_shards(self, *dims: int) -> int:
        """How many ways the model axis splits dims that are all divisible
        by it (1 when any isn't — the spec_for replication fallback).
        Kernel call sites use this to key tuned configs on the LOCAL
        per-shard problem (dim // tp_shards) instead of the global shape."""
        tp = self.model_size
        if tp > 1 and all(d % tp == 0 for d in dims):
            return tp
        return 1
