"""Selective SSM (Mamba-style) path for the Hymba hybrid block.

h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t        (A diagonal, state N)
y_t = C_t . h_t + D * x_t

Evaluated three ways:
  * `ssm_scan`    — sequential oracle / decode step basis;
  * `ssm_chunked` — chunk-parallel: sequential across chunks, cumulative-
                    decay matmul form inside a chunk (same trick as
                    rwkv6.wkv6_chunked; raises AI onto the MXU);
  * `ssm_decode`  — single-token state update.

The depthwise causal conv1d (kernel 4) that precedes the SSM keeps a
(B, d_inner, K-1) rolling state for decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

CONV_K = 4


def causal_conv1d(x, w, conv_state=None):
    """Depthwise causal conv. x: (B,T,C); w: (K,C).
    conv_state: (B,K-1,C) tail of the previous segment (decode/streaming)."""
    b, t, c = x.shape
    k = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((b, k - 1, c), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)          # (B, T+K-1, C)
    out = jnp.zeros((b, t, c), jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + t].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = xp[:, -(k - 1):]
    return out.astype(x.dtype), new_state


def ssm_scan(x, dt, bmat, cmat, a_log, d, h0):
    """Sequential oracle.
    x, dt: (B,T,C);  bmat, cmat: (B,T,N);  a_log: (C,N) (A = -exp(a_log));
    d: (C,); h0: (B,C,N). Returns (y (B,T,C) f32, hT)."""
    a = -jnp.exp(a_log.astype(jnp.float32))                # (C,N)

    def step(h, inp):
        xt, dtt, bt, ct = inp                              # (B,C),(B,C),(B,N),(B,N)
        da = jnp.exp(dtt[..., None] * a[None])             # (B,C,N)
        dbx = (dtt * xt)[..., None] * bt[:, None, :]       # (B,C,N)
        h = da * h + dbx
        y = jnp.einsum("bcn,bn->bc", h, ct)
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (x, dt, bmat, cmat))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x * d[None, None]
    return y, h


def ssm_chunked(x, dt, bmat, cmat, a_log, d, h0, *, chunk: int = 64):
    """Chunk-parallel selective scan (same contract as ssm_scan).

    Inside a chunk with La_t = sum_{s<=t} dt_s*A (cumulative, per (C,N)):
      h_t = exp(La_t) h_0 + sum_{s<=t} exp(La_t - La_s) dt_s B_s x_s
      y_t = C_t . h_t
    The inner sum is a masked (C x S) matmul over the chunk — MXU work.
    """
    b, t, c = x.shape
    n = a_log.shape[1]
    assert t % chunk == 0
    nch = t // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))                # (C,N)

    def resh(v, last):
        return v.reshape(b, nch, chunk, last).transpose(1, 0, 2, 3)

    xc = resh(x.astype(jnp.float32), c)
    dtc = resh(dt.astype(jnp.float32), c)
    bc = resh(bmat.astype(jnp.float32), n)
    cc = resh(cmat.astype(jnp.float32), n)

    def one_chunk(h, inp):
        xcc, dtcc, bcc, ccc = inp                          # (B,S,C),(B,S,C),(B,S,N)
        da = dtcc[..., None] * a[None, None]               # (B,S,C,N)
        la = jnp.cumsum(da, axis=1)                        # inclusive
        # clamp: exp(-la) must stay in f32 range. Pairwise factors
        # exp(la_t - la_s) are correct to ~e-60 absolute under the clamp
        # (both operands clamp together), the standard GLA/SSD stabilization.
        la = jnp.maximum(la, -60.0)
        # inter: y_inter[t] = C_t . (exp(La_t) h0)
        hh = jnp.exp(la) * h[:, None]                      # (B,S,C,N)
        y = jnp.einsum("bscn,bsn->bsc", hh, ccc)
        # intra: pairwise decay exp(La_t - La_s) * (dt_s x_s) B_s . C_t
        u = dtcc * xcc                                     # (B,S,C)
        # G[t,s,c] = exp(sum over n? no — per n) ... keep N dim:
        # y_intra[t,c] = sum_{s<=t} sum_n exp(la[t,c,n]-la[s,c,n]) u[s,c] b[s,n] c[t,n]
        e_pos = jnp.exp(la)                                # (B,S,C,N)
        e_neg = jnp.exp(-la)
        rhs = u[..., None] * bcc[:, :, None, :] * e_neg    # (B,S,C,N)
        acc = jnp.cumsum(rhs, axis=1)                      # prefix over s<=t
        y = y + jnp.einsum("bscn,bsn->bsc", acc * e_pos, ccc)
        # carry
        la_last = la[:, -1]                                # (B,C,N)
        h = jnp.exp(la_last) * h + \
            jnp.einsum("bscn->bcn", rhs * jnp.exp(la_last[:, None]))
        return h, y

    h, ys = jax.lax.scan(one_chunk, h0.astype(jnp.float32), (xc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3).reshape(b, t, c)
    y = y + x.astype(jnp.float32) * d[None, None].astype(jnp.float32)
    return y, h


def ssm_decode(xt, dtt, bt, ct, a_log, d, h):
    """One token. xt,dtt: (B,C); bt,ct: (B,N); h: (B,C,N)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    da = jnp.exp(dtt[..., None] * a[None])
    h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
    y = jnp.einsum("bcn,bn->bc", h, ct) + xt * d[None]
    return y, h
