"""Mixture-of-Experts FFN: top-k routing, capacity-based static dispatch,
shared experts, load-balance aux loss.

Dispatch strategy (expert-parallel friendly, static shapes): for each expert,
`top_k` over the token axis of its assignment scores picks up to `capacity`
tokens; tokens are gathered to (E, C, D), run through the expert matmuls as
one batched einsum (E sharded over the `model` mesh axis = EP), and
scatter-added back with their router weights. Tokens beyond capacity are
dropped (standard Switch/GShard semantics, capacity_factor=1.25 default).

This lowers to gathers + batched dots + a psum over the EP axis — no
data-dependent all-to-all, so the multi-pod dry-run can prove the schedule.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import PARAM_DTYPE, einsum, gather_exact_tp, swiglu


def router_topk(x, w_router, k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (T, D) -> (weights (T,k) f32, ids (T,k) i32, aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)                     # (T,k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    e = probs.shape[-1]
    assign = jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32)   # top-1 fraction
    f = assign.mean(0)
    p = probs.mean(0)
    aux = e * jnp.sum(f * p)
    return weights, ids, aux


def moe_ffn(x, params, *, n_experts: int, k: int,
            capacity_factor: float = 1.25,
            token_valid=None) -> Tuple[jax.Array, jax.Array]:
    """x: (T, D). params: {router (D,E), wi/wg/wo (E,D,F)/(E,F,D),
    shared_wi/wg/wo optional}. Returns (out (T,D), aux_loss).

    token_valid: optional (T,) bool — invalid (padding) tokens get zero
    router weight, so they can neither claim expert capacity slots from
    real tokens nor contribute to any output. (Capacity itself stays
    shape-derived from T — static shapes.)"""
    t, d = x.shape
    weights, ids, aux = router_topk(x, params["router"], k)
    if token_valid is not None:
        weights = weights * token_valid[:, None].astype(weights.dtype)

    capacity = int(max(1, (t * k * capacity_factor) // n_experts))
    capacity = min(capacity, t)

    # score of token t for expert e (0 if not routed there)
    flat_ids = ids.reshape(-1)                                  # (T*k,)
    flat_w = weights.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(t), k)

    # (E, T): routed weight of each token for each expert
    routed = jnp.zeros((n_experts, t), jnp.float32)
    routed = routed.at[flat_ids, tok_idx].add(flat_w)

    # per-expert top-C tokens (static shapes; overflow dropped)
    gate, gather_idx = jax.lax.top_k(routed, capacity)          # (E, C)
    x_e = jnp.take(x, gather_idx.reshape(-1), axis=0)
    x_e = x_e.reshape(n_experts, capacity, d)                   # (E, C, D)

    h = einsum("ecd,edf->ecf", x_e, params["wi"])
    g = einsum("ecd,edf->ecf", x_e, params["wg"])
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    # exact-TP serving: h's F dim is column-sharded — gather it so the
    # down-projection contracts whole per device (no psum; bit-exact)
    h = gather_exact_tp(h)
    y_e = einsum("ecf,efd->ecd", h, params["wo"])               # (E, C, D)

    y_e = y_e.astype(jnp.float32) * gate[..., None]
    out = jnp.zeros((t, d), jnp.float32)
    out = out.at[gather_idx.reshape(-1)].add(y_e.reshape(-1, d))

    if "shared_wi" in params:
        out = out + swiglu(x, params["shared_wi"], params["shared_wg"],
                           params["shared_wo"]).astype(jnp.float32)
    return out.astype(PARAM_DTYPE), aux


# ===========================================================================
# expert-parallel MoE with explicit all-to-all token exchange (shard_map)
#
# The pure-SPMD moe_ffn above lets XLA derive the communication, which for
# expert weights sharded over `data` materializes an all-reduce of the
# full (T, D) activation tensor per layer (measured: llama4 prefill 51s
# collective term). This version moves TOKENS to the experts' shards with
# two all_to_alls (route there, results back) — the Megatron/GShard EP
# pattern, expressed with jax.lax collectives inside shard_map.
# ===========================================================================

def moe_ffn_ep(x, params, *, n_experts: int, k: int, mesh, dp_axes,
               tp_axis="model", capacity_factor: float = 1.25):
    """x: (T, D) sharded over dp_axes (token-parallel). Expert weights
    sharded over dp_axes on the expert dim AND tp_axis on d_ff (wi:
    (E/dp, D, F/tp) per shard). Fully-manual shard_map over both axes —
    auto-axes shard_map transposition trips an XLA CHECK ("invalid binary
    instruction opcode copy") under scan+remat, and manual mode lets the
    cross-tp psum run in bf16 (half wire) explicitly.
    Returns (out (T, D), aux)."""
    from jax.sharding import PartitionSpec as P

    n_shards = 1
    for a in dp_axes:
        n_shards *= mesh.shape[a]
    e_local = n_experts // n_shards
    axis = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    has_tp = tp_axis is not None and tp_axis in mesh.shape and         (params["wi"].shape[-1] % mesh.shape[tp_axis] == 0)

    def local(x_l, router_w, wi_l, wg_l, wo_l):
        t_l, d = x_l.shape
        weights, ids, aux = router_topk(x_l, router_w, k)   # (T_l, k)
        # flatten routes
        r_ids = ids.reshape(-1)                              # (T_l*k,)
        r_w = weights.reshape(-1)
        r_tok = jnp.repeat(jnp.arange(t_l), k)
        r_dst = r_ids // e_local                             # dst shard
        r_eid = r_ids % e_local                              # local expert @dst

        c_send = int(max(1, (t_l * k * capacity_factor) // n_shards))
        c_send = min(c_send, t_l * k)
        # per-dst route selection (top-C by routing weight; overflow drops)
        score = jnp.where(r_dst[None, :] == jnp.arange(n_shards)[:, None],
                          r_w[None, :], 0.0)                 # (S, T_l*k)
        gate, sel = jax.lax.top_k(score, c_send)             # (S, C)
        tok_send = jnp.take(r_tok, sel.reshape(-1)).reshape(n_shards, c_send)
        eid_send = jnp.take(r_eid, sel.reshape(-1)).reshape(n_shards, c_send)
        x_send = jnp.take(x_l, tok_send.reshape(-1), axis=0) \
            .reshape(n_shards, c_send, d)

        # a2a: dim0 = destination shard -> received dim0 = source shard
        x_recv = jax.lax.all_to_all(x_send, axis, 0, 0, tiled=False)
        eid_recv = jax.lax.all_to_all(eid_send[..., None].astype(jnp.float32),
                                      axis, 0, 0)[..., 0].astype(jnp.int32)
        gate_recv = jax.lax.all_to_all(gate[..., None], axis, 0, 0)[..., 0]

        # local expert compute: second-level capacity dispatch
        r_total = n_shards * c_send
        xr = x_recv.reshape(r_total, d)
        er = eid_recv.reshape(r_total)
        valid = (gate_recv.reshape(r_total) > 0).astype(jnp.float32)
        c2 = int(max(1, (r_total * capacity_factor) // e_local))
        c2 = min(c2, r_total)
        onehot = jnp.where(er[None, :] == jnp.arange(e_local)[:, None],
                           valid[None, :], 0.0)              # (E_l, R)
        pick_w, pick = jax.lax.top_k(onehot, c2)             # (E_l, C2)
        x_e = jnp.take(xr, pick.reshape(-1), axis=0).reshape(e_local, c2, d)
        h = einsum("ecd,edf->ecf", x_e, wi_l)
        g = einsum("ecd,edf->ecf", x_e, wg_l)
        h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
        # bf16 before the model-axis psum and the return a2a (half wire)
        y_e = jnp.einsum("ecf,efd->ecd", h, wo_l,
                         preferred_element_type=jnp.float32)
        if has_tp:
            # explicit row-parallel combine across tp, on the bf16 wire
            y_e = jax.lax.psum(y_e.astype(jnp.bfloat16), tp_axis)
        y_e = (y_e.astype(jnp.float32) * pick_w[..., None]).astype(PARAM_DTYPE)
        # scatter back to route slots
        yr = jnp.zeros((r_total, d), PARAM_DTYPE)
        yr = yr.at[pick.reshape(-1)].add(y_e.reshape(-1, d))
        y_back = jax.lax.all_to_all(yr.reshape(n_shards, c_send, d),
                                    axis, 0, 0)
        # combine at the source: weight by gate, add into local tokens
        out = jnp.zeros((t_l, d), jnp.float32)
        out = out.at[tok_send.reshape(-1)].add(
            (y_back.astype(jnp.float32) * gate[..., None]).reshape(-1, d))
        return out.astype(PARAM_DTYPE), aux[None]

    dp_spec = P(axis)
    names = set(dp_axes if isinstance(dp_axes, tuple) else (dp_axes,))
    if has_tp:
        names.add(tp_axis)
        wi_spec = P(axis, None, tp_axis)
        wo_spec = P(axis, tp_axis, None)
    else:
        wi_spec = wo_spec = dp_spec
    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(dp_spec, P(), wi_spec, wi_spec, wo_spec),
        out_specs=(dp_spec, dp_spec),
        axis_names=frozenset(names),
        check_vma=False,
    )
    out, aux = fn(x, params["router"], params["wi"], params["wg"],
                  params["wo"])
    out_final = out
    if "shared_wi" in params:
        out_final = out_final + swiglu(x, params["shared_wi"],
                                       params["shared_wg"],
                                       params["shared_wo"])
    return out_final, jnp.mean(aux)
