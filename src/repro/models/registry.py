"""Model assembly: config -> Model (init / loss / prefill / decode_step).

The Model object is the single integration point used by train/, serve/,
launch/dryrun.py and the smoke tests. All apply functions are pure and
jit-friendly; caches are plain dicts with a "pos" scalar.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import transformer as T
from repro.models.layers import (
    PARAM_DTYPE, DistCtx, embed, gelu_ffn, layer_norm,
    lm_logits, matmul, rms_norm, softmax_xent, swiglu,
)

PyTree = Any


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    param_axes: Dict[str, Tuple[Optional[str], ...]]
    init_params: Callable[[jax.Array], PyTree]
    abstract_params: Callable[[], PyTree]
    loss_fn: Callable[..., Tuple[jax.Array, Dict]]
    prefill: Callable[..., Tuple[jax.Array, PyTree]]
    decode_step: Callable[..., Tuple[jax.Array, PyTree]]
    # single-row prefill written into one slot of a batched decode cache
    # (continuous batching refill — see serve/engine.py)
    prefill_into_slot: Callable[..., Tuple[jax.Array, PyTree]]
    init_cache: Callable[..., PyTree]
    cache_axes: Callable[..., PyTree]
    # suffix-only prefill continuing from cached prefix K/V lines (paged
    # K/V cache prefix reuse — serve/kvcache.py). None for families whose
    # prefill is not suffix-separable (recurrent state, vis/enc prefixes,
    # token-count-sensitive MoE capacity).
    prefill_continue: Optional[Callable[..., Tuple[jax.Array, PyTree]]] = None
    # multi-position decode: verify Q=k+1 candidate tokens per row in ONE
    # forward (speculative decoding — serve/spec.py). None for families
    # whose step is not position-batchable (recurrent state folds tokens
    # sequentially; moe capacity is token-count sensitive).
    decode_verify: Optional[Callable[..., Tuple[jax.Array, PyTree]]] = None


# ===========================================================================
# per-family forward passes
# ===========================================================================

def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _moe_apply(cfg: ModelConfig, lp_moe, h, ctx, token_valid=None):
    """Dispatch MoE FFN: shard_map EP (all-to-all token exchange) when the
    plan asks for it and the token count justifies the exchange; otherwise
    the pure-SPMD capacity dispatch. token_valid (flattened (B*S,) bool)
    keeps padding tokens out of the capacity competition (left-padded
    prefill) — only the capacity path supports it."""
    b_, s, d = h.shape
    flat = h.reshape(-1, d)
    use_ep = (ctx is not None and getattr(ctx, "ep_data", False)
              and ctx.mesh is not None and b_ * s >= 4096
              and token_valid is None)
    if use_ep:
        f, aux = moe_lib.moe_ffn_ep(flat, lp_moe, n_experts=cfg.n_experts,
                                    k=cfg.experts_per_token, mesh=ctx.mesh,
                                    dp_axes=ctx.data_axes)
    else:
        f, aux = moe_lib.moe_ffn(flat, lp_moe, n_experts=cfg.n_experts,
                                 k=cfg.experts_per_token,
                                 token_valid=token_valid)
    return f.reshape(b_, s, d), aux


def _dense_stack(cfg: ModelConfig, layers, x, positions, *, remat, moe: bool,
                 window: int = 0, ctx=None):
    """Scan dense/moe decoder layers over x (B,S,D). Returns (x, aux_loss)."""

    def body(carry, lp):
        x = carry
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, _ = T.attn_block(lp["attn"], h, cfg, positions=positions,
                            window=window, ctx=ctx)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if moe:
            f, aux = _moe_apply(cfg, lp["moe"], h, ctx)
        else:
            f = swiglu(h, lp["ffn"]["wi"], lp["ffn"]["wg"], lp["ffn"]["wo"])
            aux = jnp.float32(0.0)
        return x + f, aux

    body = _maybe_remat(body, remat)
    x, auxs = jax.lax.scan(body, x, layers)
    return x, jnp.sum(auxs)


def _dense_prefill_stack(cfg: ModelConfig, layers, x, positions, *,
                         moe: bool, window: int = 0, ctx=None,
                         kv_valid=None):
    """Like _dense_stack but also emits the (k, v) cache per layer."""

    def body(carry, lp):
        x = carry
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, (k, v) = T.attn_block(lp["attn"], h, cfg, positions=positions,
                                 window=window, ctx=ctx, kv_valid=kv_valid)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if moe:
            tv = None if kv_valid is None else kv_valid.reshape(-1)
            f, _ = _moe_apply(cfg, lp["moe"], h, ctx, token_valid=tv)
        else:
            f = swiglu(h, lp["ffn"]["wi"], lp["ffn"]["wg"], lp["ffn"]["wo"])
        return x + f, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, layers)
    return x, ks, vs


def _dense_decode_stack(cfg: ModelConfig, layers, x, cache, *, ctx,
                        window: int = 0, ring: bool = False):
    pos = cache["pos"]

    def body(carry, inp):
        x = carry
        lp, ck, cv = inp
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, (ck, cv) = T.attn_block_decode(lp["attn"], h, cfg, cache_k=ck,
                                          cache_v=cv, pos=pos, window=window,
                                          ctx=ctx, ring=ring)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            f, _ = _moe_apply(cfg, lp["moe"], h, ctx)
        else:
            f = swiglu(h, lp["ffn"]["wi"], lp["ffn"]["wg"], lp["ffn"]["wo"])
        return x + f, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (layers, cache["k"], cache["v"]))
    return x, {"k": ks, "v": vs, "pos": pos + 1, **{k: v for k, v in cache.items() if k not in ("k", "v", "pos")}}


# --- rwkv ------------------------------------------------------------------

def _rwkv_stack(cfg: ModelConfig, layers, x, states, *, decode: bool, remat="none"):
    """states: {"wkv": (L,B,H,D,D) f32, "tm": (L,B,D), "cm": (L,B,D)}."""

    def body(carry, inp):
        x = carry
        lp, wkv, tm_shift, cm_shift = inp
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, tm_last, wkv = T.rwkv_time_mix(lp["tm"], h, tm_shift, wkv, cfg,
                                          decode=decode)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        c, cm_last = T.rwkv_channel_mix(lp["cm"], h, cm_shift)
        x = x + c
        return x, (wkv, tm_last, cm_last)

    if not decode:
        body = _maybe_remat(body, remat)
    x, (wkv, tm, cm) = jax.lax.scan(
        body, x, (layers, states["wkv"], states["tm"], states["cm"]))
    return x, {"wkv": wkv, "tm": tm, "cm": cm, "pos": states["pos"] + x.shape[1]}


# --- hymba -----------------------------------------------------------------

def _hymba_stack(cfg: ModelConfig, layers, x, positions, *, remat,
                 cache=None, decode=False, ctx=None):
    w = cfg.attn_window

    def fuse(lp, attn_out, ssm_out):
        a = rms_norm(attn_out, lp["mamba"]["norm_attn"], cfg.norm_eps)
        s = rms_norm(ssm_out, lp["mamba"]["norm_ssm"], cfg.norm_eps)
        return 0.5 * (a + s)

    if not decode and cache is None:
        def body(carry, lp):
            x = carry
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            a, _ = T.attn_block(lp["attn"], h, cfg, positions=positions,
                                window=w, ctx=ctx)
            m, _, _ = T.mamba_path(lp["mamba"], h, cfg, ctx=ctx)
            x = x + fuse(lp, a, m)
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            f = swiglu(h, lp["ffn"]["wi"], lp["ffn"]["wg"], lp["ffn"]["wo"])
            return x + f, jnp.float32(0.0)
        body = _maybe_remat(body, remat)
        x, _ = jax.lax.scan(body, x, layers)
        return x, None

    if not decode:  # prefill: emit window cache + ssm states
        s = x.shape[1]

        def body(carry, lp):
            x = carry
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            a, (k, v) = T.attn_block(lp["attn"], h, cfg, positions=positions,
                                     window=w, ctx=ctx)
            m, conv_st, h_st = T.mamba_path(lp["mamba"], h, cfg, ctx=ctx)
            x = x + fuse(lp, a, m)
            hh = rms_norm(x, lp["ln2"], cfg.norm_eps)
            f = swiglu(hh, lp["ffn"]["wi"], lp["ffn"]["wg"], lp["ffn"]["wo"])
            # ring-buffer layout: slot i <- position p, p % w == i
            kw = jnp.roll(k[:, -w:], shift=s % w, axis=1)
            vw = jnp.roll(v[:, -w:], shift=s % w, axis=1)
            return x + f, (kw, vw, conv_st, h_st)

        x, (ks, vs, conv, hs) = jax.lax.scan(body, x, layers)
        return x, {"k": ks, "v": vs, "conv": conv, "h": hs,
                   "pos": jnp.int32(s)}

    # decode
    def body(carry, inp):
        x = carry
        lp, ck, cv, conv_st, h_st = inp
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, (ck, cv) = T.attn_block_decode(lp["attn"], h, cfg, cache_k=ck,
                                          cache_v=cv, pos=cache["pos"],
                                          ring=True)
        m, conv_st, h_st = T.mamba_path(lp["mamba"], h, cfg,
                                        conv_state=conv_st, h_state=h_st,
                                        decode=True, ctx=ctx)
        x = x + fuse(lp, a, m)
        hh = rms_norm(x, lp["ln2"], cfg.norm_eps)
        f = swiglu(hh, lp["ffn"]["wi"], lp["ffn"]["wg"], lp["ffn"]["wo"])
        return x + f, (ck, cv, conv_st, h_st)

    x, (ks, vs, conv, hs) = jax.lax.scan(
        body, x, (layers, cache["k"], cache["v"], cache["conv"], cache["h"]))
    return x, {"k": ks, "v": vs, "conv": conv, "h": hs,
               "pos": cache["pos"] + 1}


# --- whisper (encdec) ------------------------------------------------------

def _whisper_encode(cfg: ModelConfig, params, frames):
    """frames: (B, enc_seq, D) stub embeddings -> encoder output."""
    from repro.models.layers import sinusoid_pos
    x = frames + sinusoid_pos(frames.shape[1], cfg.d_model)[None]

    def body(carry, lp):
        x = carry
        h = layer_norm(x, lp["ln1"], lp["ln1b"], cfg.norm_eps)
        q, k, v = T._qkv(lp["attn"], h, cfg)
        a = attn_lib.chunked_causal_attention(q, k, v, causal=False)
        a = matmul(a.reshape(*h.shape[:2], -1), lp["attn"]["wo"])
        x = x + a
        h = layer_norm(x, lp["ln2"], lp["ln2b"], cfg.norm_eps)
        f = gelu_ffn(h, lp["ffn"]["wi"], lp["ffn"]["bi"], lp["ffn"]["wo"],
                     lp["ffn"]["bo"])
        return x + f, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layer_norm(x, params["enc_ln"], params["enc_lnb"], cfg.norm_eps)


def _whisper_dec_stack(cfg: ModelConfig, layers, x, enc_out, positions, *,
                       remat, collect_cache=False, cache=None, decode=False,
                       ctx=None):
    def xattn(lp, h, eo):
        b_, s, _ = h.shape
        hh, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = matmul(h, lp["wq"]).reshape(b_, s, hh, hd)
        k = matmul(eo, lp["wk"]).reshape(b_, eo.shape[1], kv, hd)
        v = matmul(eo, lp["wv"]).reshape(b_, eo.shape[1], kv, hd)
        a = attn_lib.chunked_causal_attention(q, k, v, causal=False)
        return matmul(a.reshape(b_, s, -1), lp["wo"]), (k, v)

    def xattn_cached(lp, h, ck, cv):
        b_ = h.shape[0]
        hh, hd = cfg.n_heads, cfg.head_dim
        q = matmul(h, lp["wq"]).reshape(b_, 1, hh, hd)
        a = attn_lib.decode_attention(q, ck, cv, ck.shape[1])
        return matmul(a.reshape(b_, 1, -1), lp["wo"])

    if not decode:
        def body(carry, lp):
            x = carry
            h = layer_norm(x, lp["ln1"], lp["ln1b"], cfg.norm_eps)
            q, k, v = T._qkv(lp["attn"], h, cfg)
            a = attn_lib.chunked_causal_attention(q, k, v)
            a = matmul(a.reshape(*h.shape[:2], -1), lp["attn"]["wo"])
            x = x + a
            h = layer_norm(x, lp["lnx"], lp["lnxb"], cfg.norm_eps)
            xa, (xk, xv) = xattn(lp["xattn"], h, enc_out)
            x = x + xa
            h = layer_norm(x, lp["ln2"], lp["ln2b"], cfg.norm_eps)
            f = gelu_ffn(h, lp["ffn"]["wi"], lp["ffn"]["bi"],
                         lp["ffn"]["wo"], lp["ffn"]["bo"])
            ys = (k, v, xk, xv) if collect_cache else None
            return x + f, ys

        if not collect_cache:
            body = _maybe_remat(body, remat)
        x, ys = jax.lax.scan(body, x, layers)
        return x, ys

    def body(carry, inp):
        x = carry
        lp, ck, cv, xk, xv = inp
        h = layer_norm(x, lp["ln1"], lp["ln1b"], cfg.norm_eps)
        a, (ck, cv) = T.attn_block_decode(lp["attn"], h, cfg, cache_k=ck,
                                          cache_v=cv, pos=cache["pos"],
                                          rope=False, ctx=ctx)
        x = x + a
        h = layer_norm(x, lp["lnx"], lp["lnxb"], cfg.norm_eps)
        x = x + xattn_cached(lp["xattn"], h, xk, xv)
        h = layer_norm(x, lp["ln2"], lp["ln2b"], cfg.norm_eps)
        f = gelu_ffn(h, lp["ffn"]["wi"], lp["ffn"]["bi"], lp["ffn"]["wo"],
                     lp["ffn"]["bo"])
        return x + f, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (layers, cache["k"], cache["v"], cache["xk"], cache["xv"]))
    return x, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
               "pos": cache["pos"] + 1}


# ===========================================================================
# cache construction
# ===========================================================================

def _mk(shape, dtype, abstract):
    return (jax.ShapeDtypeStruct(shape, dtype) if abstract
            else jnp.zeros(shape, dtype))


def make_cache(cfg: ModelConfig, batch: int, cache_len: int,
               abstract: bool = False) -> PyTree:
    """Decode-state pytree per family. cache_len = max context (the shape
    cell's seq_len); for hybrid the attention part only keeps the window."""
    L, kv, hd, d = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    pos = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
           else jnp.zeros((), jnp.int32))
    if cfg.family in ("dense", "vlm"):
        return {"k": _mk((L, batch, cache_len, kv, hd), PARAM_DTYPE, abstract),
                "v": _mk((L, batch, cache_len, kv, hd), PARAM_DTYPE, abstract),
                "pos": pos}
    if cfg.family == "moe":
        lm = L - cfg.first_k_dense
        c = {"k": _mk((lm, batch, cache_len, kv, hd), PARAM_DTYPE, abstract),
             "v": _mk((lm, batch, cache_len, kv, hd), PARAM_DTYPE, abstract),
             "pos": pos}
        if cfg.first_k_dense:
            kd = cfg.first_k_dense
            c["dk"] = _mk((kd, batch, cache_len, kv, hd), PARAM_DTYPE, abstract)
            c["dv"] = _mk((kd, batch, cache_len, kv, hd), PARAM_DTYPE, abstract)
        return c
    if cfg.family == "ssm":
        h = d // cfg.rwkv_head_dim
        rhd = cfg.rwkv_head_dim
        return {"wkv": _mk((L, batch, h, rhd, rhd), jnp.float32, abstract),
                "tm": _mk((L, batch, d), PARAM_DTYPE, abstract),
                "cm": _mk((L, batch, d), PARAM_DTYPE, abstract),
                "pos": pos}
    if cfg.family == "hybrid":
        w = cfg.attn_window
        ci = 2 * d
        return {"k": _mk((L, batch, w, kv, hd), PARAM_DTYPE, abstract),
                "v": _mk((L, batch, w, kv, hd), PARAM_DTYPE, abstract),
                "conv": _mk((L, batch, mamba_lib.CONV_K - 1, ci), PARAM_DTYPE, abstract),
                "h": _mk((L, batch, ci, cfg.ssm_state), jnp.float32, abstract),
                "pos": pos}
    if cfg.family == "encdec":
        return {"k": _mk((L, batch, cache_len, kv, hd), PARAM_DTYPE, abstract),
                "v": _mk((L, batch, cache_len, kv, hd), PARAM_DTYPE, abstract),
                "xk": _mk((L, batch, cfg.enc_seq, kv, hd), PARAM_DTYPE, abstract),
                "xv": _mk((L, batch, cfg.enc_seq, kv, hd), PARAM_DTYPE, abstract),
                "pos": pos}
    raise ValueError(cfg.family)


def cache_logical_axes(cfg: ModelConfig) -> PyTree:
    """Logical axes for the cache pytree (mirrors make_cache's structure)."""
    kvax = ("layers", "batch", "kv_seq", "kv_heads", None)
    if cfg.family in ("dense", "vlm"):
        return {"k": kvax, "v": kvax, "pos": ()}
    if cfg.family == "moe":
        c = {"k": kvax, "v": kvax, "pos": ()}
        if cfg.first_k_dense:
            c["dk"] = kvax
            c["dv"] = kvax
        return c
    if cfg.family == "ssm":
        return {"wkv": ("layers", "batch", "heads", None, None),
                "tm": ("layers", "batch", "d_model"),
                "cm": ("layers", "batch", "d_model"),
                "pos": ()}
    if cfg.family == "hybrid":
        return {"k": ("layers", "batch", None, "kv_heads", None),
                "v": ("layers", "batch", None, "kv_heads", None),
                "conv": ("layers", "batch", None, "heads"),
                "h": ("layers", "batch", "heads", None),
                "pos": ()}
    if cfg.family == "encdec":
        return {"k": kvax, "v": kvax,
                "xk": ("layers", "batch", None, "kv_heads", None),
                "xv": ("layers", "batch", None, "kv_heads", None),
                "pos": ()}
    raise ValueError(cfg.family)


# ===========================================================================
# build_model
# ===========================================================================

def build_model(cfg: ModelConfig) -> Model:
    """Assemble a `Model` for one config: pure, jit-friendly apply
    functions (init_params / loss_fn / prefill / decode_step /
    prefill_into_slot / init_cache) plus the logical-axis metadata the
    sharding engine consumes (param_axes, cache_axes). One call covers
    every family — dense / moe / ssm / hybrid / encdec / vlm — selected
    by cfg.family.

    Example::

        import jax, repro
        from repro.configs.base import get_config, reduce_config
        cfg = reduce_config(get_config("qwen2-1.5b"), d_model=64, vocab=128)
        model = repro.build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        logits, cache = model.prefill(params, {"tokens": jax.numpy.ones(
            (1, 8), jax.numpy.int32)})
    """
    param_fn = T.build_param_fn(cfg)

    from repro.models.layers import build_params

    def init_params(rng):
        tree, _ = build_params(param_fn, rng, abstract=False)
        return tree

    def abstract_params():
        tree, _ = build_params(param_fn, None, abstract=True)
        return tree

    _, param_axes = build_params(param_fn, None, abstract=True)

    def _logits(params, x):
        x = (layer_norm(x, params["final_norm"], params["final_normb"],
                        cfg.norm_eps)
             if cfg.family == "encdec"
             else rms_norm(x, params["final_norm"], cfg.norm_eps))
        table = params["embed"].T if cfg.tie_embeddings else params["head"]
        return lm_logits(x, table)

    # ---- backbone forward (returns final hidden states) -------------------

    def _backbone_train(params, batch, ctx):
        if cfg.family == "encdec":
            enc_out = _whisper_encode(cfg, params, batch["frames"])
            x = embed(batch["tokens"], params["embed"])
            x = x + params["dec_pos"][: x.shape[1]][None].astype(x.dtype)
            x, _ = _whisper_dec_stack(cfg, params["dec_layers"], x, enc_out,
                                      None, remat=cfg.remat)
            return x, jnp.float32(0.0)

        x = embed(batch["tokens"], params["embed"])
        if cfg.family == "vlm":
            x = jnp.concatenate([batch["vis"].astype(x.dtype), x], axis=1)
        positions = jnp.arange(x.shape[1])

        if cfg.family in ("dense", "vlm"):
            x, aux = _dense_stack(cfg, params["layers"], x, positions,
                                  remat=cfg.remat, moe=False, ctx=ctx)
        elif cfg.family == "moe":
            aux = jnp.float32(0.0)
            if cfg.first_k_dense:
                x, _ = _dense_stack(cfg, params["dense_layers"], x, positions,
                                    remat=cfg.remat, moe=False)
            x, aux2 = _dense_stack(cfg, params["layers"], x, positions,
                                   remat=cfg.remat, moe=True, ctx=ctx)
            aux = aux + aux2
        elif cfg.family == "ssm":
            L, b_, d = cfg.n_layers, x.shape[0], cfg.d_model
            h = d // cfg.rwkv_head_dim
            states = {"wkv": jnp.zeros((L, b_, h, cfg.rwkv_head_dim,
                                        cfg.rwkv_head_dim), jnp.float32),
                      "tm": jnp.zeros((L, b_, d), x.dtype),
                      "cm": jnp.zeros((L, b_, d), x.dtype),
                      "pos": jnp.int32(0)}
            x, _ = _rwkv_stack(cfg, params["layers"], x, states, decode=False,
                               remat=cfg.remat)
            aux = jnp.float32(0.0)
        elif cfg.family == "hybrid":
            x, _ = _hymba_stack(cfg, params["layers"], x, positions,
                                remat=cfg.remat, ctx=ctx)
            aux = jnp.float32(0.0)
        else:
            raise ValueError(cfg.family)
        return x, aux

    # ---- loss --------------------------------------------------------------

    def loss_fn(params, batch, ctx: Optional[DistCtx] = None):
        x, aux = _backbone_train(params, batch, ctx)
        if cfg.family == "vlm":  # loss only on the text positions
            x = x[:, batch["vis"].shape[1]:]
        labels = batch["labels"]

        # chunked cross-entropy: a (B,S,V) f32 logits tensor at 200k vocab
        # and S=4096 would be the largest activation in the model — the
        # xent is evaluated per sequence chunk inside a scan (+checkpoint)
        # so the transient stays (B, chunk, V).
        s = x.shape[1]
        chunk = s
        for c in (512, 256, 128, 64):
            if s % c == 0 and s > c:
                chunk = c
                break

        def xent_chunk(x_c, labels_c):
            logits = _logits(params, x_c)
            mask = (labels_c >= 0).astype(jnp.float32)
            per_tok = softmax_xent(logits, jnp.maximum(labels_c, 0))
            return (per_tok * mask).sum(), mask.sum()

        if chunk == s:
            lsum, msum = xent_chunk(x, labels)
        else:
            n = s // chunk
            xc = x.reshape(x.shape[0], n, chunk, -1).swapaxes(0, 1)
            lc = labels.reshape(labels.shape[0], n, chunk).swapaxes(0, 1)

            def body(carry, inp):
                ls, ms = carry
                dl, dm = jax.checkpoint(xent_chunk)(*inp)
                return (ls + dl, ms + dm), None

            (lsum, msum), _ = jax.lax.scan(
                body, (jnp.float32(0.0), jnp.float32(0.0)), (xc, lc))

        ntok = jnp.maximum(msum, 1.0)
        loss = lsum / ntok
        total = loss + cfg.router_aux_coef * aux
        return total, {"loss": loss, "aux": aux, "ntokens": ntok}

    # ---- prefill -------------------------------------------------------------

    def prefill(params, batch, ctx: Optional[DistCtx] = None, *,
                last_index=None):
        """Full forward; returns (last-token logits, cache).

        batch may carry "pad_lens" — a (B,) int32 count of LEFT pad tokens
        per row (attention families only). Positions then start at 0 on
        each row's first real token and pad key/value columns are masked
        out of every softmax, so a left-padded row produces bit-identical
        final-token logits to the unpadded prompt. MoE caveat: pad tokens
        get zero router weight (moe_ffn token_valid) and can't claim
        expert capacity, but capacity itself stays shape-derived from the
        PADDED token count — when the unpadded batch already overflows an
        expert's capacity, padding raises the ceiling and real-token drops
        can differ, so exact equality there additionally requires the
        padded and unpadded counts to land on the same capacity.

        last_index: optional (traced) index into the sequence axis; the
        returned logits are taken there instead of at -1. Used by
        prefill_into_slot, where a right-padded row's last *real* token is
        not the last position."""

        def _last(x):
            if last_index is None:
                return x[:, -1:]
            return jax.lax.dynamic_slice_in_dim(x, last_index, 1, axis=1)

        pad_lens = batch.get("pad_lens")
        if pad_lens is not None:
            assert cfg.family in ("dense", "moe"), (
                "pad_lens (left-padded prefill) is only defined for pure "
                "attention stacks; recurrent state (ssm/hybrid) consumes "
                f"pads and vlm/encdec prepend non-text tokens: {cfg.family}")

        if cfg.family == "encdec":
            enc_out = _whisper_encode(cfg, params, batch["frames"])
            x = embed(batch["tokens"], params["embed"])
            s = x.shape[1]
            x = x + params["dec_pos"][:s][None].astype(x.dtype)
            x, (ks, vs, xks, xvs) = _whisper_dec_stack(
                cfg, params["dec_layers"], x, enc_out, None,
                remat="none", collect_cache=True)
            cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs,
                     "pos": jnp.int32(s)}
            return _logits(params, _last(x)), cache

        x = embed(batch["tokens"], params["embed"])
        if cfg.family == "vlm":
            x = jnp.concatenate([batch["vis"].astype(x.dtype), x], axis=1)
        s = x.shape[1]
        if pad_lens is None:
            positions = jnp.arange(s)
            kv_valid = None
        else:
            positions = jnp.maximum(
                jnp.arange(s)[None, :] - pad_lens[:, None], 0)
            kv_valid = jnp.arange(s)[None, :] >= pad_lens[:, None]

        if cfg.family in ("dense", "vlm"):
            x, ks, vs = _dense_prefill_stack(cfg, params["layers"], x,
                                             positions, moe=False, ctx=ctx,
                                             kv_valid=kv_valid)
            cache = {"k": ks, "v": vs, "pos": jnp.int32(s)}
        elif cfg.family == "moe":
            cache = {}
            if cfg.first_k_dense:
                x, dk, dv = _dense_prefill_stack(cfg, params["dense_layers"],
                                                 x, positions, moe=False,
                                                 kv_valid=kv_valid)
                cache.update({"dk": dk, "dv": dv})
            x, ks, vs = _dense_prefill_stack(cfg, params["layers"], x,
                                             positions, moe=True, ctx=ctx,
                                             kv_valid=kv_valid)
            cache.update({"k": ks, "v": vs, "pos": jnp.int32(s)})
        elif cfg.family == "ssm":
            L, b_, d = cfg.n_layers, x.shape[0], cfg.d_model
            h = d // cfg.rwkv_head_dim
            states = {"wkv": jnp.zeros((L, b_, h, cfg.rwkv_head_dim,
                                        cfg.rwkv_head_dim), jnp.float32),
                      "tm": jnp.zeros((L, b_, d), x.dtype),
                      "cm": jnp.zeros((L, b_, d), x.dtype),
                      "pos": jnp.int32(0)}
            x, cache = _rwkv_stack(cfg, params["layers"], x, states,
                                   decode=False)
        elif cfg.family == "hybrid":
            x, cache = _hymba_stack(cfg, params["layers"], x, positions,
                                    remat="none", cache={}, decode=False,
                                    ctx=ctx)
        else:
            raise ValueError(cfg.family)
        return _logits(params, _last(x)), cache

    # ---- decode --------------------------------------------------------------

    def decode_step(params, cache, tokens, ctx: Optional[DistCtx] = None):
        """tokens: (B, 1). Returns (logits (B,1,V) f32, new cache).

        cache["pos"] may be a scalar (lockstep decode) or a (B,) vector
        (slot scheduler: every row at its own offset)."""
        x = embed(tokens, params["embed"])
        if cfg.family == "encdec":
            pe = params["dec_pos"][cache["pos"]].astype(x.dtype)
            x = x + (pe[:, None] if pe.ndim == 2 else pe[None, None])
            x, cache = _whisper_dec_stack(cfg, params["dec_layers"], x, None,
                                          None, remat="none", cache=cache,
                                          decode=True, ctx=ctx)
        elif cfg.family in ("dense", "vlm"):
            x, cache = _dense_decode_stack(cfg, params["layers"], x, cache,
                                           ctx=ctx)
        elif cfg.family == "moe":
            pos = cache["pos"]
            if cfg.first_k_dense:
                dsub = {"k": cache["dk"], "v": cache["dv"], "pos": pos}
                x, dsub = _dense_decode_stack(cfg, params["dense_layers"], x,
                                              dsub, ctx=ctx)
                cache = {**cache, "dk": dsub["k"], "dv": dsub["v"]}
            sub = {"k": cache["k"], "v": cache["v"], "pos": pos}
            x, sub = _dense_decode_stack(cfg, params["layers"], x, sub,
                                         ctx=ctx)
            cache = {**cache, "k": sub["k"], "v": sub["v"], "pos": pos + 1}
        elif cfg.family == "ssm":
            x, cache = _rwkv_stack(cfg, params["layers"], x, cache,
                                   decode=True)
        elif cfg.family == "hybrid":
            x, cache = _hymba_stack(cfg, params["layers"], x, None,
                                    remat="none", cache=cache, decode=True,
                                    ctx=ctx)
        else:
            raise ValueError(cfg.family)
        return _logits(params, x), cache

    # ---- speculative verify (multi-position decode) --------------------------

    def decode_verify(params, cache, tokens, ctx: Optional[DistCtx] = None):
        """tokens: (B, Q) — per row, the last committed token followed by
        Q-1 draft candidates. Returns (logits (B,Q,V) f32, new cache):
        logits[:, j] is the target distribution AFTER consuming tokens[:, j],
        exactly what Q sequential decode_step calls would have produced.

        cache["pos"] must be the (B,) per-row slot-scheduler layout; row
        writes land at pos..pos+Q-1 and pos advances by Q (the speculated
        tip). The caller rolls pos back to the last ACCEPTED line after the
        accept decision — see serve/spec.py for the contract."""
        assert cfg.family == "dense", (
            "decode_verify is only defined for pure-attention decoder "
            f"stacks (position-batchable step): {cfg.family}")
        x = embed(tokens, params["embed"])
        pos = cache["pos"]

        def body(carry, inp):
            x = carry
            lp, ck, cv = inp
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            a, (ck, cv) = T.attn_block_decode_k(lp["attn"], h, cfg,
                                                cache_k=ck, cache_v=cv,
                                                pos=pos)
            x = x + a
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            f = swiglu(h, lp["ffn"]["wi"], lp["ffn"]["wg"], lp["ffn"]["wo"])
            return x + f, (ck, cv)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs, "pos": pos + tokens.shape[1]}
        return _logits(params, x), new_cache

    # ---- slot refill (continuous batching) -----------------------------------

    def prefill_into_slot(params, cache, slot, batch, prompt_len,
                          ctx: Optional[DistCtx] = None):
        """Prefill ONE request (batch row of size 1) and overwrite `slot`'s
        cache lines in a batched decode cache, so a new request joins a
        mid-flight batch without retracing or disturbing its batch-mates.

        cache: a batched decode cache whose "pos" is a (B,) per-row vector
          (the slot scheduler's layout — see serve/engine.py).
        slot: (traced) row index to overwrite.
        batch: single-row prefill inputs; "tokens" is (1, P). P may exceed
          the real prompt (right padding to a shape bucket): pad lines land
          beyond prompt_len, stay masked by the per-row length, and are
          overwritten as decode advances. For ssm/hybrid (recurrent state
          folds every token in) and moe (capacity dispatch is token-count
          sensitive) P must equal the real prompt length.
        prompt_len: (traced) number of valid leading positions in the row
          — the slot's pos after admission; logits are taken at
          prompt_len - 1 (the last real token).

        Returns (logits (1,1,V), new cache). Every cache leaf has layout
        (layers, batch, ...), so the write is one dynamic_update_slice at
        (0, slot, 0, ...) per leaf.
        """
        logits, row = prefill(params, batch, ctx, last_index=prompt_len - 1)
        new = {}
        for key, full in cache.items():
            if key == "pos":
                new[key] = full.at[slot].set(
                    jnp.asarray(prompt_len, full.dtype))
                continue
            upd = row[key].astype(full.dtype)
            starts = (0, slot) + (0,) * (full.ndim - 2)
            new[key] = jax.lax.dynamic_update_slice(full, upd, starts)
        return logits, new

    # ---- prefix-continue prefill (paged K/V prefix reuse) --------------------

    def prefill_continue(params, cache, slot, batch, start, n_real,
                         ctx: Optional[DistCtx] = None):
        """Prefill ONLY the unseen suffix of a request whose first `start`
        prompt positions already sit in `slot`'s cache rows (restored from
        shared prefix pages — serve/kvcache.py). Dense family only: the
        suffix hidden states depend on the prefix exclusively through the
        cached K/V (causal attention), so continuing from restored lines is
        bit-identical to a cold full-prompt prefill.

        batch["tokens"]: (1, S) suffix tokens, right-padded to a shape
        bucket like prefill_into_slot (pad lines land beyond the real
        suffix and stay masked by the per-row pos). start: (traced) count
        of already-cached prompt positions. n_real: (traced) real suffix
        length; logits are taken at suffix index n_real - 1 and the slot's
        pos becomes start + n_real.
        """
        assert cfg.family == "dense", (
            "prefill_continue is only defined for pure-attention decoder "
            f"stacks (suffix-separable prefill): {cfg.family}")
        x = embed(batch["tokens"], params["embed"])
        s = x.shape[1]
        positions = start + jnp.arange(s)

        def body(carry, inp):
            x = carry
            lp, ck, cv = inp
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            a, (ck, cv) = T.attn_block_continue(
                lp["attn"], h, cfg, cache_k=ck, cache_v=cv, slot=slot,
                start=start, positions=positions, ctx=ctx)
            x = x + a
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            f = swiglu(h, lp["ffn"]["wi"], lp["ffn"]["wg"], lp["ffn"]["wo"])
            return x + f, (ck, cv)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs,
                     "pos": cache["pos"].at[slot].set(
                         jnp.asarray(start + n_real, cache["pos"].dtype))}
        last = jax.lax.dynamic_slice_in_dim(x, n_real - 1, 1, axis=1)
        return _logits(params, last), new_cache

    return Model(
        cfg=cfg,
        param_axes=param_axes,
        init_params=init_params,
        abstract_params=abstract_params,
        loss_fn=loss_fn,
        prefill=prefill,
        decode_step=decode_step,
        prefill_into_slot=prefill_into_slot,
        init_cache=functools.partial(make_cache, cfg),
        cache_axes=functools.partial(cache_logical_axes, cfg),
        prefill_continue=(prefill_continue if cfg.family == "dense"
                          else None),
        decode_verify=(decode_verify if cfg.family == "dense" else None),
    )
