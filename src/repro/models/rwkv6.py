"""RWKV-6 "Finch": attention-free time-mix with data-dependent decay
(arXiv:2404.05892) + channel-mix.

Two WKV evaluators:
  * `wkv6_scan`     — sequential lax.scan over time (the oracle; also the
                      decode path, where it is exact and O(1) per token);
  * `wkv6_chunked`  — chunked parallel form (GLA-style): within a chunk the
                      per-channel cumulative decays turn the recurrence into
                      a masked matmul; across chunks only the (H, Dk, Dv)
                      state is carried. This is the train/prefill path — it
                      converts VPU-bound recurrence into MXU matmuls, which
                      is exactly the paper's v4 "raise arithmetic intensity"
                      move applied to an SSM (see DESIGN.md).

Recurrence (per head, k-dim i, v-dim j):
    y_t[j] = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] k_t[i] v_t[j])
    S_t    = diag(w_t) S_{t-1} + k_t v_t^T ,  w_t = exp(-exp(wlog_t))
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_scan(r, k, v, w, u, state):
    """Sequential oracle. r/k/v/w: (B,T,H,D) f32; u: (H,D); state: (B,H,D,D).
    Returns (y (B,T,H,D), new_state). All math f32."""
    def step(s, inp):
        rt, kt, vt, wt = inp                       # (B,H,D)
        kv = kt[..., :, None] * vt[..., None, :]   # (B,H,Dk,Dv)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def wkv6_chunked(r, k, v, w, u, state, *, chunk: int = 64):
    """Chunked parallel WKV6. Same signature/semantics as wkv6_scan.

    Within a chunk (length C) with cumulative log-decay La_t = sum_{s<=t} log w_s:
      inter:  y_t += (r_t * exp(La_{t-1})) @ S_0
      intra:  y_t += sum_{s<t} [r_t . (exp(La_{t-1}-La_s) * k_s)] v_s
      bonus:  y_t += (r_t . (u * k_t)) v_t
      carry:  S_C = diag(exp(La_C)) S_0 + sum_s (exp(La_C - La_s) * k_s) v_s^T
    """
    b, t, h, d = r.shape
    assert t % chunk == 0, (t, chunk)
    n = t // chunk

    def resh(x):
        return x.reshape(b, n, chunk, h, d).transpose(1, 0, 3, 2, 4)  # (n,B,H,C,D)

    rc, kc, vc, wc = map(resh, (r, k, v, w))
    logw = jnp.log(jnp.maximum(wc, 1e-38))
    la = jnp.cumsum(logw, axis=3)                          # (n,B,H,C,D) inclusive
    # stability clamp (see mamba.ssm_chunked): keeps exp(-la) finite in f32;
    # pairwise decay factors stay correct to ~e-60 absolute.
    la = jnp.maximum(la, -60.0)

    def one_chunk(s, inp):
        rcc, kcc, vcc, lac = inp                           # (B,H,C,D)
        # exclusive cumulative decay (shift right by one step)
        la_excl = jnp.pad(lac[:, :, :-1], ((0, 0), (0, 0), (1, 0), (0, 0)))
        r_t = rcc * jnp.exp(la_excl)                       # r-tilde
        k_s = kcc * jnp.exp(-lac)                          # k-tilde
        # inter-chunk: contribution of the carried state
        y = jnp.einsum("bhcd,bhde->bhce", r_t, s)
        # intra-chunk: strictly-lower-triangular "attention" matmul (MXU)
        att = jnp.einsum("bhcd,bhsd->bhcs", r_t, k_s)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        y = y + jnp.einsum("bhcs,bhse->bhce", att, vcc)
        # bonus (current token, u-weighted)
        bonus = jnp.sum(rcc * u[None, :, None, :] * kcc, -1, keepdims=True)
        y = y + bonus * vcc
        # carry the state across the chunk boundary
        la_last = lac[:, :, -1:, :]                        # (B,H,1,D)
        k_carry = kcc * jnp.exp(la_last - lac)             # (B,H,C,D)
        s = jnp.exp(la_last[:, :, 0, :, None]) * s + \
            jnp.einsum("bhcd,bhce->bhde", k_carry, vcc)
        return s, y

    state, ys = jax.lax.scan(one_chunk, state, (rc, kc, vc, la))
    ys = ys.transpose(1, 0, 3, 2, 4).reshape(b, t, h, d)   # back to (B,T,H,D)
    return ys, state


def wkv6_decode(r, k, v, w, u, state):
    """One-token decode. r/k/v/w: (B,H,D); state (B,H,Dk,Dv) f32."""
    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhi,bhij->bhj", r, state + u[None, :, :, None] * kv)
    state = w[..., :, None] * state + kv
    return y, state


# ---------------------------------------------------------------------------
# full RWKV6 block (time-mix + channel-mix) — used by transformer.py
# ---------------------------------------------------------------------------

LORA_MIX = 32     # TIME_MIX_EXTRA_DIM
LORA_DECAY = 64   # TIME_DECAY_EXTRA_DIM


def ddlerp(x, x_prev, mu, lora_a, lora_b):
    """Data-dependent lerp (the Finch token-shift). x,x_prev: (B,T,D)."""
    diff = x_prev - x
    xx = x + diff * mu[0]
    delta = jnp.tanh(xx.astype(jnp.float32) @ lora_a.astype(jnp.float32))
    delta = (delta @ lora_b.astype(jnp.float32)).astype(x.dtype)
    return x + diff * (mu[1] + delta)


def token_shift(x, shift_state):
    """x: (B,T,D); shift_state: (B,D) = last token of previous segment."""
    prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1]], axis=1)
    return prev, x[:, -1]
