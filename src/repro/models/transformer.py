"""The model core: one scanned-layer decoder covering all assigned families.

Families and their block structure (cfg.family):
  dense / vlm : [RMSNorm → GQA+RoPE → RMSNorm → SwiGLU] ×L
  moe         : same, FFN replaced by routed experts (+shared); optional
                leading dense layers (deepseek first_k_dense)
  ssm         : RWKV6 [time-mix (WKV6) → channel-mix] ×L
  hybrid      : Hymba [RMSNorm → (GQA-SWA ∥ Mamba) fused → RMSNorm → SwiGLU] ×L
  encdec      : Whisper [enc: LN → MHA → LN → GELU-FFN] ×Le then
                [dec: LN → causal MHA → LN → cross MHA → LN → GELU-FFN] ×Ld

All layer stacks are `jax.lax.scan`s over stacked params (leading "layers"
axis) so a 64-layer model lowers to one compact while-loop — essential for
the 40-cell × 2-mesh dry-run compile budget.

Three entry points per model (see ModelConfig shapes):
  loss_fn(params, batch)                 → train_4k
  prefill(params, batch)                 → prefill_32k (returns cache+logits)
  decode_step(params, cache, tokens)     → decode_32k / long_500k
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.layers import (
    PARAM_DTYPE, DistCtx, ParamBuilder, apply_rope, embed, matmul,
    matmul_rp, swiglu,
)

PyTree = Any


# ===========================================================================
# parameter construction
# ===========================================================================

def _attn_params(b: ParamBuilder, pre: str, L: int, cfg: ModelConfig,
                 d: Optional[int] = None) -> Dict:
    d = d or cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": b.param(f"{pre}/wq", (L, d, h * hd), ("layers", "d_model", "heads")),
        "wk": b.param(f"{pre}/wk", (L, d, kv * hd), ("layers", "d_model", "kv_heads")),
        "wv": b.param(f"{pre}/wv", (L, d, kv * hd), ("layers", "d_model", "kv_heads")),
        "wo": b.param(f"{pre}/wo", (L, h * hd, d), ("layers", "heads", "d_model")),
    }
    if cfg.qkv_bias:
        p["bq"] = b.param(f"{pre}/bq", (L, h * hd), ("layers", "heads"), "zeros")
        p["bk"] = b.param(f"{pre}/bk", (L, kv * hd), ("layers", "kv_heads"), "zeros")
        p["bv"] = b.param(f"{pre}/bv", (L, kv * hd), ("layers", "kv_heads"), "zeros")
    return p


def _ffn_params(b: ParamBuilder, pre: str, L: int, d: int, f: int,
                act: str) -> Dict:
    if act == "swiglu":
        return {
            "wi": b.param(f"{pre}/wi", (L, d, f), ("layers", "d_model", "d_ff")),
            "wg": b.param(f"{pre}/wg", (L, d, f), ("layers", "d_model", "d_ff")),
            "wo": b.param(f"{pre}/wo", (L, f, d), ("layers", "d_ff", "d_model")),
        }
    return {
        "wi": b.param(f"{pre}/wi", (L, d, f), ("layers", "d_model", "d_ff")),
        "bi": b.param(f"{pre}/bi", (L, f), ("layers", "d_ff"), "zeros"),
        "wo": b.param(f"{pre}/wo", (L, f, d), ("layers", "d_ff", "d_model")),
        "bo": b.param(f"{pre}/bo", (L, d), ("layers", "d_model"), "zeros"),
    }


def _moe_params(b: ParamBuilder, pre: str, L: int, cfg: ModelConfig) -> Dict:
    d, e, f = cfg.d_model, cfg.n_experts, (cfg.moe_d_ff or cfg.d_ff)
    p = {
        "router": b.param(f"{pre}/router", (L, d, e), ("layers", "d_model", None)),
        "wi": b.param(f"{pre}/wi", (L, e, d, f), ("layers", "experts", "d_model", "d_ff")),
        "wg": b.param(f"{pre}/wg", (L, e, d, f), ("layers", "experts", "d_model", "d_ff")),
        "wo": b.param(f"{pre}/wo", (L, e, f, d), ("layers", "experts", "d_ff", "d_model")),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_wi"] = b.param(f"{pre}/shared_wi", (L, d, fs), ("layers", "d_model", "d_ff"))
        p["shared_wg"] = b.param(f"{pre}/shared_wg", (L, d, fs), ("layers", "d_model", "d_ff"))
        p["shared_wo"] = b.param(f"{pre}/shared_wo", (L, fs, d), ("layers", "d_ff", "d_model"))
    return p


def _rwkv_params(b: ParamBuilder, pre: str, L: int, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    km, kd = rwkv_lib.LORA_MIX, rwkv_lib.LORA_DECAY
    f = cfg.d_ff
    return {
        "ln1": b.param(f"{pre}/ln1", (L, d), ("layers", "d_model"), "ones"),
        "ln2": b.param(f"{pre}/ln2", (L, d), ("layers", "d_model"), "ones"),
        "tm": {
            "mu_x": b.param(f"{pre}/tm/mu_x", (L, d), ("layers", "d_model")),
            # r,k,v,w,g stream mus
            "mu_5": b.param(f"{pre}/tm/mu_5", (L, 5, d), ("layers", None, "d_model")),
            "lora_a": b.param(f"{pre}/tm/lora_a", (L, d, 5 * km), ("layers", "d_model", None)),
            "lora_b": b.param(f"{pre}/tm/lora_b", (L, 5, km, d), ("layers", None, None, "d_model")),
            "td_a": b.param(f"{pre}/tm/td_a", (L, d, kd), ("layers", "d_model", None)),
            "td_b": b.param(f"{pre}/tm/td_b", (L, kd, d), ("layers", None, "d_model")),
            "w0": b.param(f"{pre}/tm/w0", (L, d), ("layers", "d_model")),
            "u": b.param(f"{pre}/tm/u", (L, h, hd), ("layers", "heads", None)),
            "wr": b.param(f"{pre}/tm/wr", (L, d, d), ("layers", "d_model", "heads")),
            "wk": b.param(f"{pre}/tm/wk", (L, d, d), ("layers", "d_model", "heads")),
            "wv": b.param(f"{pre}/tm/wv", (L, d, d), ("layers", "d_model", "heads")),
            "wg": b.param(f"{pre}/tm/wg", (L, d, d), ("layers", "d_model", "heads")),
            "wo": b.param(f"{pre}/tm/wo", (L, d, d), ("layers", "heads", "d_model")),
            "ln_x": b.param(f"{pre}/tm/ln_x", (L, d), ("layers", "d_model"), "ones"),
        },
        "cm": {
            "mu_k": b.param(f"{pre}/cm/mu_k", (L, d), ("layers", "d_model")),
            "mu_r": b.param(f"{pre}/cm/mu_r", (L, d), ("layers", "d_model")),
            "wk": b.param(f"{pre}/cm/wk", (L, d, f), ("layers", "d_model", "d_ff")),
            "wv": b.param(f"{pre}/cm/wv", (L, f, d), ("layers", "d_ff", "d_model")),
            "wr": b.param(f"{pre}/cm/wr", (L, d, d), ("layers", "d_model", "d_model")),
        },
    }


def _mamba_params(b: ParamBuilder, pre: str, L: int, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    ci = 2 * d                      # d_inner
    n = cfg.ssm_state
    dt_rank = max(1, d // 16)
    return {
        "in_proj": b.param(f"{pre}/in_proj", (L, d, 2 * ci), ("layers", "d_model", "heads")),
        "conv_w": b.param(f"{pre}/conv_w", (L, mamba_lib.CONV_K, ci), ("layers", None, "heads")),
        "x_proj": b.param(f"{pre}/x_proj", (L, ci, dt_rank + 2 * n), ("layers", "heads", None)),
        "dt_proj": b.param(f"{pre}/dt_proj", (L, dt_rank, ci), ("layers", None, "heads")),
        # dt ~= softplus(-4.6) ~= 0.01 at init (standard mamba dt range)
        "dt_bias": b.param(f"{pre}/dt_bias", (L, ci), ("layers", "heads"), "const:-4.6"),
        "a_log": b.param(f"{pre}/a_log", (L, ci, n), ("layers", "heads", None), "a_log"),
        "d": b.param(f"{pre}/d", (L, ci), ("layers", "heads"), "ones"),
        "out_proj": b.param(f"{pre}/out_proj", (L, ci, d), ("layers", "heads", "d_model")),
        "norm_attn": b.param(f"{pre}/norm_attn", (L, d), ("layers", "d_model"), "ones"),
        "norm_ssm": b.param(f"{pre}/norm_ssm", (L, d), ("layers", "d_model"), "ones"),
    }


def build_param_fn(cfg: ModelConfig) -> Callable[[ParamBuilder], Dict]:
    """Returns a builder fn producing the full param tree for cfg."""
    d, v, L = cfg.d_model, cfg.vocab_size, cfg.n_layers

    def fn(b: ParamBuilder) -> Dict:
        p: Dict = {"embed": b.param("embed", (v, d), ("vocab", "d_model"))}

        if cfg.family in ("dense", "vlm"):
            p["layers"] = {
                "ln1": b.param("layers/ln1", (L, d), ("layers", "d_model"), "ones"),
                "ln2": b.param("layers/ln2", (L, d), ("layers", "d_model"), "ones"),
                "attn": _attn_params(b, "layers/attn", L, cfg),
                "ffn": _ffn_params(b, "layers/ffn", L, d, cfg.d_ff, cfg.act),
            }
        elif cfg.family == "moe":
            k = cfg.first_k_dense
            if k:
                p["dense_layers"] = {
                    "ln1": b.param("dense_layers/ln1", (k, d), ("layers", "d_model"), "ones"),
                    "ln2": b.param("dense_layers/ln2", (k, d), ("layers", "d_model"), "ones"),
                    "attn": _attn_params(b, "dense_layers/attn", k, cfg),
                    "ffn": _ffn_params(b, "dense_layers/ffn", k, d, cfg.d_ff, cfg.act),
                }
            lm = L - k
            p["layers"] = {
                "ln1": b.param("layers/ln1", (lm, d), ("layers", "d_model"), "ones"),
                "ln2": b.param("layers/ln2", (lm, d), ("layers", "d_model"), "ones"),
                "attn": _attn_params(b, "layers/attn", lm, cfg),
                "moe": _moe_params(b, "layers/moe", lm, cfg),
            }
        elif cfg.family == "ssm":
            p["layers"] = _rwkv_params(b, "layers", L, cfg)
        elif cfg.family == "hybrid":
            p["layers"] = {
                "ln1": b.param("layers/ln1", (L, d), ("layers", "d_model"), "ones"),
                "ln2": b.param("layers/ln2", (L, d), ("layers", "d_model"), "ones"),
                "attn": _attn_params(b, "layers/attn", L, cfg),
                "mamba": _mamba_params(b, "layers/mamba", L, cfg),
                "ffn": _ffn_params(b, "layers/ffn", L, d, cfg.d_ff, cfg.act),
            }
        elif cfg.family == "encdec":
            Le = cfg.n_enc_layers
            p["enc_layers"] = {
                "ln1": b.param("enc_layers/ln1", (Le, d), ("layers", "d_model"), "ones"),
                "ln1b": b.param("enc_layers/ln1b", (Le, d), ("layers", "d_model"), "zeros"),
                "ln2": b.param("enc_layers/ln2", (Le, d), ("layers", "d_model"), "ones"),
                "ln2b": b.param("enc_layers/ln2b", (Le, d), ("layers", "d_model"), "zeros"),
                "attn": _attn_params(b, "enc_layers/attn", Le, cfg),
                "ffn": _ffn_params(b, "enc_layers/ffn", Le, d, cfg.d_ff, "gelu"),
            }
            p["dec_layers"] = {
                "ln1": b.param("dec_layers/ln1", (L, d), ("layers", "d_model"), "ones"),
                "ln1b": b.param("dec_layers/ln1b", (L, d), ("layers", "d_model"), "zeros"),
                "lnx": b.param("dec_layers/lnx", (L, d), ("layers", "d_model"), "ones"),
                "lnxb": b.param("dec_layers/lnxb", (L, d), ("layers", "d_model"), "zeros"),
                "ln2": b.param("dec_layers/ln2", (L, d), ("layers", "d_model"), "ones"),
                "ln2b": b.param("dec_layers/ln2b", (L, d), ("layers", "d_model"), "zeros"),
                "attn": _attn_params(b, "dec_layers/attn", L, cfg),
                "xattn": _attn_params(b, "dec_layers/xattn", L, cfg),
                "ffn": _ffn_params(b, "dec_layers/ffn", L, d, cfg.d_ff, "gelu"),
            }
            p["enc_ln"] = b.param("enc_ln", (d,), ("d_model",), "ones")
            p["enc_lnb"] = b.param("enc_lnb", (d,), ("d_model",), "zeros")
            p["dec_pos"] = b.param("dec_pos", (32768, d), (None, "d_model"))
        else:
            raise ValueError(cfg.family)

        p["final_norm"] = b.param("final_norm", (d,), ("d_model",), "ones")
        if cfg.family == "encdec":
            p["final_normb"] = b.param("final_normb", (d,), ("d_model",), "zeros")
        if not cfg.tie_embeddings:
            p["head"] = b.param("head", (d, v), ("d_model", "vocab"))
        return p

    return fn


# ===========================================================================
# blocks (apply)
# ===========================================================================

def _qkv(lp, x, cfg: ModelConfig):
    b_, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = matmul(x, lp["wq"])
    k = matmul(x, lp["wk"])
    v = matmul(x, lp["wv"])
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(q.dtype)
        k = k + lp["bk"].astype(k.dtype)
        v = v + lp["bv"].astype(v.dtype)
    return (q.reshape(b_, s, h, hd), k.reshape(b_, s, kv, hd),
            v.reshape(b_, s, kv, hd))


def attn_block(lp, x, cfg: ModelConfig, *, positions, window=0, rope=True,
               ctx=None, kv_valid=None):
    """Full-sequence attention (train/prefill). Returns (out, (k, v)).

    positions: (S,) shared, or (B,S) per-row (left-padded prefill, where
    each row's real tokens start at its own offset). kv_valid: optional
    (B,S) bool marking real (non-pad) key/value columns."""
    b_, s, _ = x.shape
    q, k, v = _qkv(lp, x, cfg)
    if rope and cfg.rope_theta:
        # (B,S) positions broadcast over the head axis of the (B,H,S,Hd)
        # rope input as (B,1,S)
        pos_r = positions if positions.ndim == 1 else positions[:, None]
        q = apply_rope(q.swapaxes(1, 2), pos_r, cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), pos_r, cfg.rope_theta).swapaxes(1, 2)
    if (cfg.use_flash_attention and window == 0 and s % 256 == 0
            and kv_valid is None):
        # Pallas flash kernel: VMEM-blocked online softmax — no (S,S)
        # score tensor ever reaches HBM (EXPERIMENTS.md §Perf iteration 2).
        # On CPU this runs in interpret mode (tests); the dry-run models its
        # traffic analytically (launch/dryrun.py flash adjustment) because
        # the interpret-mode while-loop carries full arrays with per-step
        # copies that misrepresent the kernel's true HBM traffic.
        out = attn_lib.flash_attention_spmd(q, k, v, ctx, causal=True)
    else:
        out = attn_lib.chunked_causal_attention(q, k, v, window=window,
                                                kv_valid=kv_valid)
    out = matmul_rp(out.reshape(b_, s, -1), lp["wo"])
    return out, (k, v)


def attn_block_decode(lp, x, cfg: ModelConfig, *, cache_k, cache_v, pos,
                      window=0, rope=True, ctx: Optional[DistCtx] = None,
                      ring=False):
    """One-token attention against a cache. cache_k/v: (B,L,KvH,Hd).

    pos is the write position — a scalar shared by all rows (the classic
    lockstep decode) or a (B,) vector when every batch row is at its own
    offset (the serve engine's slot scheduler, where refilled slots join
    mid-flight). Per-row writes use a one-hot select instead of
    dynamic_update_slice so each row lands on its own line."""
    b_, s, _ = x.shape
    assert s == 1
    per_row = jnp.ndim(pos) == 1
    q, k, v = _qkv(lp, x, cfg)
    if rope and cfg.rope_theta:
        # scalar pos -> one shared position; vector pos -> (B,1,1) so the
        # angle table broadcasts over heads per row
        pvec = pos[:, None, None] if per_row else jnp.full((1,), pos, jnp.int32)
        q = apply_rope(q.swapaxes(1, 2), pvec, cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), pvec, cfg.rope_theta).swapaxes(1, 2)
    lcache = cache_k.shape[1]
    slot = jnp.mod(pos, lcache) if ring else pos
    if per_row:
        oh = jnp.arange(lcache)[None, :] == slot[:, None]      # (B, L)
        cache_k = jnp.where(oh[:, :, None, None], k, cache_k)
        cache_v = jnp.where(oh[:, :, None, None], v, cache_v)
    else:
        cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    cache_len = pos + 1
    if ring:
        # ring buffer (sliding window): every slot <= cache_len-1 is valid;
        # window masking is implicit in the buffer size
        eff_len = jnp.minimum(cache_len, lcache)
        out = attn_lib.decode_attention(q, cache_k, cache_v, eff_len)
    elif ctx is not None and ctx.kv_seq_shard and not per_row:
        out = attn_lib.flash_decode_sharded(q, cache_k, cache_v, cache_len,
                                            ctx=ctx, window=window)
    else:
        out = attn_lib.decode_attention(q, cache_k, cache_v, cache_len,
                                        window=window)
    out = matmul_rp(out.reshape(b_, 1, -1), lp["wo"])
    return out, (cache_k, cache_v)


def attn_block_decode_k(lp, x, cfg: ModelConfig, *, cache_k, cache_v, pos,
                        window=0, rope=True):
    """Q-token verify attention against a cache (speculative decoding —
    serve/spec.py). x: (B,Q,D) holds the Q=k+1 candidate tokens per row;
    pos: (B,) per-row write offset of the FIRST candidate (identical to the
    plain decode write position, so a spec round that accepts zero drafts
    writes the same line plain decode would have).

    All Q K/V lines land at pos..pos+Q-1 via Q one-hot selects (a static
    python loop — Q is small), then one multi-query causal attention where
    candidate j sees cache positions <= pos+j. Rejected candidates' lines
    stay in the buffer beyond the rolled-back position; they are invisible
    (cache_len masking) and are overwritten in the step that first reaches
    them (write-at-pos precedes the mask that includes pos)."""
    b_, qn, _ = x.shape
    q, k, v = _qkv(lp, x, cfg)
    pvec = pos[:, None] + jnp.arange(qn)[None, :]          # (B,Q) absolute
    if rope and cfg.rope_theta:
        q = apply_rope(q.swapaxes(1, 2), pvec[:, None], cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), pvec[:, None], cfg.rope_theta).swapaxes(1, 2)
    lcache = cache_k.shape[1]
    for j in range(qn):
        oh = jnp.arange(lcache)[None, :] == (pos + j)[:, None]    # (B, L)
        cache_k = jnp.where(oh[:, :, None, None], k[:, j:j + 1], cache_k)
        cache_v = jnp.where(oh[:, :, None, None], v[:, j:j + 1], cache_v)
    out = attn_lib.decode_attention_multi(q, cache_k, cache_v, pos + qn,
                                          window=window)
    out = matmul_rp(out.reshape(b_, qn, -1), lp["wo"])
    return out, (cache_k, cache_v)


def attn_block_continue(lp, x, cfg: ModelConfig, *, cache_k, cache_v, slot,
                        start, positions, ctx=None):
    """Suffix attention for prefix-continue prefill (paged K/V cache with
    prefix reuse — serve/kvcache.py). x: (1,S,D) suffix hidden states whose
    first token sits at absolute position `start`; cache_k/v: batched
    (B,Lcache,KvH,Hd) slot caches whose `slot` row already holds the first
    `start` K/V lines (restored prefix pages).

    The suffix k/v are written into the slot row at `start` and the queries
    attend against the FULL row with q_offset=start: keys at absolute
    positions > each query are causally masked, so stale lines beyond the
    written region contribute exact-0 softmax weight and the output is
    bit-identical to a cold full-prompt prefill of the same row (the
    chunked_causal_attention masking contract). Returns (out, (ck, cv))
    with the slot row updated.
    """
    b_, s, _ = x.shape
    assert b_ == 1
    q, k, v = _qkv(lp, x, cfg)
    if cfg.rope_theta:
        q = apply_rope(q.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
    row_k = jax.lax.dynamic_index_in_dim(cache_k, slot, axis=0, keepdims=True)
    row_v = jax.lax.dynamic_index_in_dim(cache_v, slot, axis=0, keepdims=True)
    row_k = jax.lax.dynamic_update_slice(row_k, k.astype(row_k.dtype),
                                         (0, start, 0, 0))
    row_v = jax.lax.dynamic_update_slice(row_v, v.astype(row_v.dtype),
                                         (0, start, 0, 0))
    out = attn_lib.chunked_causal_attention(q, row_k, row_v, q_offset=start)
    out = matmul_rp(out.reshape(b_, s, -1), lp["wo"])
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, row_k, (slot,) + (0,) * (cache_k.ndim - 1))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, row_v, (slot,) + (0,) * (cache_v.ndim - 1))
    return out, (cache_k, cache_v)


def rwkv_time_mix(tm, x, shift_in, wkv_state, cfg: ModelConfig, *,
                  decode: bool):
    """RWKV6 time-mix. x: (B,T,D). Returns (out, last_token, new_state)."""
    b_, t, d = x.shape
    h = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    x_prev, last = rwkv_lib.token_shift(x, shift_in)

    diff = x_prev - x
    xx = x + diff * tm["mu_x"].astype(x.dtype)
    delta = jnp.tanh(xx.astype(jnp.float32) @ tm["lora_a"].astype(jnp.float32))
    delta = delta.reshape(b_, t, 5, rwkv_lib.LORA_MIX)
    delta = jnp.einsum("btsk,skd->btsd", delta,
                       tm["lora_b"].astype(jnp.float32)).astype(x.dtype)
    mus = tm["mu_5"].astype(x.dtype)                    # (5, D)
    xs = [x + diff * (mus[i] + delta[:, :, i]) for i in range(5)]
    x_r, x_k, x_v, x_w, x_g = xs

    r = matmul(x_r, tm["wr"]).reshape(b_, t, h, hd).astype(jnp.float32)
    k = matmul(x_k, tm["wk"]).reshape(b_, t, h, hd).astype(jnp.float32)
    v = matmul(x_v, tm["wv"]).reshape(b_, t, h, hd).astype(jnp.float32)
    g = jax.nn.silu(matmul(x_g, tm["wg"]).astype(jnp.float32))

    wlog = tm["w0"].astype(jnp.float32) + \
        (jnp.tanh(x_w.astype(jnp.float32) @ tm["td_a"].astype(jnp.float32))
         @ tm["td_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(wlog)).reshape(b_, t, h, hd)
    u = tm["u"].astype(jnp.float32)

    if decode:
        y, wkv_state = rwkv_lib.wkv6_decode(
            r[:, 0], k[:, 0], v[:, 0], w[:, 0], u, wkv_state)
        y = y[:, None]
    else:
        chunk = 64 if t % 64 == 0 else (t if t < 64 else 1)
        if chunk > 1:
            y, wkv_state = rwkv_lib.wkv6_chunked(r, k, v, w, u, wkv_state,
                                                 chunk=chunk)
        else:
            y, wkv_state = rwkv_lib.wkv6_scan(r, k, v, w, u, wkv_state)

    # per-head group norm, then gate and output projection
    y = y.reshape(b_, t, h, hd)
    mu = jnp.mean(y, -1, keepdims=True)
    var = jnp.var(y, -1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(b_, t, d) * tm["ln_x"].astype(jnp.float32)
    out = matmul((y * g).astype(PARAM_DTYPE), tm["wo"])
    return out, last, wkv_state


def rwkv_channel_mix(cm, x, shift_in):
    x_prev, last = rwkv_lib.token_shift(x, shift_in)
    xk = x + (x_prev - x) * cm["mu_k"].astype(x.dtype)
    xr = x + (x_prev - x) * cm["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(matmul(xk, cm["wk"]).astype(jnp.float32)))
    kv = matmul(k.astype(PARAM_DTYPE), cm["wv"])
    out = jax.nn.sigmoid(matmul(xr, cm["wr"]).astype(jnp.float32)) * kv
    return out.astype(PARAM_DTYPE), last


def mamba_path(mp, x, cfg: ModelConfig, *, conv_state=None, h_state=None,
               decode: bool = False, ctx: Optional[DistCtx] = None):
    """Mamba selective-SSM path of the Hymba block. x: (B,T,D).
    Returns (y (B,T,D), new_conv_state, new_h_state).

    ctx: when a TP mesh splits the inner channels (Ci column-parallel),
    the registry-dispatched scan (cfg.ssm_impl == "pallas") keys its tuned
    blk_c on the per-shard channel count — see DistCtx.tp_shards."""
    b_, t, d = x.shape
    ci = 2 * d
    n = cfg.ssm_state
    dt_rank = max(1, d // 16)

    xz = matmul(x, mp["in_proj"])                       # (B,T,2Ci)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv_state = mamba_lib.causal_conv1d(xs, mp["conv_w"], conv_state)
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(PARAM_DTYPE)

    proj = matmul(xs, mp["x_proj"]).astype(jnp.float32)  # (B,T,dtr+2N)
    dt_in, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ mp["dt_proj"].astype(jnp.float32)
                         + mp["dt_bias"].astype(jnp.float32))

    if h_state is None:
        h_state = jnp.zeros((b_, ci, n), jnp.float32)
    if cfg.ssm_impl == "stub" and not decode:
        # §Perf instrumentation: skip the selective scan itself (keep the
        # projections) to isolate the scan's HBM traffic by differencing.
        y = xs.astype(jnp.float32) * mp["d"].astype(jnp.float32)
        y = y * jax.nn.silu(z.astype(jnp.float32))
        return matmul(y.astype(PARAM_DTYPE), mp["out_proj"]), conv_state, h_state
    if decode:
        y, h_state = mamba_lib.ssm_decode(
            xs[:, 0].astype(jnp.float32), dt[:, 0], bmat[:, 0], cmat[:, 0],
            mp["a_log"], mp["d"], h_state)
        y = y[:, None]
    elif cfg.ssm_impl == "pallas":
        # unified-registry dispatch: blk_c comes from the repro.tune cache
        # keyed on the LOCAL channel shard (Ci/tp under a TP mesh), so the
        # cached config matches the slab each device actually executes
        from repro.kernels.ssm import ops as ssm_ops
        from repro.kernels.ssm.kernel_def import SsmKey
        shards = ctx.tp_shards(ci) if ctx is not None else 1
        key = SsmKey(b=b_, t=t, c=ci // shards, n=n)
        y, h_state = ssm_ops.ssm_scan(
            xs.astype(jnp.float32), dt, bmat, cmat, mp["a_log"], mp["d"],
            h_state, problem_key=key)
    else:
        chunk = 64 if (t % 64 == 0 and cfg.ssm_impl == "chunked") else 1
        if chunk > 1:
            y, h_state = mamba_lib.ssm_chunked(
                xs.astype(jnp.float32), dt, bmat, cmat, mp["a_log"], mp["d"],
                h_state, chunk=chunk)
        else:
            y, h_state = mamba_lib.ssm_scan(
                xs.astype(jnp.float32), dt, bmat, cmat, mp["a_log"], mp["d"],
                h_state)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = matmul(y.astype(PARAM_DTYPE), mp["out_proj"])
    return out, conv_state, h_state
