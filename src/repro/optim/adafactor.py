"""Adafactor (Shazeer & Stern 2018) — factored second moments.

The distributed-optimization workhorse for the biggest assigned configs
(llama4-maverick 0.77T total, qwen2.5-32b, internvl2-26b): optimizer state
for a (n, m) matrix is O(n+m) instead of O(n*m), which is what lets the
train_4k cell fit 16 GiB/chip at 256 chips (DESIGN.md §3.1).

Implementation: factored for rank>=2 leaves (row/col running means of
squared grads over the last two dims), full second moment for vectors;
update clipping (RMS threshold d=1.0), relative step size off (we pass an
external schedule), no first moment (beta1=0) by default.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import clip_by_global_norm

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr_fn: Callable[[jax.Array], jax.Array]
    decay: float = 0.8            # \hat\beta_2t exponent base
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    max_grad_norm: float = 1.0

    def _factored(self, shape) -> bool:
        return len(shape) >= 2

    def init(self, params: PyTree) -> PyTree:
        def leaf(p):
            if self._factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(leaf, params),
                "step": jnp.zeros((), jnp.int32)}

    def abstract_state(self, params: PyTree) -> PyTree:
        def leaf(p):
            if self._factored(p.shape):
                return {"vr": jax.ShapeDtypeStruct(p.shape[:-1], jnp.float32),
                        "vc": jax.ShapeDtypeStruct(p.shape[:-2] + p.shape[-1:],
                                                   jnp.float32)}
            return {"v": jax.ShapeDtypeStruct(p.shape, jnp.float32)}
        return {"f": jax.tree.map(leaf, params),
                "step": jax.ShapeDtypeStruct((), jnp.int32)}

    def update(self, params: PyTree, grads: PyTree, state: PyTree
               ) -> Tuple[PyTree, PyTree, dict]:
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        step = state["step"] + 1
        lr = self.lr_fn(step)
        beta2 = 1.0 - step.astype(jnp.float32) ** (-self.decay)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if self._factored(p.shape):
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                     self.eps))
                cfac = jax.lax.rsqrt(vc)
                u = g * rfac[..., None] * cfac[..., None, :]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v)
                new_s = {"v": v}
            # update clipping (RMS(u) <= d)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            newp = p.astype(jnp.float32) - lr * (
                u + self.weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), new_s

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["f"])
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_f = treedef.unflatten([o[1] for o in out])
        return new_p, {"f": new_f, "step": step}, \
            {"grad_norm": gnorm, "lr": lr}


def make_optimizer(name: str, lr_fn) :
    from repro.optim.adamw import AdamW
    if name == "adamw":
        return AdamW(lr_fn=lr_fn)
    if name == "adafactor":
        return Adafactor(lr_fn=lr_fn)
    raise ValueError(name)
