"""AdamW, built from scratch on pytrees (no optax).

State: {"m": tree f32, "v": tree f32, "step": i32}. m/v inherit the param
sharding (same logical axes), so under FSDP the optimizer state is sharded
too (ZeRO-style for free via the sharding engine).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr_fn: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0

    def init(self, params: PyTree) -> PyTree:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def abstract_state(self, params: PyTree) -> PyTree:
        z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "step": jax.ShapeDtypeStruct((), jnp.int32)}

    def update(self, params: PyTree, grads: PyTree, state: PyTree
               ) -> Tuple[PyTree, PyTree, dict]:
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        step = state["step"] + 1
        lr = self.lr_fn(step)
        b1, b2 = self.b1, self.b2
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / c1
            vh = v / c2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return newp.astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}, \
            {"grad_norm": gnorm, "lr": lr}
