"""Gradient compression for the DP all-reduce.

Two production-grade modes (both exact-shape, jit-friendly):

  * bf16 cast-before-sync (2x wire reduction; what `grad_compress="bf16"`
    in train/step.py does inline);
  * int8 + per-leaf scale with ERROR FEEDBACK: quantization residual is
    carried to the next step, so the compression error is O(1) over
    training instead of O(T) (Seide et al. / EF-SGD). 4x wire reduction.

The int8 path is expressed as quantize -> psum(int32 accum via f32) ->
dequantize under shard_map over the dp axes, so the wire payload really is
int8 per hop on a ring all-reduce of the quantized values.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def ef_int8_compress(grads: PyTree, residual: PyTree
                     ) -> Tuple[PyTree, PyTree, PyTree]:
    """Error-feedback int8 quantization.
    Returns (q_int8 tree, scales tree, new_residual tree)."""
    def leaf(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return q, scale, gf - deq

    flat, treedef = jax.tree.flatten(grads)
    rflat = treedef.flatten_up_to(residual)
    out = [leaf(g, r) for g, r in zip(flat, rflat)]
    qs = treedef.unflatten([o[0] for o in out])
    scales = treedef.unflatten([o[1] for o in out])
    res = treedef.unflatten([o[2] for o in out])
    return qs, scales, res


def ef_int8_decompress(qs: PyTree, scales: PyTree) -> PyTree:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qs, scales)


def init_residual(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def allreduce_int8(grads: PyTree, residual: PyTree, mesh, dp_axes,
                   in_specs=None) -> Tuple[PyTree, PyTree]:
    """Compressed DP gradient sync: each dp rank quantizes its local grad
    (with error feedback), the int8 payloads are summed across dp (wire =
    int8), scales are maxed, and the result dequantized. Inside shard_map
    so per-rank quantization is explicit, not SPMD-derived.

    `in_specs`: PartitionSpec describing how the per-rank grads are laid
    out over dp_axes (default: rank-major dim 0, P(dp_axes, ...)). The
    output keeps the same layout, every rank slot holding the mean."""
    from jax.sharding import PartitionSpec as P

    def local(g_and_r):
        grads_l, res_l = g_and_r
        n = 1
        for a in dp_axes:
            n *= mesh.shape[a]

        def leaf(g, r):
            gf = g.astype(jnp.float32) + r
            # SHARED scale across ranks (pmax before quantizing) — ranks
            # must quantize against the same quantum or the summed payload
            # dequantizes inconsistently.
            scale = jax.lax.pmax(
                jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12), dp_axes) / 127.0
            q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            new_r = gf - q.astype(jnp.float32) * scale
            summed = jax.lax.psum(q.astype(jnp.int32), dp_axes)
            return summed.astype(jnp.float32) * scale / n, new_r

        flat, treedef = jax.tree.flatten(grads_l)
        rflat = treedef.flatten_up_to(res_l)
        out = [leaf(g, r) for g, r in zip(flat, rflat)]
        deq = treedef.unflatten([o[0] for o in out])
        new_res = treedef.unflatten([o[1] for o in out])
        return deq, new_res

    if in_specs is None:
        in_specs = jax.tree.map(
            lambda g: P(dp_axes, *([None] * (g.ndim - 1))), grads)
    spec_tree = (in_specs, in_specs)
    fn = jax.shard_map(local, mesh=mesh, in_specs=(spec_tree,),
                       out_specs=(spec_tree[0], spec_tree[0]),
                       axis_names=frozenset(dp_axes), check_vma=False)
    return fn((grads, residual))
