"""Serving tier: the slot-scheduler engine, the multi-replica DP router,
and the synthetic trace generator.

  * engine — ServeEngine: slot-level continuous batching over one
    compiled decode step (run() to drain, or the stepwise
    submit()/step()/evict_inflight() surface drivers build on).
  * router — Router: DP load balancing over N replica engines with
    heartbeat failover and a deterministic FaultPlan.
  * trace  — seeded Poisson/bursty request traces with heavy-tail
    length mixes.

See docs/serving.md.
"""

from repro.serve.engine import (Request, RequestStats, ServeEngine,  # noqa: F401
                                StepReport, aggregate_engine_stats)
from repro.serve.trace import (Trace, TraceConfig, TracedRequest,  # noqa: F401
                               generate_trace)
from repro.serve.router import FaultPlan, Router  # noqa: F401
