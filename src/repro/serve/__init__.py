"""Serving tier: the slot-scheduler engine, the multi-replica DP router,
and the synthetic trace generator.

  * engine — ServeEngine: slot-level continuous batching over one
    compiled decode step (run() to drain, or the stepwise
    submit()/step()/evict_inflight() surface drivers build on).
  * router — Router: DP load balancing over N replica engines with
    heartbeat failover, a deterministic FaultPlan (kill/stall/recover/
    flap), bounded-queue load shedding with retry backoff, deadlines,
    and an OverloadConfig brown-out controller.
  * trace  — seeded Poisson/bursty request traces with heavy-tail
    length (and optional deadline) mixes.

See docs/serving.md.
"""

from repro.serve.engine import (Request, RequestStats, ServeEngine,  # noqa: F401
                                StepReport, aggregate_engine_stats)
from repro.serve.trace import (Trace, TraceConfig, TracedRequest,  # noqa: F401
                               generate_trace)
from repro.serve.router import (FaultPlan, OverloadConfig,  # noqa: F401
                                Router)
