"""Batched serving engine: continuous-batching-lite on top of the model's
prefill/decode steps.

Requests join a waiting queue; the engine packs up to `max_batch` active
sequences into one fixed-shape decode batch (static shapes => one compiled
decode step, the TPU-friendly design). Finished slots are refilled from the
queue between steps by re-prefilling into the slot's cache lines. Greedy or
temperature sampling.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import Model, build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 cache_len: int = 512, rng_seed: int = 0):
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.rng = jax.random.PRNGKey(rng_seed)
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(p, c, t))
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b))
        # whole-batch sampler: greedy rows take argmax, temperature rows a
        # categorical draw, selected per-row on device — one compiled call
        # per step instead of a host round-trip per sequence.
        self._sample_jit = jax.jit(self._sample_batch_impl)

    @staticmethod
    def _sample_batch_impl(logits: jax.Array, temps: jax.Array,
                           key: jax.Array) -> jax.Array:
        lg = logits.astype(jnp.float32).reshape(logits.shape[0], -1)
        greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        scaled = lg / jnp.maximum(temps, 1e-6)[:, None]
        sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
        return jnp.where(temps > 0, sampled, greedy)

    def _sample_batch(self, logits: jax.Array, temperatures) -> np.ndarray:
        """Sample next tokens for the whole batch in one device call;
        one np.asarray pulls them to the host. Returns (B,) int32."""
        self.rng, sub = jax.random.split(self.rng)
        temps = jnp.asarray(np.asarray(temperatures, np.float32))
        return np.asarray(self._sample_jit(logits, temps, sub))

    def run(self, requests: List[Request], *, extra_inputs: Optional[Dict] = None
            ) -> Dict[int, List[int]]:
        """Serve a list of requests with batched decode. Returns
        {rid: generated tokens}. Batches of size<=max_batch decode together;
        shorter prompts are left-padded into a common prefill call."""
        out: Dict[int, List[int]] = {}
        queue = list(requests)
        while queue:
            wave = queue[: self.max_batch]
            queue = queue[self.max_batch:]
            b = len(wave)
            plen = max(len(r.prompt) for r in wave)
            toks = np.zeros((b, plen), np.int32)
            for i, r in enumerate(wave):
                toks[i, plen - len(r.prompt):] = r.prompt   # left pad
            batch = {"tokens": jnp.asarray(toks)}
            if extra_inputs:
                batch.update({k: v[:b] for k, v in extra_inputs.items()})
            logits, cache = self._prefill(self.params, batch)
            live = {i: r for i, r in enumerate(wave)}
            for r in wave:
                out[r.rid] = []
            temps = [r.temperature for r in wave]
            toks = self._sample_batch(logits, temps)
            cur = toks[:, None].copy()
            for i, r in enumerate(wave):
                out[r.rid].append(int(toks[i]))
            max_new = max(r.max_new_tokens for r in wave)
            for _ in range(max_new - 1):
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(cur))
                toks = self._sample_batch(logits, temps)
                done = []
                for i, r in list(live.items()):
                    if len(out[r.rid]) >= r.max_new_tokens:
                        done.append(i)
                        continue
                    out[r.rid].append(int(toks[i]))
                    cur[i, 0] = toks[i]
                for i in done:
                    live.pop(i)
                if not live:
                    break
        return out
