"""Slot-level continuous-batching serving engine.

The engine owns a fixed pool of `max_batch` slots over ONE static-shape
decode batch (static shapes => one compiled decode step, the TPU-friendly
design). Requests wait in a FIFO admission queue; whenever a slot's request
finishes, the slot is refilled from the queue by prefilling the new request
into that slot's cache lines (Model.prefill_into_slot), so new requests
join the mid-flight batch without retracing and without disturbing their
batch-mates. One long request therefore occupies one slot, not the whole
batch — the occupancy failure of the old wave loop (process max_batch
requests, wait for the slowest, repeat) is gone.

Per-slot state lives in _Slot (rid, tokens remaining, temperature, done
flag, timing); per-row device state lives in the cache, whose "pos" is a
(B,) vector so every slot decodes at its own offset (models/registry.py,
transformer.attn_block_decode).

Sampling is deterministic PER REQUEST: token i of request rid is drawn
with fold_in(fold_in(base_key, rid), i), so identical requests produce
identical samples regardless of slot placement, batch-mates, or admission
order — and finished slots advance no shared RNG state (they have none to
advance). Finished slots are masked: their pos is held so their cache rows
stop growing, and their (discarded) sample comes from a constant dummy
lane. Greedy (temperature=0) rows take argmax.

Per-request latency/throughput stats (queue wait, TTFT, decode steps,
tokens/s) and engine aggregates (total decode steps, slot occupancy) are
collected on every run — `run(..., collect_stats=True)` returns them, and
`last_stats` always holds the most recent run's aggregates (the
benchmarks/run.py --serve table reads those into the repro-bench
artifact).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import Model, build_model

# right-padding shape buckets for slot prefill: one compiled
# prefill_into_slot per bucket instead of one per distinct prompt length.
# Exact-length families (see _bucket_len) skip bucketing: ssm/hybrid fold
# pads into their recurrent state, and MoE capacity dispatch would let
# pads shift the shape-derived expert capacity and claim slots.
PREFILL_BUCKETS = (8, 16, 32, 64, 128, 256, 512)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    # extra single-row model inputs, e.g. {"vis": (1, n_vis, D)} for vlm or
    # {"frames": (1, enc_seq, D)} for encdec
    extra: Optional[Dict[str, Any]] = None


@dataclasses.dataclass
class RequestStats:
    """Per-request latency/throughput, wall-clock measured by the engine."""
    rid: int
    prompt_len: int
    new_tokens: int
    queue_wait_s: float           # enqueue -> admitted into a slot
    ttft_s: float                 # enqueue -> first token sampled
    decode_steps: int             # batched decode steps this request rode
    total_s: float                # enqueue -> finished
    tok_per_s: float              # new_tokens / (finish - admit)


@dataclasses.dataclass
class _Slot:
    rid: int
    temperature: float
    remaining: int                # new tokens still to generate
    n_gen: int                    # tokens generated so far (rng fold index)
    prompt_len: int
    t_enqueue: float
    t_admit: float
    t_first: float
    decode_steps: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 cache_len: int = 512, rng_seed: int = 0):
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        # never split: per-request sample keys are fold_in derivations of
        # this base, so no shared RNG state advances across requests.
        self.rng = jax.random.PRNGKey(rng_seed)
        self.last_stats: Optional[Dict[str, Any]] = None

        def _decode_masked(p, c, t, active):
            logits, new = self.model.decode_step(p, c, t)
            # done-row masking: hold finished slots' pos so their cache
            # rows stop growing — the step writes one (masked, invisible)
            # line at the held position and the row costs nothing
            # semantically.
            new["pos"] = jnp.where(active, new["pos"], c["pos"])
            return logits, new

        self._decode = jax.jit(_decode_masked)
        self._prefill_slot = jax.jit(
            lambda p, c, s, b, n: self.model.prefill_into_slot(p, c, s, b, n))
        self._sample = jax.jit(self._sample_batch_impl)

    # ------------------------------------------------------------- sampling

    @staticmethod
    def _sample_batch_impl(logits: jax.Array, temps: jax.Array,
                           base_key: jax.Array, rids: jax.Array,
                           ngens: jax.Array) -> jax.Array:
        """Whole-batch next-token sampler, one compiled call per step.
        Greedy rows take argmax; temperature rows draw categorically with a
        per-request key fold_in(fold_in(base, rid), token_index) — no row's
        draw depends on its batch-mates or on any mutable RNG state."""
        lg = logits.astype(jnp.float32).reshape(logits.shape[0], -1)
        greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)

        def draw(rid, ngen, row, temp):
            key = jax.random.fold_in(jax.random.fold_in(base_key, rid), ngen)
            return jax.random.categorical(key, row / jnp.maximum(temp, 1e-6))

        sampled = jax.vmap(draw)(rids, ngens, lg, temps).astype(jnp.int32)
        return jnp.where(temps > 0, sampled, greedy)

    def _sample_rows(self, logits, slots: List[Optional[_Slot]]) -> np.ndarray:
        temps = np.array([s.temperature if s else 0.0 for s in slots],
                         np.float32)
        rids = np.array([s.rid if s else -1 for s in slots], np.int32)
        ngens = np.array([s.n_gen if s else 0 for s in slots], np.int32)
        return np.asarray(self._sample(logits, jnp.asarray(temps), self.rng,
                                       jnp.asarray(rids), jnp.asarray(ngens)))

    # ------------------------------------------------------------ admission

    def _bucket_len(self, n: int, room: int) -> int:
        # exact-length families: recurrent state (ssm/hybrid) folds every
        # token in, and MoE capacity dispatch is token-count sensitive
        # (pad tokens would shift the shape-derived expert capacity and
        # compete for slots) — for them one trace per prompt length is the
        # price of correctness. Pure-attention stacks are causal, so right
        # pads are invisible to real tokens and bucketing is free. `room`
        # caps the padded length so the row's cache lines (including any
        # prepended vis tokens) still fit the slot.
        if self.cfg.family in ("ssm", "hybrid", "moe"):
            return n
        for b in PREFILL_BUCKETS:
            if n <= b <= room:
                return b
        return n

    def _fresh_cache(self):
        cache = self.model.init_cache(self.max_batch, self.cache_len)
        # per-row positions: each slot decodes at its own offset
        cache["pos"] = jnp.zeros((self.max_batch,), jnp.int32)
        return cache

    def _admit(self, cache, slot_idx: int, r: Request, t_enqueue: float):
        """Prefill r into slot_idx's cache lines; returns
        (new cache, slot state, first sampled token)."""
        plen = len(r.prompt)
        if self.cfg.family == "vlm":
            plen += self.cfg.n_vis_tokens  # vis tokens occupy cache lines
        assert plen + r.max_new_tokens <= self.cache_len, (
            f"request {r.rid}: prompt {plen} + max_new {r.max_new_tokens} "
            f"exceeds cache_len {self.cache_len}")
        vis = plen - len(r.prompt)
        padded = self._bucket_len(len(r.prompt), self.cache_len - vis)
        toks = np.zeros((1, padded), np.int32)
        toks[0, : len(r.prompt)] = r.prompt      # right pad: masked by pos
        batch = {"tokens": jnp.asarray(toks)}
        if r.extra:
            batch.update(r.extra)
        t_admit = time.perf_counter()
        logits, cache = self._prefill_slot(
            self.params, cache, np.int32(slot_idx), batch, np.int32(plen))
        slot = _Slot(rid=r.rid, temperature=r.temperature,
                     remaining=r.max_new_tokens, n_gen=0, prompt_len=plen,
                     t_enqueue=t_enqueue, t_admit=t_admit, t_first=0.0)
        first = int(self._sample_rows(logits, [slot])[0])
        slot.t_first = time.perf_counter()
        slot.n_gen = 1
        slot.remaining -= 1
        return cache, slot, first

    # ------------------------------------------------------------ scheduler

    def run(self, requests: List[Request], *, collect_stats: bool = False):
        """Serve requests with slot-level continuous batching. Returns
        {rid: generated tokens}, or (that, stats) with collect_stats=True.

        stats = {"requests": {rid: RequestStats}, "engine": {...}} — the
        engine dict is what last_stats holds after every run."""
        t_run = time.perf_counter()
        queue = deque(requests)
        t_enq = {r.rid: t_run for r in requests}
        out: Dict[int, List[int]] = {r.rid: [] for r in requests}
        per_req: Dict[int, RequestStats] = {}
        slots: List[Optional[_Slot]] = [None] * self.max_batch
        cache = self._fresh_cache()
        cur = np.zeros((self.max_batch, 1), np.int32)
        n_steps = 0          # global batched decode steps
        n_prefills = 0
        slot_steps_active = 0

        def finish(i: int):
            s = slots[i]
            now = time.perf_counter()
            per_req[s.rid] = RequestStats(
                rid=s.rid, prompt_len=s.prompt_len, new_tokens=s.n_gen,
                queue_wait_s=s.t_admit - s.t_enqueue,
                ttft_s=s.t_first - s.t_enqueue,
                decode_steps=s.decode_steps, total_s=now - s.t_enqueue,
                tok_per_s=s.n_gen / max(now - s.t_admit, 1e-9))
            slots[i] = None

        while queue or any(s is not None for s in slots):
            # refill every free slot from the queue before the next step
            for i in range(self.max_batch):
                if slots[i] is None and queue:
                    r = queue.popleft()
                    if r.max_new_tokens < 1:     # nothing to generate
                        per_req[r.rid] = RequestStats(
                            rid=r.rid, prompt_len=len(r.prompt),
                            new_tokens=0, queue_wait_s=0.0, ttft_s=0.0,
                            decode_steps=0, total_s=0.0, tok_per_s=0.0)
                        continue
                    cache, slot, first = self._admit(cache, i, r,
                                                     t_enq[r.rid])
                    n_prefills += 1
                    out[r.rid].append(first)
                    cur[i, 0] = first
                    slots[i] = slot
                    if slot.remaining <= 0:      # max_new_tokens == 1
                        finish(i)
            if not any(s is not None for s in slots):
                continue                          # queue drained via finish
            active = np.array([s is not None for s in slots])
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(cur),
                                         jnp.asarray(active))
            n_steps += 1
            slot_steps_active += int(active.sum())
            toks = self._sample_rows(logits, slots)
            for i, s in enumerate(slots):
                if s is None:
                    continue
                tok = int(toks[i])
                out[s.rid].append(tok)
                cur[i, 0] = tok
                s.n_gen += 1
                s.remaining -= 1
                s.decode_steps += 1
                if s.remaining <= 0:
                    finish(i)

        wall = time.perf_counter() - t_run
        total_new = sum(st.new_tokens for st in per_req.values())
        engine_stats = {
            "requests": len(requests),
            "decode_steps": n_steps,
            "prefills": n_prefills,
            "new_tokens": total_new,
            "occupancy": (slot_steps_active / (n_steps * self.max_batch)
                          if n_steps else 1.0),
            "wall_s": wall,
            "tok_per_s": total_new / max(wall, 1e-9),
            "mean_queue_wait_s": (float(np.mean([s.queue_wait_s
                                                 for s in per_req.values()]))
                                  if per_req else 0.0),
            "mean_ttft_s": (float(np.mean([s.ttft_s
                                           for s in per_req.values()]))
                            if per_req else 0.0),
        }
        self.last_stats = engine_stats
        if collect_stats:
            return out, {"requests": per_req, "engine": engine_stats}
        return out
