"""Batched serving engine: continuous-batching-lite on top of the model's
prefill/decode steps.

Requests join a waiting queue; the engine packs up to `max_batch` active
sequences into one fixed-shape decode batch (static shapes => one compiled
decode step, the TPU-friendly design). Finished slots are refilled from the
queue between steps by re-prefilling into the slot's cache lines. Greedy or
temperature sampling.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import Model, build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 cache_len: int = 512, rng_seed: int = 0):
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.rng = jax.random.PRNGKey(rng_seed)
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(p, c, t))
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b))

    def _sample(self, logits: jax.Array, temperature: float) -> int:
        lg = np.asarray(logits, np.float32).reshape(-1)
        if temperature <= 0:
            return int(lg.argmax())
        self.rng, sub = jax.random.split(self.rng)
        return int(jax.random.categorical(sub, jnp.asarray(lg) / temperature))

    def run(self, requests: List[Request], *, extra_inputs: Optional[Dict] = None
            ) -> Dict[int, List[int]]:
        """Serve a list of requests with batched decode. Returns
        {rid: generated tokens}. Batches of size<=max_batch decode together;
        shorter prompts are left-padded into a common prefill call."""
        out: Dict[int, List[int]] = {}
        queue = list(requests)
        while queue:
            wave = queue[: self.max_batch]
            queue = queue[self.max_batch:]
            b = len(wave)
            plen = max(len(r.prompt) for r in wave)
            toks = np.zeros((b, plen), np.int32)
            for i, r in enumerate(wave):
                toks[i, plen - len(r.prompt):] = r.prompt   # left pad
            batch = {"tokens": jnp.asarray(toks)}
            if extra_inputs:
                batch.update({k: v[:b] for k, v in extra_inputs.items()})
            logits, cache = self._prefill(self.params, batch)
            live = {i: r for i, r in enumerate(wave)}
            for r in wave:
                out[r.rid] = []
            cur = np.zeros((b, 1), np.int32)
            for i, r in enumerate(wave):
                nxt = self._sample(logits[i], r.temperature)
                out[r.rid].append(nxt)
                cur[i, 0] = nxt
            max_new = max(r.max_new_tokens for r in wave)
            for _ in range(max_new - 1):
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(cur))
                done = []
                for i, r in list(live.items()):
                    if len(out[r.rid]) >= r.max_new_tokens:
                        done.append(i)
                        continue
                    nxt = self._sample(logits[i], r.temperature)
                    out[r.rid].append(nxt)
                    cur[i, 0] = nxt
                for i in done:
                    live.pop(i)
                if not live:
                    break
        return out
