"""Slot-level continuous-batching serving engine.

The engine owns a fixed pool of `max_batch` slots over ONE static-shape
decode batch (static shapes => one compiled decode step, the TPU-friendly
design). Requests wait in a FIFO admission queue; whenever a slot's request
finishes, the slot is refilled from the queue by prefilling the new request
into that slot's cache lines (Model.prefill_into_slot), so new requests
join the mid-flight batch without retracing and without disturbing their
batch-mates. One long request therefore occupies one slot, not the whole
batch — the occupancy failure of the old wave loop (process max_batch
requests, wait for the slowest, repeat) is gone.

Per-slot state lives in _Slot (rid, tokens remaining, temperature, done
flag, timing); per-row device state lives in the cache, whose "pos" is a
(B,) vector so every slot decodes at its own offset (models/registry.py,
transformer.attn_block_decode).

Sampling is deterministic PER REQUEST: token i of request rid is drawn
with fold_in(fold_in(base_key, rid), i), so identical requests produce
identical samples regardless of slot placement, batch-mates, or admission
order — and finished slots advance no shared RNG state (they have none to
advance). Finished slots are masked: their pos is held so their cache rows
stop growing, and their (discarded) sample comes from a constant dummy
lane. Greedy (temperature=0) rows take argmax.

Per-request latency/throughput stats (queue wait, TTFT, decode steps,
tokens/s) and engine aggregates (total decode steps, slot occupancy) are
collected on every run — `run(..., collect_stats=True)` returns them, and
`last_stats` always holds the most recent run's aggregates (the
benchmarks/run.py --serve table reads those into the repro-bench
artifact).

The scheduler is exposed at two granularities. `run(requests)` drains a
whole workload. The stepwise surface — `reset()`, `submit(request)`,
`step()` (one admission pass + one batched decode step, returning a
StepReport), `evict_inflight()`, `finalize()` — lets an outer driver
interleave many engines and inject/remove work mid-flight; the
multi-replica DP router (repro.serve.router) is built on it, re-queuing a
dead replica's evicted requests onto survivors. Because sampling is
per-request (below), a re-queued request restarted from scratch on any
replica regenerates the exact token stream the dead replica would have
produced.

Sharded serving: pass `mesh=` to run the engine tensor-parallel over a
`repro.dist` mesh. Params and the per-slot K/V cache shard head-wise per
`dist.sharding.serve_specs` (TP for attention/FFN weights, replicated
scheduler state); prefill_into_slot and the decode step execute as
sharded jitted computations while the FIFO slot loop stays host-side and
device-count-agnostic. See docs/serving.md §Sharded serving.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import Model, build_model

# right-padding shape buckets for slot prefill: one compiled
# prefill_into_slot per bucket instead of one per distinct prompt length.
# Exact-length families (see _bucket_len) skip bucketing: ssm/hybrid fold
# pads into their recurrent state, and MoE capacity dispatch would let
# pads shift the shape-derived expert capacity and claim slots.
PREFILL_BUCKETS = (8, 16, 32, 64, 128, 256, 512)


@dataclasses.dataclass
class Request:
    """One serving request: a token prompt plus generation knobs.

    rid must be unique per engine run — it keys the output dict AND the
    per-request deterministic sample stream (fold_in(base_key, rid)), so
    two requests with the same rid would draw identical randomness.
    temperature 0.0 means greedy argmax.

    Example::

        import numpy as np, repro
        r = repro.Request(rid=0, prompt=np.array([3, 1, 4]),
                          max_new_tokens=8, temperature=0.7)
    """
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    # extra single-row model inputs, e.g. {"vis": (1, n_vis, D)} for vlm or
    # {"frames": (1, enc_seq, D)} for encdec
    extra: Optional[Dict[str, Any]] = None


@dataclasses.dataclass
class RequestStats:
    """Per-request latency/throughput, wall-clock measured by the engine."""
    rid: int
    prompt_len: int
    new_tokens: int
    queue_wait_s: float           # enqueue -> admitted into a slot
    ttft_s: float                 # enqueue -> first token sampled
    decode_steps: int             # batched decode steps this request rode
    total_s: float                # enqueue -> finished
    tok_per_s: float              # new_tokens / (finish - admit)


@dataclasses.dataclass
class _Slot:
    rid: int
    temperature: float
    remaining: int                # new tokens still to generate
    n_gen: int                    # tokens generated so far (rng fold index)
    prompt_len: int
    t_enqueue: float
    t_admit: float
    t_first: float
    decode_steps: int = 0


@dataclasses.dataclass
class StepReport:
    """What one ServeEngine.step() round did — the router's per-tick feed.

    admitted:    rids prefilled into a slot this round (their first token
                 was sampled during admission)
    finished:    rids whose last token was produced this round (including
                 degenerate max_new_tokens<1 requests, which finish
                 without ever occupying a slot)
    decoded:     occupied rows in this round's batched decode step (0 when
                 the decode was skipped because nothing was occupied)
    queue_depth: requests still waiting after this round's admissions
    """
    admitted: List[int]
    finished: List[int]
    decoded: int
    queue_depth: int


def percentile(xs, q: float) -> float:
    """Percentile with numpy's default linear interpolation, defined as
    0.0 on an empty sample (a run where nothing qualified). n=1 and
    all-equal samples degenerate to that single value for every q —
    tests/test_serve_stats.py pins these edges."""
    if len(xs) == 0:
        return 0.0
    return float(np.percentile(np.asarray(xs, np.float64), q))


def request_tpot_s(st: "RequestStats") -> Optional[float]:
    """Time-per-output-token of one finished request: the decode time
    after its first token spread over the remaining tokens,
    (total_s - ttft_s) / (new_tokens - 1). Undefined (None) for requests
    with fewer than two tokens — a max_new_tokens<=1 request has no
    inter-token gap to measure, so it contributes no TPOT sample."""
    if st.new_tokens < 2:
        return None
    return (st.total_s - st.ttft_s) / (st.new_tokens - 1)


def aggregate_engine_stats(per_req: Dict[int, "RequestStats"], *,
                           n_requests: int, n_steps: int, n_prefills: int,
                           slot_steps_active: int, max_batch: int,
                           wall_s: float) -> Dict[str, Any]:
    """Fold per-request stats + scheduler counters into the engine dict
    (the `last_stats` schema benchmarks/run.py --serve reads).

    Definitions (tests/test_serve_stats.py pins these):
      occupancy   = slot_steps_active / (n_steps * max_batch); an idle run
                    (no decode steps) is vacuously fully occupied (1.0).
      tok_per_s   = total generated tokens / wall_s (engine throughput,
                    prefill + decode inclusive since wall_s spans the run).
      mean_*      = arithmetic means over finished requests (0.0 when no
                    request finished).
      p50/p99_*   = distribution tails (linear-interpolated percentiles).
                    TTFT samples come from requests that produced at least
                    one token (a max_new_tokens<1 request records a
                    vacuous 0.0 TTFT and is excluded); TPOT samples from
                    requests with >= 2 tokens (see request_tpot_s).
    """
    total_new = sum(st.new_tokens for st in per_req.values())
    ttfts = [st.ttft_s for st in per_req.values() if st.new_tokens > 0]
    tpots = [t for t in (request_tpot_s(st) for st in per_req.values())
             if t is not None]
    return {
        "p50_ttft_s": percentile(ttfts, 50),
        "p99_ttft_s": percentile(ttfts, 99),
        "p50_tpot_s": percentile(tpots, 50),
        "p99_tpot_s": percentile(tpots, 99),
        "requests": n_requests,
        "decode_steps": n_steps,
        "prefills": n_prefills,
        "new_tokens": total_new,
        "occupancy": (slot_steps_active / (n_steps * max_batch)
                      if n_steps else 1.0),
        "wall_s": wall_s,
        "tok_per_s": total_new / max(wall_s, 1e-9),
        "mean_queue_wait_s": (float(np.mean([s.queue_wait_s
                                             for s in per_req.values()]))
                              if per_req else 0.0),
        "mean_ttft_s": (float(np.mean([s.ttft_s
                                       for s in per_req.values()]))
                        if per_req else 0.0),
    }


class ServeEngine:
    """Slot-level continuous-batching LM server over one compiled decode
    step. See the module docstring for the scheduling model and
    docs/serving.md for the full guide.

    Example (tiny model, CPU)::

        import jax, numpy as np, repro
        from repro.configs.base import get_config, reduce_config
        cfg = reduce_config(get_config("qwen2-1.5b"), d_model=64, vocab=128)
        params = repro.build_model(cfg).init_params(jax.random.PRNGKey(0))
        eng = repro.ServeEngine(cfg, params, max_batch=2, cache_len=64)
        out = eng.run([repro.Request(rid=0, prompt=np.arange(5),
                                     max_new_tokens=8)])

    mesh: optional `jax.sharding.Mesh` with a "model" axis — the engine
    then serves tensor-parallel: params and the slot K/V cache shard per
    `repro.dist.sharding.serve_specs`, the scheduler stays host-side, and
    outputs are bit-exact vs the mesh-less engine on a 1-device mesh.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 cache_len: int = 512, rng_seed: int = 0, mesh=None,
                 kv_page_size: int = 0, kv_pages: Optional[int] = None,
                 kv_dtype: str = "bf16", prefix_reuse: bool = True,
                 draft_cfg: Optional[ModelConfig] = None, draft_params=None,
                 spec_k: int = 0):
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.mesh = mesh
        # paged K/V cache (serve/kvcache.py): kv_page_size > 0 switches the
        # slot caches to page accounting + prefix reuse. The page pool is
        # host-managed and per-replica, so it is gated off the TP mesh path
        # (the sharded cache layout is pinned by serve_specs).
        if kv_page_size and mesh is not None:
            raise ValueError("paged K/V cache (kv_page_size>0) does not "
                             "compose with mesh= tensor parallelism")
        self.kv_page_size = kv_page_size
        self.kv_pages = kv_pages
        self.kv_dtype = kv_dtype
        self.prefix_reuse = prefix_reuse
        self._kv = None
        # speculative decoding (serve/spec.py): spec_k > 0 pairs the target
        # with a small draft model that proposes spec_k candidates per
        # active slot each round, verified by ONE (spec_k+1)-position
        # target forward (Model.decode_verify). The accept loop and the
        # per-row position rollback are host-managed like the page pool,
        # so spec is gated off the TP mesh path too.
        self.spec_k = spec_k
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        self.draft_model: Optional[Model] = None
        self._draft_cache = None
        self._spec_inflight: Dict[int, int] = {}
        self._spec: Optional[Dict[str, int]] = None
        if spec_k:
            if mesh is not None:
                raise ValueError("speculative decoding (spec_k>0) does not "
                                 "compose with mesh= tensor parallelism")
            if draft_cfg is None or draft_params is None:
                raise ValueError("spec_k>0 requires draft_cfg= and "
                                 "draft_params=")
            if draft_cfg.family != "dense":
                raise ValueError("draft model must be a dense decoder "
                                 f"(per-row K/V rollback): {draft_cfg.family!r}")
            if draft_cfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    "draft/target vocab mismatch: "
                    f"{draft_cfg.vocab_size} vs {cfg.vocab_size}")
            self.draft_model = build_model(draft_cfg)
        # never split: per-request sample keys are fold_in derivations of
        # this base, so no shared RNG state advances across requests.
        self.rng = jax.random.PRNGKey(rng_seed)
        self.last_stats: Optional[Dict[str, Any]] = None
        # scheduler state is armed lazily: reset() allocates the cache, so
        # constructing an engine stays cheap; run() resets every time and
        # submit() resets on first use. queue/slots exist from birth so
        # idle/queue_depth/active_count are safe to read before the first
        # reset.
        self._cache = None
        self._queue: deque = deque()
        self._slots: List[Optional[_Slot]] = [None] * max_batch

        if mesh is not None:
            from repro.dist.sharding import serve_specs
            from repro.models.layers import DistCtx
            self._specs = serve_specs(cfg, mesh, max_batch=max_batch,
                                      cache_len=cache_len, model=self.model)
            # commit params onto the mesh once; every jitted step below
            # inherits the layout (row/column-parallel weights, head-wise
            # sharded cache, replicated scheduler state)
            self.params = jax.device_put(params, self._specs.params)
            # the ctx threads per-shard sizes into the flash/ssm registry
            # dispatch (tuned block configs key on the LOCAL shard of the
            # problem, not the global shape)
            self._ctx = DistCtx(mesh=mesh, data_axes=(), model_axis="model")
            self._cache_bytes_local = self._local_cache_bytes()
        else:
            self._specs = None
            self._ctx = None
            self.params = params
            self._cache_bytes_local = 0

        from repro.models.layers import exact_tp_scope

        def _decode_masked(p, c, t, active):
            # exact_tp_scope is trace-time: with a mesh it makes every
            # row-parallel contraction gather-then-compute (bit-exact);
            # mesh=None makes it a no-op.
            with exact_tp_scope(mesh):
                logits, new = self.model.decode_step(p, c, t, self._ctx)
            # done-row masking: hold finished slots' pos so their cache
            # rows stop growing — the step writes one (masked, invisible)
            # line at the held position and the row costs nothing
            # semantically.
            new["pos"] = jnp.where(active, new["pos"], c["pos"])
            return logits, new

        def _prefill_slot(p, c, s, b, n):
            with exact_tp_scope(mesh):
                return self.model.prefill_into_slot(p, c, s, b, n,
                                                    self._ctx)

        if mesh is None:
            self._decode = jax.jit(_decode_masked)
            self._prefill_slot = jax.jit(_prefill_slot)
        else:
            # pin the cache layout across steps (out_shardings) so XLA
            # cannot silently gather the sharded K/V between prefill and
            # decode; logits replicate — the host samples from them.
            sp = self._specs
            self._decode = jax.jit(
                _decode_masked,
                in_shardings=(sp.params, sp.cache, sp.replicated,
                              sp.replicated),
                out_shardings=(sp.replicated, sp.cache))
            self._prefill_slot = jax.jit(
                _prefill_slot,
                in_shardings=(sp.params, sp.cache, sp.replicated,
                              sp.replicated, sp.replicated),
                out_shardings=(sp.replicated, sp.cache))
        self._sample = jax.jit(self._sample_batch_impl)

        # suffix prefill for prefix-reuse admissions (dense family only —
        # Model.prefill_continue is None elsewhere and hits never occur)
        self._prefill_cont = None
        if kv_page_size and self.model.prefill_continue is not None:
            def _prefill_cont(p, c, s, b, st, n):
                with exact_tp_scope(mesh):
                    return self.model.prefill_continue(p, c, s, b, st, n,
                                                       self._ctx)
            self._prefill_cont = jax.jit(_prefill_cont)

        if spec_k:
            if self.model.decode_verify is None:
                raise ValueError(
                    "target family has no multi-position decode_verify "
                    f"entry (spec_k>0 needs one): {cfg.family!r}")

            def _draft_decode(p, c, t, active):
                logits, new = self.draft_model.decode_step(p, c, t, None)
                new["pos"] = jnp.where(active, new["pos"], c["pos"])
                return logits, new

            def _draft_prefill(p, c, s, b, n):
                return self.draft_model.prefill_into_slot(p, c, s, b, n,
                                                          None)

            def _verify_masked(p, c, t, active):
                # the (spec_k+1)-position verify forward; done-row masking
                # holds finished slots' pos exactly like _decode_masked
                logits, new = self.model.decode_verify(p, c, t, self._ctx)
                new["pos"] = jnp.where(active, new["pos"], c["pos"])
                return logits, new

            self._draft_decode = jax.jit(_draft_decode)
            self._draft_prefill = jax.jit(_draft_prefill)
            self._verify = jax.jit(_verify_masked)
            self._spec_sample = jax.jit(self._spec_sample_impl)

    # ------------------------------------------------------------- sampling

    @staticmethod
    def _sample_batch_impl(logits: jax.Array, temps: jax.Array,
                           base_key: jax.Array, rids: jax.Array,
                           ngens: jax.Array) -> jax.Array:
        """Whole-batch next-token sampler, one compiled call per step.
        Greedy rows take argmax; temperature rows draw categorically with a
        per-request key fold_in(fold_in(base, rid), token_index) — no row's
        draw depends on its batch-mates or on any mutable RNG state."""
        lg = logits.astype(jnp.float32).reshape(logits.shape[0], -1)
        greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)

        def draw(rid, ngen, row, temp):
            key = jax.random.fold_in(jax.random.fold_in(base_key, rid), ngen)
            return jax.random.categorical(key, row / jnp.maximum(temp, 1e-6))

        sampled = jax.vmap(draw)(rids, ngens, lg, temps).astype(jnp.int32)
        return jnp.where(temps > 0, sampled, greedy)

    @staticmethod
    def _spec_sample_impl(logits: jax.Array, temps: jax.Array,
                          base_key: jax.Array, rids: jax.Array,
                          ngens: jax.Array, salt: jax.Array) -> jax.Array:
        """Draft-proposal sampler for spec rounds. Greedy rows take the
        draft argmax; temperature rows draw from the DRAFT distribution
        with the salted per-request key (serve/spec.py key schedule), so
        spec-round draws can never collide with the plain path's un-salted
        sample stream or with the accept/residual/bonus draws."""
        lg = logits.astype(jnp.float32).reshape(logits.shape[0], -1)
        greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)

        def draw(rid, ngen, row, temp):
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.fold_in(base_key, rid), ngen),
                salt)
            return jax.random.categorical(key, row / jnp.maximum(temp, 1e-6))

        sampled = jax.vmap(draw)(rids, ngens, lg, temps).astype(jnp.int32)
        return jnp.where(temps > 0, sampled, greedy)

    def _sample_rows(self, logits, slots: List[Optional[_Slot]]) -> np.ndarray:
        temps = np.array([s.temperature if s else 0.0 for s in slots],
                         np.float32)
        rids = np.array([s.rid if s else -1 for s in slots], np.int32)
        ngens = np.array([s.n_gen if s else 0 for s in slots], np.int32)
        return np.asarray(self._sample(logits, jnp.asarray(temps), self.rng,
                                       jnp.asarray(rids), jnp.asarray(ngens)))

    # ------------------------------------------------------------ admission

    def _bucket_len(self, n: int, room: int) -> int:
        # exact-length families: recurrent state (ssm/hybrid) folds every
        # token in, and MoE capacity dispatch is token-count sensitive
        # (pad tokens would shift the shape-derived expert capacity and
        # compete for slots) — for them one trace per prompt length is the
        # price of correctness. Pure-attention stacks are causal, so right
        # pads are invisible to real tokens and bucketing is free. `room`
        # caps the padded length so the row's cache lines (including any
        # prepended vis tokens) still fit the slot.
        if self.cfg.family in ("ssm", "hybrid", "moe"):
            return n
        for b in PREFILL_BUCKETS:
            if n <= b <= room:
                return b
        return n

    def _fresh_cache(self):
        cache = self.model.init_cache(self.max_batch, self.cache_len)
        # per-row positions: each slot decodes at its own offset
        cache["pos"] = jnp.zeros((self.max_batch,), jnp.int32)
        if self._specs is not None:
            cache = jax.device_put(cache, self._specs.cache)
        return cache

    # ------------------------------------------------------------ per-device

    def _local_cache_bytes(self) -> int:
        """One device's cache shard bytes, from the pinned shard shapes
        (identical per device: shard_shape is uniform). The layout is
        fixed at construction, so this is computed once in __init__."""
        ab = self.model.init_cache(self.max_batch, self.cache_len,
                                   abstract=True)
        ab["pos"] = jax.ShapeDtypeStruct((self.max_batch,), jnp.int32)
        cache_b = 0
        for sharding, leaf in zip(jax.tree.leaves(self._specs.cache),
                                  jax.tree.leaves(ab)):
            n = 1
            for s in sharding.shard_shape(leaf.shape):
                n *= s
            cache_b += n * leaf.dtype.itemsize
        return cache_b

    def device_stats(self) -> List[Dict[str, Any]]:
        """Per-device shard accounting on a sharded engine ([] without a
        mesh): for every mesh device, the bytes of its local param shards
        (measured from the committed arrays) and of its local cache
        shards. benchmarks/run.py --serve --mesh emits one artifact row
        per entry."""
        if self.mesh is None:
            return []
        params_b: Dict[int, int] = {d.id: 0 for d in self.mesh.devices.flat}
        for leaf in jax.tree.leaves(self.params):
            for sh in leaf.addressable_shards:
                params_b[sh.device.id] += sh.data.nbytes
        return [{"device": did, "params_bytes": pb,
                 "cache_bytes": self._cache_bytes_local}
                for did, pb in sorted(params_b.items())]

    def _admit(self, cache, slot_idx: int, r: Request, t_enqueue: float):
        """Prefill r into slot_idx's cache lines; returns
        (new cache, slot state, first sampled token)."""
        plen = len(r.prompt)
        if self.cfg.family == "vlm":
            plen += self.cfg.n_vis_tokens  # vis tokens occupy cache lines
        # spec rounds write up to spec_k speculative lines past the
        # committed region before the accept decision rolls pos back, so a
        # spec engine reserves that headroom in every slot
        assert plen + r.max_new_tokens + self.spec_k <= self.cache_len, (
            f"request {r.rid}: prompt {plen} + max_new {r.max_new_tokens} "
            f"+ spec_k {self.spec_k} exceeds cache_len {self.cache_len}")
        vis = plen - len(r.prompt)
        # paged cache: open the block table (allocating pages for the
        # prompt) and consult the prefix index. A hit restores the cached
        # K/V pages into the slot row and prefills only the unseen suffix.
        hit = None
        if self._kv is not None:
            hit = self._kv.admit(r.rid, np.asarray(r.prompt, np.int32),
                                 plen, r.max_new_tokens)
        t_admit = time.perf_counter()
        if hit is not None:
            cache = self._kv.restore_prefix(cache, slot_idx, hit)
            start = hit.tokens
            suffix = np.asarray(r.prompt[start:], np.int32)
            padded = self._bucket_len(len(suffix), self.cache_len - start)
            toks = np.zeros((1, padded), np.int32)
            toks[0, : len(suffix)] = suffix
            logits, cache = self._prefill_cont(
                self.params, cache, np.int32(slot_idx),
                {"tokens": jnp.asarray(toks)}, np.int32(start),
                np.int32(len(suffix)))
        else:
            padded = self._bucket_len(len(r.prompt), self.cache_len - vis)
            toks = np.zeros((1, padded), np.int32)
            toks[0, : len(r.prompt)] = r.prompt  # right pad: masked by pos
            batch = {"tokens": jnp.asarray(toks)}
            if r.extra:
                batch.update(r.extra)
            logits, cache = self._prefill_slot(
                self.params, cache, np.int32(slot_idx), batch,
                np.int32(plen))
        if self._kv is not None and self._kv.prefix_reuse:
            # publish this prompt's full pages for future admissions
            cache = self._kv.insert_prefix(np.asarray(r.prompt, np.int32),
                                           r.rid, cache, slot_idx)
        if self.spec_k:
            # the draft keeps its own slot-resident K/V lines (never
            # page-accounted: the page pool tracks committed TARGET lines
            # only) and always prefills the full prompt — it has no prefix
            # store, and its first-token logits are discarded (the first
            # token comes from the target, the bit-exactness contract)
            dpad = self._bucket_len(len(r.prompt), self.cache_len)
            dtoks = np.zeros((1, dpad), np.int32)
            dtoks[0, : len(r.prompt)] = r.prompt
            _, self._draft_cache = self._draft_prefill(
                self.draft_params, self._draft_cache, np.int32(slot_idx),
                {"tokens": jnp.asarray(dtoks)}, np.int32(plen))
        slot = _Slot(rid=r.rid, temperature=r.temperature,
                     remaining=r.max_new_tokens, n_gen=0, prompt_len=plen,
                     t_enqueue=t_enqueue, t_admit=t_admit, t_first=0.0)
        first = int(self._sample_rows(logits, [slot])[0])
        slot.t_first = time.perf_counter()
        slot.n_gen = 1
        slot.remaining -= 1
        return cache, slot, first

    # ------------------------------------------------------------ scheduler
    #
    # The scheduler is incremental: reset() arms a fresh run, submit()
    # enqueues requests at any point, and step() performs one scheduler
    # round (admit free slots FIFO, then one batched decode step). run()
    # is the drain-everything convenience built on top; a multi-replica
    # router (repro.serve.router) instead interleaves submit()/step()
    # across engines and uses evict_inflight() for failover re-queue.

    def reset(self) -> None:
        """Arm a fresh scheduling run: empty queue/slots, a fresh cache,
        zeroed counters. Called by run(); a stepwise driver (the router)
        calls it once before its first submit()."""
        self._queue: deque = deque()
        self._reqs: Dict[int, Request] = {}      # in-flight rid -> Request
        self._t_enq: Dict[int, float] = {}
        self._out: Dict[int, List[int]] = {}
        self._per_req: Dict[int, RequestStats] = {}
        self._slots: List[Optional[_Slot]] = [None] * self.max_batch
        self._cache = self._fresh_cache()
        if self.kv_page_size:
            from repro.serve.kvcache import PagedKVCache
            self._kv = PagedKVCache(
                self.cfg, max_batch=self.max_batch,
                cache_len=self.cache_len, page_size=self.kv_page_size,
                n_pages=self.kv_pages, kv_dtype=self.kv_dtype,
                prefix_reuse=self.prefix_reuse)
        self._draft_cache = None
        if self.spec_k:
            dc = self.draft_model.init_cache(self.max_batch, self.cache_len)
            dc["pos"] = jnp.zeros((self.max_batch,), jnp.int32)
            self._draft_cache = dc
        self._spec_inflight = {}
        self._spec = {"proposed": 0, "accepted": 0, "rejected": 0,
                      "bonus": 0, "tokens_emitted": 0, "verify_steps": 0,
                      "draft_steps": 0}
        self._cur = np.zeros((self.max_batch, 1), np.int32)
        self._n_steps = 0          # global batched decode steps
        self._n_prefills = 0
        self._n_submitted = 0
        self._slot_steps_active = 0
        self._t_start = time.perf_counter()

    @property
    def idle(self) -> bool:
        """True when nothing is queued and every slot is free."""
        return not self._queue and all(s is None for s in self._slots)

    @property
    def kv(self):
        """The run's PagedKVCache (None until reset() on a paged engine,
        always None with kv_page_size=0). Chaos tests call its
        check_conservation() through every evict/fence/recover path."""
        return self._kv

    @property
    def queue_depth(self) -> int:
        """Requests admitted by submit() but not yet occupying a slot."""
        return len(self._queue)

    @property
    def active_count(self) -> int:
        """Slots currently decoding a request."""
        return sum(1 for s in self._slots if s is not None)

    @property
    def outputs(self) -> Dict[int, List[int]]:
        """Tokens generated so far this run, {rid: [tok, ...]}."""
        return self._out

    @property
    def request_stats(self) -> Dict[int, RequestStats]:
        """Per-request records of requests FINISHED so far this run."""
        return self._per_req

    def submit(self, r: Request, *, t_enqueue: Optional[float] = None
               ) -> None:
        """Enqueue one request (FIFO). t_enqueue backdates the queue-wait/
        TTFT clock — a router passes the moment the request arrived at the
        router, so latency spans its whole queueing life, including a
        failed first attempt on a replica that died."""
        if self._cache is None:
            self.reset()
        self._queue.append(r)
        self._reqs[r.rid] = r
        self._t_enq[r.rid] = (time.perf_counter() if t_enqueue is None
                              else t_enqueue)
        self._out[r.rid] = []
        self._n_submitted += 1

    def _finish(self, i: int) -> int:
        s = self._slots[i]
        now = time.perf_counter()
        self._per_req[s.rid] = RequestStats(
            rid=s.rid, prompt_len=s.prompt_len, new_tokens=s.n_gen,
            queue_wait_s=s.t_admit - s.t_enqueue,
            ttft_s=s.t_first - s.t_enqueue,
            decode_steps=s.decode_steps, total_s=now - s.t_enqueue,
            tok_per_s=s.n_gen / max(now - s.t_admit, 1e-9))
        self._slots[i] = None
        self._reqs.pop(s.rid, None)
        if self._kv is not None:
            self._kv.release(s.rid)     # terminal outcome: free pages once
        return s.rid

    def step(self) -> StepReport:
        """One scheduler round: refill every free slot from the queue
        (each free slot index gets at most one admission attempt per
        round), then run one batched decode step over the occupied slots.
        Returns a StepReport; with nothing occupied after admission the
        decode is skipped (decoded=0)."""
        admitted: List[int] = []
        finished: List[int] = []
        for i in range(self.max_batch):
            if self._slots[i] is None and self._queue:
                r = self._queue.popleft()
                if r.max_new_tokens < 1:     # nothing to generate
                    self._per_req[r.rid] = RequestStats(
                        rid=r.rid, prompt_len=len(r.prompt),
                        new_tokens=0, queue_wait_s=0.0, ttft_s=0.0,
                        decode_steps=0, total_s=0.0, tok_per_s=0.0)
                    self._reqs.pop(r.rid, None)
                    finished.append(r.rid)
                    continue
                self._cache, slot, first = self._admit(
                    self._cache, i, r, self._t_enq[r.rid])
                self._n_prefills += 1
                self._out[r.rid].append(first)
                self._cur[i, 0] = first
                self._slots[i] = slot
                admitted.append(r.rid)
                if slot.remaining <= 0:      # max_new_tokens == 1
                    finished.append(self._finish(i))
        if not any(s is not None for s in self._slots):
            return StepReport(admitted=admitted, finished=finished,
                              decoded=0, queue_depth=len(self._queue))
        if self.spec_k:
            return self._spec_round(admitted, finished)
        active = np.array([s is not None for s in self._slots])
        logits, self._cache = self._decode(self.params, self._cache,
                                           jnp.asarray(self._cur),
                                           jnp.asarray(active))
        self._n_steps += 1
        self._slot_steps_active += int(active.sum())
        toks = self._sample_rows(logits, self._slots)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            tok = int(toks[i])
            self._out[s.rid].append(tok)
            self._cur[i, 0] = tok
            s.n_gen += 1
            s.remaining -= 1
            s.decode_steps += 1
            if self._kv is not None:
                # decode growth: allocate pages as the row crosses a
                # page boundary (the written line is at pos-1; pos covers
                # prompt_len + n_gen lines)
                self._kv.grow(s.rid, s.prompt_len + s.n_gen)
            if s.remaining <= 0:
                finished.append(self._finish(i))
        return StepReport(admitted=admitted, finished=finished,
                          decoded=int(active.sum()),
                          queue_depth=len(self._queue))

    def _spec_round(self, admitted: List[int], finished: List[int]
                    ) -> StepReport:
        """One speculative scheduler round (replaces the plain batched
        decode when spec_k > 0): propose spec_k draft candidates per
        active slot, verify them all plus the committed current token in
        ONE target forward, accept host-side (serve/spec.py), then roll
        every slot's cache position back to its last accepted line.

        Position contract: a round starts with both caches' pos at the
        committed offset P = prompt_len + n_gen - 1 (cur's line unwritten,
        the plain-decode invariant). Propose advances the draft to
        P + spec_k + 1 (spec_k candidate feeds plus one catch-up feed that
        writes the last candidate's line — needed only on a full accept);
        verify advances the target to the speculated tip P + spec_k + 1.
        After accepting a tokens (+1 correction or bonus), BOTH roll back
        to P + a + 1. Rejected candidates' lines stay in the buffer beyond
        the committed region: invisible (cache_len masking) and
        overwritten by the step that first reaches them."""
        from repro.serve import spec as spec_lib
        k = self.spec_k
        active = np.array([s is not None for s in self._slots])
        act_j = jnp.asarray(active)
        n_active = int(active.sum())
        temps = np.array([s.temperature if s else 0.0 for s in self._slots],
                         np.float32)
        rids = np.array([s.rid if s else -1 for s in self._slots], np.int32)
        base_gen = np.array([s.n_gen if s else 0 for s in self._slots],
                            np.int32)
        # ---- propose: k sequential draft steps + the catch-up feed
        draft_toks = np.zeros((self.max_batch, k), np.int32)
        draft_logits = np.zeros((self.max_batch, k, self.cfg.vocab_size),
                                np.float32)
        feed = jnp.asarray(self._cur)
        for j in range(k):
            dlg, self._draft_cache = self._draft_decode(
                self.draft_params, self._draft_cache, feed, act_j)
            toks = np.asarray(self._spec_sample(
                dlg, jnp.asarray(temps), self.rng, jnp.asarray(rids),
                jnp.asarray(base_gen + j),
                jnp.int32(spec_lib.SALT_DRAFT)))
            draft_toks[:, j] = toks
            draft_logits[:, j] = np.asarray(dlg[:, 0], np.float32)
            feed = jnp.asarray(toks[:, None])
        _, self._draft_cache = self._draft_decode(
            self.draft_params, self._draft_cache, feed, act_j)
        # ---- verify: one (k+1)-position target forward over
        # [cur, d_0..d_{k-1}]; device pos advances to the speculated tip,
        # recorded in _spec_inflight so a mid-verify eviction (a fenced
        # replica) can roll back to the last accepted line
        for i, s in enumerate(self._slots):
            if s is not None:
                self._spec_inflight[i] = s.prompt_len + s.n_gen - 1
        vtoks = np.concatenate([self._cur, draft_toks], axis=1)
        vlg, self._cache = self._verify(self.params, self._cache,
                                        jnp.asarray(vtoks), act_j)
        vlg = np.asarray(vlg, np.float32)
        self._n_steps += 1
        self._slot_steps_active += n_active
        self._spec["verify_steps"] += n_active
        self._spec["draft_steps"] += n_active * (k + 1)
        self._spec["proposed"] += n_active * k
        # ---- accept + commit (host), then roll positions back
        new_pos = np.asarray(self._cache["pos"]).copy()
        draft_pos = np.asarray(self._draft_cache["pos"]).copy()
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            emitted, kinds = spec_lib.accept_tokens(
                draft_toks[i], draft_logits[i], vlg[i],
                temperature=s.temperature, base_key=self.rng, rid=s.rid,
                n_gen=s.n_gen)
            # cap at the request budget; counters follow the kept tokens
            # so accepted + rejected + bonus == tokens_emitted survives
            m = min(len(emitted), s.remaining)
            emitted, kinds = emitted[:m], kinds[:m]
            self._out[s.rid].extend(emitted)
            self._cur[i, 0] = emitted[-1]
            s.n_gen += m
            s.remaining -= m
            s.decode_steps += 1
            for kind in kinds:
                self._spec[kind] += 1
            self._spec["tokens_emitted"] += m
            committed = s.prompt_len + s.n_gen - 1
            new_pos[i] = committed
            draft_pos[i] = committed
            if self._kv is not None:
                # page accounting covers committed lines only — the
                # speculative tip is never page-backed
                self._kv.grow(s.rid, s.prompt_len + s.n_gen)
            self._spec_inflight.pop(i, None)
            if s.remaining <= 0:
                finished.append(self._finish(i))
        self._cache["pos"] = jnp.asarray(new_pos)
        self._draft_cache["pos"] = jnp.asarray(draft_pos)
        return StepReport(admitted=admitted, finished=finished,
                          decoded=n_active, queue_depth=len(self._queue))

    def evict_inflight(self, rids: Optional[Iterable[int]] = None
                       ) -> Tuple[List[Request], int]:
        """Pull unfinished requests (occupied slots first, then the
        waiting queue) OUT of the engine. Two callers:

          * failover (rids=None): a router fencing a dead replica evicts
            EVERYTHING so survivors can re-serve it;
          * targeted eviction (rids={...}): the router's deadline sweep
            removes exactly the expired requests — batch-mates keep
            decoding undisturbed (their sample keys are per-request, so
            their streams cannot shift).

        Partial outputs and timing for the evicted rids are discarded — a
        re-queued request restarts from scratch, and the per-request
        fold_in(rid, i) sample keys make the restart token-for-token
        identical to an undisturbed run (the chaos-tier contract).
        Returns (evicted requests, tokens thrown away). The evicted
        slots' cache rows need no scrubbing: a freed slot's pos is held
        (its rows are masked) until the next admission overwrites them —
        PROVIDED the held pos never overstates the row's committed
        content. A spec engine evicted mid-verify violates that (device
        pos sits at the speculated tip), so spec slots roll back to the
        last ACCEPTED line here."""
        target = None if rids is None else set(rids)
        evicted: List[Request] = []
        wasted = 0
        for i, s in enumerate(self._slots):
            if s is None or (target is not None and s.rid not in target):
                continue
            if self.spec_k and self._cache is not None:
                # mid-verify eviction: roll the slot back to the last
                # accepted token, never the speculated tip (regression:
                # tests/test_spec_decode.py). _spec_inflight holds the
                # committed offset recorded at verify launch; outside a
                # round it is empty and the fallback equals device pos.
                committed = self._spec_inflight.pop(
                    i, s.prompt_len + s.n_gen - 1)
                self._cache["pos"] = \
                    self._cache["pos"].at[i].set(committed)
                self._draft_cache["pos"] = \
                    self._draft_cache["pos"].at[i].set(committed)
            evicted.append(self._reqs.pop(s.rid))
            wasted += len(self._out.pop(s.rid, []))
            self._t_enq.pop(s.rid, None)
            self._slots[i] = None
            if self._kv is not None:
                # slot eviction is this rid's terminal outcome here —
                # release exactly once (queued evictions below never
                # reached _admit, so they hold no pages)
                self._kv.release(s.rid)
        keep: deque = deque()
        while self._queue:
            r = self._queue.popleft()
            if target is not None and r.rid not in target:
                keep.append(r)
                continue
            evicted.append(self._reqs.pop(r.rid, r))
            wasted += len(self._out.pop(r.rid, []))
            self._t_enq.pop(r.rid, None)
        self._queue = keep
        self._n_submitted -= len(evicted)
        return evicted, wasted

    def finalize(self) -> Dict[str, Any]:
        """Aggregate this run's counters into the engine-stats dict
        (also stored on last_stats). run() calls it after draining; a
        stepwise driver calls it when it stops driving the engine."""
        wall = time.perf_counter() - self._t_start
        engine_stats = aggregate_engine_stats(
            self._per_req, n_requests=self._n_submitted,
            n_steps=self._n_steps, n_prefills=self._n_prefills,
            slot_steps_active=self._slot_steps_active,
            max_batch=self.max_batch, wall_s=wall)
        if self.mesh is not None:
            per_dev = self.device_stats()
            engine_stats["devices"] = len(per_dev)
            engine_stats["per_device"] = [
                {**d, "occupancy": engine_stats["occupancy"],
                 "tok_per_s": engine_stats["tok_per_s"]}
                for d in per_dev]
        if self._kv is not None:
            # merged here (not in aggregate_engine_stats, whose schema is
            # pinned by tests/test_serve_stats.py)
            engine_stats["kvcache"] = self._kv.stats()
        if self.spec_k and self._spec is not None:
            # same pattern as kvcache: merged outside the pinned schema
            sp: Dict[str, Any] = dict(self._spec)
            sp["k"] = self.spec_k
            sp["acceptance_rate"] = (sp["accepted"] / sp["proposed"]
                                     if sp["proposed"] else 0.0)
            sp["accepted_tokens_per_step"] = (
                sp["tokens_emitted"] / sp["verify_steps"]
                if sp["verify_steps"] else 0.0)
            engine_stats["spec"] = sp
        self.last_stats = engine_stats
        return engine_stats

    def run(self, requests: List[Request], *, collect_stats: bool = False):
        """Serve requests with slot-level continuous batching. Returns
        {rid: generated tokens}, or (that, stats) with collect_stats=True.

        stats = {"requests": {rid: RequestStats}, "engine": {...}} — the
        engine dict is what last_stats holds after every run."""
        self.reset()
        for r in requests:
            self._queue.append(r)
            self._reqs[r.rid] = r
            self._t_enq[r.rid] = self._t_start
            self._out[r.rid] = []
        self._n_submitted = len(requests)
        while not self.idle:
            self.step()
        out = self._out
        engine_stats = self.finalize()
        if collect_stats:
            return out, {"requests": self._per_req, "engine": engine_stats}
        return out
