"""Paged K/V cache with prefix reuse for the slot scheduler.

The serving engine's decode cache is one static `(max_batch, cache_len)`
allocation per K/V leaf: every slot pins worst-case HBM whether its
request uses 6 tokens or 600, and two requests sharing a system prompt
re-prefill it twice. At the ROADMAP's millions-of-users scale the cache
IS the memory hierarchy (the paper's lesson applied to serving), so this
module makes it a managed resource:

  * `PagedKVCache` — fixed-size cache pages (`page_size` K/V lines per
    page, spanning every pageable leaf at one page id) with a
    PER-REQUEST BLOCK TABLE: pages are allocated at admission and as
    decode crosses page boundaries, and freed exactly once when the
    request reaches a terminal outcome (finish or eviction). The free
    list is a FIFO over page ids, so identical runs allocate identical
    pages — paging never perturbs determinism.
  * Prefix reuse — prompt prefixes are hashed at PAGE granularity into a
    chained index (`(depth, sha1(tokens[:depth*page]))` -> page id).
    Admission walks the chain; on a hit the cached K/V pages are copied
    into the slot's cache rows and only the unseen suffix is prefilled
    (`Model.prefill_continue`), so a shared system prompt is prefilled
    once per replica. Shared pages are refcounted (request admission
    takes a reference, release drops it); they are read-only — a
    request's own lines live in its slot rows, so sharing needs no
    copy-on-write fault path, just the refcount that keeps a page alive
    while any admitted request still maps it.
  * int8 K/V pages (`kv_dtype="int8"`) — pages quantize on the way into
    the pool with one symmetric per-page scale and dequantize on restore
    (`quantize_page`/`dequantize_page`). Opt-in: the accuracy delta is
    pinned in tests/test_kvcache.py and reported by the `kvcache` bench
    table; the default bf16 pool is bit-exact.

Bit-exactness contract: with the default dtype, paged serving produces
bit-identical tokens to the static-cache engine across every model
family. Causal attention makes prefix K/V position-pure (line i depends
only on tokens[:i+1] and absolute RoPE positions), so restored pages are
bit-identical to recomputed ones; families whose decode state is not
paged K/V (ssm's recurrent state, hybrid's window ring) simply report
`pageable=False` and the engine falls through to its unpaged path.

Accounting: `pages_allocated == pages_freed + pages_live` is a hard
invariant (`check_conservation`), asserted by the router chaos tier
through every evict/fence/recover path. `stats()` feeds the engine's
`last_stats["kvcache"]` block: prefix hit rate, prefill tokens saved,
live-page occupancy, and the measured bytes/slot against the static
layout's worst case.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

INT8_MAX = 127.0

# cache leaves that hold full-context K/V lines, per family. hybrid's k/v
# is a window ring buffer (slot index = pos % window — a page table over
# it would alias lines) and ssm has no K/V at all; encdec's xk/xv are
# whole-prompt cross-attention lines with no decode growth, left dense.
_PAGEABLE_LEAVES: Dict[str, Tuple[str, ...]] = {
    "dense": ("k", "v"),
    "vlm": ("k", "v"),
    "moe": ("k", "v", "dk", "dv"),
    "encdec": ("k", "v"),
}

# prefix reuse needs a suffix-prefill path whose numerics match the cold
# prefill bit-for-bit. Pure-attention decoder-only stacks have one
# (Model.prefill_continue); vlm prepends vis tokens ahead of the text
# (page hashes would mix modalities), moe's expert capacity is derived
# from the prefilled token COUNT (a suffix-only prefill changes it), and
# encdec needs the encoder pass regardless. Paging (block tables,
# conservation, occupancy) still applies to all of them.
_PREFIX_FAMILIES = ("dense",)


def quantize_page(page: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization with ONE scale per page (the page is
    the quantization granule — per-page scales are what the int8 pool
    stores). Returns (int8 page, f32 scalar scale)."""
    amax = jnp.max(jnp.abs(page.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(page.astype(jnp.float32) / scale),
                 -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize_page(q: jax.Array, scale: jax.Array,
                    dtype=jnp.bfloat16) -> jax.Array:
    """Inverse of quantize_page (lossy: the roundtrip error bound is
    pinned in tests/test_kvcache.py)."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _hash_tokens(tokens: np.ndarray) -> str:
    return hashlib.sha1(
        np.ascontiguousarray(tokens, dtype=np.int32).tobytes()).hexdigest()


@dataclasses.dataclass
class _IndexEntry:
    """One shared prefix page: the `depth`-th page of some prompt chain."""
    page_id: int
    refcount: int = 0            # admitted requests currently mapping it


@dataclasses.dataclass
class _BlockTable:
    """Per-request page map: `shared` pages are index-owned prefix pages
    this request holds references on; `private` pages back its tail
    prompt lines and generated tokens."""
    shared: List[int]
    private: List[int]
    ctx_len: int                 # lines currently covered by allocation

    def pages(self) -> List[int]:
        return self.shared + self.private


@dataclasses.dataclass
class PrefixHit:
    """Admission-time prefix lookup result: the first `tokens` prompt
    positions are covered by cached pages `page_ids` (page granularity,
    always < the full prompt so the last real token is still computed
    and its logits sampled)."""
    tokens: int
    page_ids: List[int]


class PagedKVCache:
    """Page allocator + prefix index for one ServeEngine (see module
    docstring). Host-side state is plain Python (deterministic FIFO free
    list); device-side state is the per-leaf page pools the jitted
    copy-in/copy-out helpers read and write.

    Example (dense family)::

        from repro.configs.base import get_config, reduce_config
        from repro.serve.kvcache import PagedKVCache
        cfg = reduce_config(get_config("qwen2-1.5b"), layers=2,
                            d_model=64, vocab=128)
        kv = PagedKVCache(cfg, max_batch=2, cache_len=64, page_size=8)
        kv.admit(rid=0, prompt_tokens=None, prompt_len=10, max_new=6)
        kv.release(0)
        kv.check_conservation()
    """

    def __init__(self, cfg: ModelConfig, *, max_batch: int, cache_len: int,
                 page_size: int, n_pages: Optional[int] = None,
                 kv_dtype: str = "bf16", prefix_reuse: bool = True):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if kv_dtype not in ("bf16", "int8"):
            raise ValueError(f"kv_dtype must be 'bf16' or 'int8', "
                             f"got {kv_dtype!r}")
        self.cfg = cfg
        self.page_size = page_size
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.kv_dtype = kv_dtype
        self.leaves = _PAGEABLE_LEAVES.get(cfg.family, ())
        if cfg.family == "moe" and not cfg.first_k_dense:
            self.leaves = ("k", "v")     # no dk/dv leaves in the cache
        self.pageable = bool(self.leaves)
        self.prefix_reuse = (prefix_reuse and self.pageable
                             and cfg.family in _PREFIX_FAMILIES)
        self.pages_per_slot = -(-cache_len // page_size)
        # worst case every slot fully grown, plus index headroom so a
        # standing shared prefix never starves slot growth
        self.n_pages = (n_pages if n_pages is not None
                        else (max_batch + 2) * self.pages_per_slot)

        # device pools: one slab per pageable leaf at each page id.
        # leaf layout mirrors the slot cache: (L, page, kvh, hd) per page.
        from repro.models.layers import PARAM_DTYPE
        self._param_dtype = PARAM_DTYPE
        pool_dtype = jnp.int8 if kv_dtype == "int8" else PARAM_DTYPE
        self.pools: Dict[str, jax.Array] = {}
        self.scales: Dict[str, jax.Array] = {}
        self._leaf_layers: Dict[str, int] = {}
        L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        for name in self.leaves:
            ll = (cfg.first_k_dense if name in ("dk", "dv")
                  else (L - cfg.first_k_dense if cfg.family == "moe" else L))
            self._leaf_layers[name] = ll
            self.pools[name] = jnp.zeros(
                (ll, self.n_pages, page_size, kvh, hd), pool_dtype)
            if kv_dtype == "int8":
                self.scales[name] = jnp.zeros((self.n_pages,), jnp.float32)

        # host accounting
        self._free: deque = deque(range(self.n_pages))
        self._tables: Dict[int, _BlockTable] = {}
        self._index: "OrderedDict[Tuple[int, str], _IndexEntry]" \
            = OrderedDict()
        self._index_pages = 0
        # conservation + stats counters
        self.pages_allocated = 0
        self.pages_freed = 0
        self.peak_live = 0
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefill_tokens_saved = 0
        self._admitted = 0
        self._pages_at_admit: List[int] = []

        if self.pageable:
            self._copy_out = jax.jit(self._copy_out_impl)
            self._copy_in = jax.jit(self._copy_in_impl)

    # ------------------------------------------------------------ page math

    def page_bytes(self) -> int:
        """HBM bytes of one page across every pageable leaf (pool dtype,
        plus the per-page scales for int8)."""
        total = 0
        for name in self.leaves:
            total += int(np.prod(self.pools[name].shape[2:])) \
                * self._leaf_layers[name] * self.pools[name].dtype.itemsize
            if self.kv_dtype == "int8":
                total += 4
        return total

    def static_bytes_per_slot(self) -> int:
        """What the static layout pins per slot: the full cache_len worth
        of pageable lines (the paging win's denominator)."""
        return self.pages_per_slot * self.page_bytes()

    @property
    def pages_live(self) -> int:
        return self.pages_allocated - self.pages_freed

    def check_conservation(self) -> None:
        """pages allocated == pages freed + pages live, and the free list
        accounts for every id not live. Chaos tests call this after every
        fence/recover/deadline storm."""
        live = sum(len(t.private) for t in self._tables.values()) \
            + self._index_pages
        assert self.pages_allocated == self.pages_freed + live, (
            f"page conservation violated: allocated={self.pages_allocated} "
            f"freed={self.pages_freed} live={live}")
        assert len(self._free) + live == self.n_pages, (
            f"free-list leak: free={len(self._free)} live={live} "
            f"total={self.n_pages}")

    def _alloc_page(self) -> int:
        if not self._free:
            self._evict_index_page()
        if not self._free:
            raise RuntimeError(
                f"page pool exhausted: {self.n_pages} pages, "
                f"{self._index_pages} pinned by the prefix index")
        pid = self._free.popleft()
        self.pages_allocated += 1
        self.peak_live = max(self.peak_live, self.pages_live)
        return pid

    def _free_page(self, pid: int) -> None:
        self.pages_freed += 1
        self._free.append(pid)

    def _evict_index_page(self) -> None:
        """Drop the oldest unreferenced prefix page (insertion order —
        deterministic). A missing link truncates its chain at lookup, so
        deeper entries just become unreachable and evictable later."""
        for key, ent in self._index.items():
            if ent.refcount == 0:
                del self._index[key]
                self._index_pages -= 1
                self._free_page(ent.page_id)
                return

    # ------------------------------------------------------- request lifecycle

    def lookup_prefix(self, prompt: np.ndarray) -> Optional[PrefixHit]:
        """Walk the page-granularity hash chain over `prompt`. The hit is
        capped below the full prompt so the suffix prefill always has at
        least one real token to produce logits from."""
        if not self.prefix_reuse:
            return None
        self.prefix_lookups += 1
        max_depth = (len(prompt) - 1) // self.page_size
        ids: List[int] = []
        for depth in range(1, max_depth + 1):
            key = (depth, _hash_tokens(prompt[: depth * self.page_size]))
            ent = self._index.get(key)
            if ent is None:
                break
            ids.append(ent.page_id)
        if not ids:
            return None
        self.prefix_hits += 1
        saved = len(ids) * self.page_size
        self.prefill_tokens_saved += saved
        return PrefixHit(tokens=saved, page_ids=ids)

    def admit(self, rid: int, prompt_tokens: Optional[np.ndarray],
              prompt_len: int, max_new: int) -> Optional[PrefixHit]:
        """Open `rid`'s block table: take references on any prefix hit,
        then allocate private pages covering the prompt tail. Returns the
        hit (None on miss / non-prefix families) so the engine can
        restore the cached pages and prefill only the suffix."""
        if not self.pageable:
            return None
        assert rid not in self._tables, f"rid {rid} already admitted"
        hit = (self.lookup_prefix(prompt_tokens)
               if prompt_tokens is not None else None)
        shared: List[int] = []
        covered = 0
        if hit is not None:
            shared = list(hit.page_ids)
            covered = hit.tokens
            for depth, pid in enumerate(shared, start=1):
                key = (depth,
                       _hash_tokens(prompt_tokens[: depth * self.page_size]))
                self._index[key].refcount += 1
        need = -(-prompt_len // self.page_size) - len(shared)
        private = [self._alloc_page() for _ in range(need)]
        self._tables[rid] = _BlockTable(shared=shared, private=private,
                                        ctx_len=prompt_len)
        self._admitted += 1
        self._pages_at_admit.append(len(shared) + len(private))
        return hit

    def grow(self, rid: int, ctx_len: int) -> int:
        """Decode growth: extend `rid`'s block table to cover `ctx_len`
        lines, allocating pages as generation crosses page boundaries.
        Returns how many pages were added."""
        t = self._tables.get(rid)
        if t is None:
            return 0
        have = len(t.shared) + len(t.private)
        need = -(-ctx_len // self.page_size)
        added = 0
        while have + added < need:
            t.private.append(self._alloc_page())
            added += 1
        t.ctx_len = max(t.ctx_len, ctx_len)
        return added

    def release(self, rid: int) -> None:
        """Terminal outcome for `rid`: free its private pages and drop
        its references on shared prefix pages — exactly once (a second
        release of the same rid is a scheduler bug and asserts)."""
        if not self.pageable:
            return
        t = self._tables.pop(rid, None)
        assert t is not None, f"release of unadmitted/released rid {rid}"
        for pid in t.private:
            self._free_page(pid)
        # shared pages stay index-owned; the refcount only gates eviction
        for depth, pid in enumerate(t.shared, start=1):
            for key, ent in self._index.items():
                if ent.page_id == pid:
                    assert ent.refcount > 0, f"refcount underflow page {pid}"
                    ent.refcount -= 1
                    break

    def release_all(self) -> None:
        """Free every open block table (engine reset / replica recovery)."""
        for rid in list(self._tables):
            self.release(rid)

    # -------------------------------------------------------- prefix pages

    def insert_prefix(self, prompt: np.ndarray, rid: int, cache: Any,
                      slot: int) -> Any:
        """After a cold (or suffix) prefill of `slot`, publish the
        prompt's full pages into the index: each previously-unseen depth
        gets a fresh page, the slot's K/V lines are copied out into it
        (quantizing when the pool is int8), and the admitting request
        takes a reference. Returns the (unchanged) cache for symmetry."""
        if not self.prefix_reuse:
            return cache
        t = self._tables[rid]
        full_pages = (len(prompt) - 1) // self.page_size
        for depth in range(len(t.shared) + 1, full_pages + 1):
            key = (depth, _hash_tokens(prompt[: depth * self.page_size]))
            if key in self._index:
                ent = self._index[key]
            else:
                pid = self._alloc_page()
                self._index[key] = ent = _IndexEntry(page_id=pid)
                self._index_pages += 1
                self._page_out(cache, slot, depth - 1, pid)
            ent.refcount += 1
            t.shared.append(ent.page_id)
            # the depth is now backed by a shared page; retire one
            # private page that covered it
            if t.private:
                self._free_page(t.private.pop())
        return cache

    def restore_prefix(self, cache: Any, slot: int, hit: PrefixHit) -> Any:
        """Copy a prefix hit's pages back into `slot`'s cache rows (the
        inverse of insert_prefix; dequantizes int8 pools)."""
        for j, pid in enumerate(hit.page_ids):
            cache = self._page_in(cache, slot, j, pid)
        return cache

    # ------------------------------------------------- jitted page movement

    def _copy_out_impl(self, pools, scales, cache, slot, page_idx, pid):
        start = page_idx * self.page_size
        new_pools, new_scales = {}, {}
        for name in self.leaves:
            ll = self._leaf_layers[name]
            kvh, hd = cache[name].shape[-2], cache[name].shape[-1]
            src = jax.lax.dynamic_slice(
                cache[name], (0, slot, start, 0, 0),
                (ll, 1, self.page_size, kvh, hd))[:, 0]
            if self.kv_dtype == "int8":
                q, sc = quantize_page(src)
                new_pools[name] = jax.lax.dynamic_update_slice(
                    pools[name], q[:, None], (0, pid, 0, 0, 0))
                new_scales[name] = scales[name].at[pid].set(sc)
            else:
                new_pools[name] = jax.lax.dynamic_update_slice(
                    pools[name], src.astype(pools[name].dtype)[:, None],
                    (0, pid, 0, 0, 0))
        return new_pools, new_scales

    def _copy_in_impl(self, pools, scales, cache, slot, page_idx, pid):
        start = page_idx * self.page_size
        new_cache = dict(cache)
        for name in self.leaves:
            ll = self._leaf_layers[name]
            kvh, hd = cache[name].shape[-2], cache[name].shape[-1]
            page = jax.lax.dynamic_slice(
                pools[name], (0, pid, 0, 0, 0),
                (ll, 1, self.page_size, kvh, hd))[:, 0]
            if self.kv_dtype == "int8":
                page = dequantize_page(page, scales[name][pid],
                                       cache[name].dtype)
            new_cache[name] = jax.lax.dynamic_update_slice(
                cache[name], page[:, None].astype(cache[name].dtype),
                (0, slot, start, 0, 0))
        return new_cache

    def _page_out(self, cache, slot: int, page_idx: int, pid: int) -> None:
        self.pools, new_scales = self._copy_out(
            self.pools, self.scales, cache, np.int32(slot),
            np.int32(page_idx), np.int32(pid))
        if self.kv_dtype == "int8":
            self.scales = new_scales

    def _page_in(self, cache, slot: int, page_idx: int, pid: int):
        return self._copy_in(self.pools, self.scales, cache,
                             np.int32(slot), np.int32(page_idx),
                             np.int32(pid))

    # ---------------------------------------------------------------- stats

    def stats(self) -> Dict[str, Any]:
        """The engine's `last_stats["kvcache"]` block (all deterministic,
        so the bench rows built from it gate cleanly)."""
        mean_pages = (float(np.mean(self._pages_at_admit))
                      if self._pages_at_admit else 0.0)
        static_b = self.static_bytes_per_slot()
        bytes_slot = mean_pages * self.page_bytes()
        return {
            "page_size": self.page_size,
            "n_pages": self.n_pages,
            "pages_allocated": self.pages_allocated,
            "pages_freed": self.pages_freed,
            "pages_live": self.pages_live,
            "peak_live_pages": self.peak_live,
            "page_occupancy": self.peak_live / self.n_pages,
            "index_pages": self._index_pages,
            "prefix_lookups": self.prefix_lookups,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_rate": (self.prefix_hits / self.prefix_lookups
                                if self.prefix_lookups else 0.0),
            "prefill_tokens_saved": self.prefill_tokens_saved,
            "kv_bytes_per_slot": bytes_slot,
            "static_bytes_per_slot": static_b,
            "bytes_per_slot_reduction": (1.0 - bytes_slot / static_b
                                         if static_b else 0.0),
            "kv_dtype": self.kv_dtype,
        }
