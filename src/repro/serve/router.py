"""Multi-replica DP router: trace-driven load balancing over ServeEngines
with heartbeat failover, admission control, and replica recovery.

PR 5 made ONE tensor-parallel replica bit-exact; production is N replicas
behind a router. `Router` owns N `ServeEngine`s (data-parallel — same
config/params, independent slot pools; each optionally exact-TP via the
engine's `mesh=` path) and drives them with the engine's stepwise API on
a deterministic virtual clock:

  one tick = one scheduler round (admission + one batched decode step)
  on every healthy replica.

Per tick, in order: apply `FaultPlan` events (kill / stall / recover),
admit due retries and trace arrivals through the bounded-queue shed
policy, sweep deadline-expired requests out of the queue and the
in-flight slots, check replica heartbeats and fence stale replicas
(re-queuing their in-flight work), dispatch the router queue
least-loaded-first, then step every healthy replica (which also beats
its heartbeat). Because arrivals, dispatch, admission, shedding, retry
backoff, and sampling are all functions of the trace seed and the tick
counter — never the wall clock — every token, queue-depth sample, and
tick-denominated latency is reproducible, which is what lets chaos tests
assert exact outcomes and lets `report.py --compare` gate tail-latency
rows across machines.

Terminal outcomes — every request ends in EXACTLY ONE of:

  * `completed`       — full output produced; bit-exact vs an undisturbed
                        single-engine run (per-request fold_in(rid, i)
                        sample keys make retries and failover safe);
  * `shed`            — rejected by admission control (bounded queue or
                        overload brown-out) with its retry budget spent;
  * `deadline_missed` — its `deadline_ticks` slack expired before
                        completion; evicted from the queue or mid-flight
                        (`ServeEngine.evict_inflight(rids=...)`), partial
                        tokens counted as waste.

Overload model (docs/serving.md §Overload & recovery):

  * `max_queue` bounds the router admission queue. A full queue sheds
    deterministically: "reject-newest" (default) refuses the arriving
    request; "reject-oldest" sheds the head of the queue to admit it.
  * A shed request with retry budget left re-enters after an exponential
    backoff in ticks (`dist.fault.backoff_ticks`); budget exhausted means
    terminal `shed`.
  * An optional windowed `OverloadConfig` controller brown-outs
    admissions under sustained pressure (queue depth above `queue_high`
    for a full window, or windowed p99 admission-TTFT above
    `ttft_p99_high`) and restores once the queue drains to `queue_low`.
    Fence-evicted work is exempt from admission control — it was already
    admitted once and re-enters at the FRONT of the queue.

Failure model (wired through repro.dist.fault):

  * Every replica owns a `HeartbeatFile` and beats its current tick each
    healthy round — the same liveness file the training watchdog uses.
  * The router reads each beat and declares a replica DEAD when its last
    beaten tick lags more than `stale_after_ticks` behind (tick-lag
    staleness: the deterministic analogue of `HeartbeatFile.stale()`'s
    wall-clock timeout). A killed replica stops stepping and beating; a
    stalled one freezes for `FaultEvent.duration` ticks — a long enough
    stall is indistinguishable from death and gets fenced too.
  * Fencing a replica evicts its in-flight requests
    (`ServeEngine.evict_inflight`) back onto the router queue, oldest
    first, with their ORIGINAL enqueue times, and the replica never
    rejoins on its own (no resurrection: a fenced replica that wakes up
    again must not double-serve re-queued work). Re-queued requests
    restart from scratch on a survivor; partial tokens from the dead
    replica are discarded and counted as `wasted_toks`.
  * `FaultPlan.recover(replica, at_tick)` is the ONLY way back: a fresh
    process takes over the replica slot — any in-flight work is evicted
    back to the router (conservation), the engine rebuilds fresh state
    from the shared params, the heartbeat is cleared and re-beaten, and
    the replica rejoins least-loaded dispatch. The semantics are uniform
    (kill-then-recover, fence-then-recover, or a rolling restart of a
    healthy replica all behave identically) and idempotent across
    repeated `flap()` cycles.
  * A `StepWatchdog` per replica (EWMA straggler detector) observes real
    step wall-times; its events are reported in the stats but never
    steer scheduling, so they cannot break determinism.

The router is host-side and CPU-testable: `FaultPlan().flap(1, at_tick=8,
down_ticks=4)` makes a kill→recover cycle a deterministic unit-testable
event, no process murder required (tests/test_router_chaos.py,
tests/test_router_overload.py).
"""

from __future__ import annotations

import dataclasses
import heapq
import tempfile
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.fault import HeartbeatFile, StepWatchdog, backoff_ticks
from repro.serve.engine import (Request, RequestStats, ServeEngine,
                                percentile, request_tpot_s)
from repro.serve.trace import Trace

_NO_DEADLINE = 1 << 62


# --------------------------------------------------------------- fault plan

@dataclasses.dataclass
class FaultEvent:
    """One scripted fault: at `tick`, `replica` is killed (permanently
    stops stepping and beating), stalled (frozen for `duration` ticks,
    then resumes — unless the router fenced it first), or recovered (a
    fresh process takes over the replica slot and rejoins dispatch).
    `seq` is the insertion index FaultPlan assigns — same-tick events
    apply in insertion order, so kill+recover on one tick is legal and
    deterministic."""
    tick: int
    replica: int
    kind: str                 # "kill" | "stall" | "recover"
    duration: int = 0         # stall length in ticks (kind == "stall")
    seq: int = -1             # insertion index (assigned by FaultPlan)


class FaultPlan:
    """A deterministic fault-injection script for Router.run.

    Example::

        from repro.serve.router import FaultPlan
        plan = FaultPlan().kill(1, at_tick=8).recover(1, at_tick=12)
        plan.flap(0, at_tick=20, down_ticks=3, times=2)
        assert [e.kind for e in plan.events_at(8)] == ["kill"]
    """

    def __init__(self, events: Optional[List[FaultEvent]] = None):
        self.events: List[FaultEvent] = []
        self._seq = 0
        for e in (events or []):
            self._add(e)

    def _add(self, ev: FaultEvent) -> "FaultPlan":
        ev.seq = self._seq
        self._seq += 1
        self.events.append(ev)
        return self

    def kill(self, replica: int, *, at_tick: int) -> "FaultPlan":
        return self._add(FaultEvent(tick=at_tick, replica=replica,
                                    kind="kill"))

    def stall(self, replica: int, *, at_tick: int, ticks: int
              ) -> "FaultPlan":
        return self._add(FaultEvent(tick=at_tick, replica=replica,
                                    kind="stall", duration=ticks))

    def recover(self, replica: int, *, at_tick: int) -> "FaultPlan":
        """Schedule a fresh process to take over `replica` at `at_tick`:
        in-flight work is evicted back to the router, engine state is
        rebuilt from the shared params, and the replica rejoins
        dispatch."""
        return self._add(FaultEvent(tick=at_tick, replica=replica,
                                    kind="recover"))

    def flap(self, replica: int, *, at_tick: int, down_ticks: int,
             times: int = 1, period: Optional[int] = None) -> "FaultPlan":
        """`times` kill→recover cycles: kill at `at_tick + k*period`,
        recover `down_ticks` later (period defaults to 2*down_ticks).
        The crash-loop scenario — fencing and recovery must both be
        idempotent across cycles."""
        if down_ticks < 1:
            raise ValueError(f"down_ticks must be >= 1, got {down_ticks}")
        if times < 1:
            raise ValueError(f"times must be >= 1, got {times}")
        p = 2 * down_ticks if period is None else period
        if times > 1 and p <= down_ticks:
            raise ValueError(f"period {p} must exceed down_ticks "
                             f"{down_ticks} for repeated flaps")
        for k in range(times):
            t0 = at_tick + k * p
            self.kill(replica, at_tick=t0)
            self.recover(replica, at_tick=t0 + down_ticks)
        return self

    def events_at(self, tick: int) -> List[FaultEvent]:
        """Same-tick events in INSERTION order (stable sort by the
        insertion index). With kill+recover legal on the same tick, which
        one wins must be a property of the plan the test author wrote —
        never a dict/list ordering accident."""
        return sorted((e for e in self.events if e.tick == tick),
                      key=lambda e: e.seq)

    def has_recovery_after(self, tick: int) -> bool:
        """Whether any replica is scheduled to recover strictly after
        `tick` — the all-replicas-dead check must keep ticking toward a
        scripted recovery instead of raising."""
        return any(e.kind == "recover" and e.tick > tick
                   for e in self.events)


# --------------------------------------------------------- overload control

@dataclasses.dataclass
class OverloadConfig:
    """Windowed overload controller knobs for the Router: brown-out
    admissions under sustained pressure, restore when the queue drains.

    Triggers (evaluated once per tick, on tick-denominated state only, so
    the controller is seed-deterministic):

      * queue_high  — brown-out when the end-of-tick queue depth exceeds
                      this for `window_ticks` CONSECUTIVE ticks (None
                      disables the depth trigger);
      * ttft_p99_high — brown-out when the p99 of admission TTFTs (ticks
                      from arrival to slot admission) observed within the
                      trailing window exceeds this (None disables).

    While browned out, every admission attempt (new arrivals and retry
    re-entries — NOT fence-evicted re-queues) is shed through the retry
    path. The brown-out lifts when the router queue drains to
    `queue_low`.

    Example::

        from repro.serve.router import OverloadConfig
        ov = OverloadConfig(window_ticks=6, queue_high=8, queue_low=2)
        assert ov.window_ticks == 6
    """
    window_ticks: int = 8
    queue_high: Optional[int] = None
    ttft_p99_high: Optional[float] = None
    queue_low: int = 0


# ------------------------------------------------------------- SLO summary

def router_slo_summary(ttft_ticks: List[int], tpot_ticks: List[float],
                       ttft_s: List[float], tpot_s: List[float],
                       queue_depth_samples: List[int]) -> Dict[str, Any]:
    """Fold raw per-request latency samples + per-tick queue depths into
    the router's SLO stats (tails via the shared linear-interpolation
    `percentile`; empty samples — e.g. a run where every request was shed
    and nothing completed — degrade to 0.0, pinned by
    tests/test_serve_stats.py against a hand-computed fixture).

    The `_ticks` metrics are deterministic (virtual-clock) and gateable;
    the `_s` metrics are wall clock and informational."""
    return {
        "p50_ttft_ticks": percentile(ttft_ticks, 50),
        "p99_ttft_ticks": percentile(ttft_ticks, 99),
        "p50_tpot_ticks": percentile(tpot_ticks, 50),
        "p99_tpot_ticks": percentile(tpot_ticks, 99),
        "p50_ttft_s": percentile(ttft_s, 50),
        "p99_ttft_s": percentile(ttft_s, 99),
        "p50_tpot_s": percentile(tpot_s, 50),
        "p99_tpot_s": percentile(tpot_s, 99),
        "mean_queue_depth": (float(np.mean(queue_depth_samples))
                             if queue_depth_samples else 0.0),
        "p99_queue_depth": percentile(queue_depth_samples, 99),
        "max_queue_depth": (int(max(queue_depth_samples))
                            if queue_depth_samples else 0),
    }


# ------------------------------------------------------------------ router

# paged K/V counters folded across engine incarnations (sums vs high-water
# marks): recovery resets the engine, the replica's cache history must not
_KV_SUM = ("prefix_lookups", "prefix_hits", "prefill_tokens_saved",
           "pages_allocated", "pages_freed")
_KV_MAX = ("peak_live_pages", "n_pages")


def _fold_kv(acc: Dict[str, Any], kv: Optional[Dict[str, Any]]) -> None:
    if not kv:
        return
    for k in _KV_SUM:
        acc[k] = acc.get(k, 0) + kv.get(k, 0)
    for k in _KV_MAX:
        acc[k] = max(acc.get(k, 0), kv.get(k, 0))


# speculative-decoding counters: all plain sums across incarnations (the
# derived rates are recomputed from the folded sums at aggregation)
_SPEC_SUM = ("proposed", "accepted", "rejected", "bonus", "tokens_emitted",
             "verify_steps", "draft_steps")


def _fold_spec(acc: Dict[str, Any], sp: Optional[Dict[str, Any]]) -> None:
    if not sp:
        return
    for k in _SPEC_SUM:
        acc[k] = acc.get(k, 0) + sp.get(k, 0)


@dataclasses.dataclass
class _Replica:
    idx: int
    engine: ServeEngine
    hb: HeartbeatFile
    watchdog: StepWatchdog
    alive: bool = True            # router's view: dispatchable
    killed: bool = False          # fault plan: dead until recovered
    stall_until: int = -1         # frozen through tick stall_until - 1
    fenced_at: int = -1
    completed: int = 0
    evicted: int = 0
    stalled_ticks: int = 0
    straggler_events: int = 0
    recoveries: int = 0
    # counters folded in from incarnations retired by recover() — the
    # engine resets on recovery, the replica's history must not
    hist_decode_steps: int = 0
    hist_prefills: int = 0
    hist_kv: Dict[str, Any] = dataclasses.field(default_factory=dict)
    hist_spec: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def healthy_at(self, tick: int) -> bool:
        """Whether the replica PROCESS runs this tick (steps + beats) —
        independent of the router's alive/fenced view of it."""
        return not self.killed and tick >= self.stall_until

    def outstanding(self) -> int:
        return self.engine.active_count + self.engine.queue_depth

    def total_decode_steps(self) -> int:
        return self.hist_decode_steps + self.engine.last_stats["decode_steps"]

    def total_prefills(self) -> int:
        return self.hist_prefills + self.engine.last_stats["prefills"]

    def total_kv(self) -> Dict[str, Any]:
        """Replica-lifetime paged-cache counters: history from retired
        incarnations plus the current engine's run ({} when paging is
        off)."""
        acc = dict(self.hist_kv)
        _fold_kv(acc, (self.engine.last_stats or {}).get("kvcache"))
        return acc

    def total_spec(self) -> Dict[str, Any]:
        """Replica-lifetime speculative-decoding counters ({} when spec
        is off)."""
        acc = dict(self.hist_spec)
        _fold_spec(acc, (self.engine.last_stats or {}).get("spec"))
        return acc


class Router:
    """Load-balance a request trace across N replica ServeEngines.

    Replicas share params (data parallel); each may additionally be
    tensor-parallel via `mesh=` exactly as a standalone engine would.
    `rng_seed` is shared so any replica draws the identical per-request
    sample stream — the property failover correctness rests on.

    Overload knobs (all deterministic; docs/serving.md §Overload &
    recovery): `max_queue` bounds the admission queue (None = unbounded,
    the pre-overload behavior), `shed_policy` picks the victim on a full
    queue ("reject-newest" | "reject-oldest"), shed requests retry up to
    `retry_budget` times with exponential backoff
    (`retry_backoff_base * 2**k` ticks, capped at `retry_backoff_cap`),
    and `overload=OverloadConfig(...)` arms the brown-out controller.

    Example (tiny model, CPU; see docs/serving.md §Multi-replica
    DP routing)::

        import jax, repro
        from repro.configs.base import get_config, reduce_config
        from repro.serve.router import FaultPlan, Router
        from repro.serve.trace import TraceConfig, generate_trace
        cfg = reduce_config(get_config("qwen2-1.5b"), d_model=64, vocab=128)
        params = repro.build_model(cfg).init_params(jax.random.PRNGKey(0))
        router = Router(cfg, params, replicas=2, max_batch=2, cache_len=64)
        trace = generate_trace(TraceConfig(n_requests=6, out_max=8,
                                           prompt_max=16))
        out, stats = router.run(trace)
        assert stats["completed"] == 6
    """

    def __init__(self, cfg: ModelConfig, params, *, replicas: int = 2,
                 max_batch: int = 4, cache_len: int = 512,
                 rng_seed: int = 0, mesh=None,
                 heartbeat_dir: Optional[str] = None,
                 stale_after_ticks: int = 3,
                 fault_plan: Optional[FaultPlan] = None,
                 max_ticks: int = 100_000,
                 max_queue: Optional[int] = None,
                 shed_policy: str = "reject-newest",
                 retry_budget: int = 2,
                 retry_backoff_base: int = 1,
                 retry_backoff_cap: int = 32,
                 overload: Optional[OverloadConfig] = None,
                 kv_page_size: int = 0,
                 kv_pages: Optional[int] = None,
                 kv_dtype: str = "bf16",
                 prefix_reuse: bool = True,
                 draft_cfg=None, draft_params=None, spec_k: int = 0):
        if replicas < 1:
            raise ValueError(f"need at least one replica, got {replicas}")
        if shed_policy not in ("reject-newest", "reject-oldest"):
            raise ValueError(f"unknown shed_policy {shed_policy!r} "
                             "(expected 'reject-newest' or 'reject-oldest')")
        if max_queue is not None and max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {max_queue}")
        if retry_budget < 0:
            raise ValueError(f"retry_budget must be >= 0, got {retry_budget}")
        self.cfg = cfg
        self.max_batch = max_batch
        self.stale_after_ticks = stale_after_ticks
        self.fault_plan = fault_plan or FaultPlan()
        self.max_ticks = max_ticks
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        self.retry_budget = retry_budget
        self.retry_backoff_base = retry_backoff_base
        self.retry_backoff_cap = retry_backoff_cap
        self.overload = overload
        self.kv_page_size = kv_page_size
        self.spec_k = spec_k
        hb_dir = heartbeat_dir or tempfile.mkdtemp(prefix="repro-router-hb-")
        self.heartbeat_dir = hb_dir
        self.replicas: List[_Replica] = []
        for i in range(replicas):
            # kv knobs pass straight through: each replica owns its OWN
            # page pool and prefix index (replica-local reuse — a shared
            # prompt prefills once per replica, not once per fleet)
            # spec knobs pass straight through too: the draft params are
            # shared (read-only) but each replica owns its draft cache,
            # and the salted key schedule makes a re-queued request's
            # draws identical on any replica
            eng = ServeEngine(cfg, params, max_batch=max_batch,
                              cache_len=cache_len, rng_seed=rng_seed,
                              mesh=mesh, kv_page_size=kv_page_size,
                              kv_pages=kv_pages, kv_dtype=kv_dtype,
                              prefix_reuse=prefix_reuse,
                              draft_cfg=draft_cfg,
                              draft_params=draft_params, spec_k=spec_k)
            rep = _Replica(
                idx=i, engine=eng,
                hb=HeartbeatFile(hb_dir, name=f"REPLICA_{i}"),
                watchdog=StepWatchdog())
            rep.watchdog.on_straggler = (
                lambda step, dt, ewma, _r=rep: _bump_straggler(_r))
            self.replicas.append(rep)
        self.last_stats: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------- pieces

    def _alive(self) -> List[_Replica]:
        return [r for r in self.replicas if r.alive]

    def _fence(self, rep: _Replica, tick: int, rq: deque,
               arrival_tick: Dict[int, int], where: Dict[int, int]
               ) -> Tuple[int, int]:
        """Declare rep dead: evict its in-flight work back onto the router
        queue (oldest arrivals first, ahead of newer work) and stop
        dispatching to it until a scripted recover event replaces it.
        Returns (n_requeued, wasted_tokens). Idempotent: fencing an
        already-fenced replica evicts nothing and changes nothing."""
        rep.alive = False
        if rep.fenced_at < 0:
            rep.fenced_at = tick
        evicted, wasted = rep.engine.evict_inflight()
        rep.evicted += len(evicted)
        for r in evicted:
            where.pop(r.rid, None)
        evicted.sort(key=lambda r: arrival_tick[r.rid])
        rq.extendleft(reversed(evicted))
        return len(evicted), wasted

    def _recover(self, rep: _Replica, tick: int, rq: deque,
                 arrival_tick: Dict[int, int], where: Dict[int, int]
                 ) -> Tuple[int, int, int, bool]:
        """A fresh process takes over the replica slot. Uniform semantics
        regardless of prior state (killed, fenced, stalled, or healthy
        rolling restart): any in-flight work is evicted back to the FRONT
        of the router queue (request conservation — a killed-but-not-yet-
        fenced replica's work must not vanish with it), the engine
        rebuilds fresh state from the shared params, the heartbeat is
        cleared and immediately re-beaten (so the recovered replica is
        not instantly re-fenced), and the replica rejoins least-loaded
        dispatch. Idempotent across repeated flap() cycles. Returns
        (n_requeued, wasted_tokens, fence_to_recover_ticks, was_fenced).
        """
        evicted, wasted = rep.engine.evict_inflight()
        rep.evicted += len(evicted)
        for r in evicted:
            where.pop(r.rid, None)
        evicted.sort(key=lambda r: arrival_tick[r.rid])
        rq.extendleft(reversed(evicted))
        # fold the retiring incarnation's counters into replica history
        # before reset wipes them
        st = rep.engine.finalize()
        rep.hist_decode_steps += st["decode_steps"]
        rep.hist_prefills += st["prefills"]
        _fold_kv(rep.hist_kv, st.get("kvcache"))
        _fold_spec(rep.hist_spec, st.get("spec"))
        rep.engine.reset()
        was_fenced = not rep.alive
        gap = tick - rep.fenced_at if (was_fenced and rep.fenced_at >= 0) \
            else 0
        rep.alive = True
        rep.killed = False
        rep.stall_until = -1
        rep.fenced_at = -1
        rep.recoveries += 1
        rep.hb.clear()
        rep.hb.beat(tick)
        return len(evicted), wasted, gap, was_fenced

    # ---------------------------------------------------------------- run

    def run(self, trace: Trace, *, tick_s: float = 0.05
            ) -> Tuple[Dict[int, List[int]], Dict[str, Any]]:
        """Drive the trace until every request reaches a terminal outcome
        (completed | shed | deadline_missed). Returns
        ({rid: tokens} for COMPLETED requests, stats — including
        stats["outcomes"], the full {rid: terminal state} map).

        tick_s maps the trace's virtual arrival times onto ticks; it has
        no relation to the wall clock (a tick takes however long the
        replicas' decode steps take)."""
        n_req = len(trace.requests)
        arrivals = deque(zip(trace.arrival_ticks(tick_s),
                             trace.requests))       # ordered by t_arrival
        for rep in self.replicas:
            rep.engine.reset()
            rep.hist_decode_steps = 0
            rep.hist_prefills = 0
            rep.hist_kv = {}
            rep.hist_spec = {}
        t_wall0 = time.perf_counter()
        ov = self.overload

        rq: deque = deque()                  # router-level admission queue
        terminal: Dict[int, str] = {}        # rid -> terminal outcome
        arrival_tick: Dict[int, int] = {}
        arrival_wall: Dict[int, float] = {}
        deadline_at: Dict[int, int] = {}     # rid -> absolute deadline tick
        attempts: Dict[int, int] = {}        # rid -> shed-retry count used
        retry_heap: List[Tuple[int, int, Request]] = []  # (due, seq, req)
        retry_seq = 0
        where: Dict[int, int] = {}           # in-flight rid -> replica idx
        first_tick: Dict[int, int] = {}      # last successful admission
        finish_tick: Dict[int, int] = {}
        done_by: Dict[int, int] = {}         # rid -> replica idx
        out: Dict[int, List[int]] = {}       # completed outputs (harvested
        per_req: Dict[int, RequestStats] = {}  # at finish: engines may
        queue_samples: List[int] = []          # reset on recovery)
        toks_at_tick: List[int] = []         # tokens produced per tick
        requeued = 0
        wasted = 0
        shed_events = 0                      # admission rejections
        retries = 0                          # backoff re-entries scheduled
        max_outstanding = 0
        killed: List[int] = []
        fenced: List[int] = []
        recovered: List[int] = []
        recovery_gaps: List[int] = []        # fence -> recover, per episode
        brown = False
        brownouts = 0
        brownout_ticks = 0
        depth_win: deque = deque(maxlen=ov.window_ticks if ov else 1)
        ttft_win: deque = deque()            # (tick, admission ttft_ticks)

        def _mark(rid: int, state: str) -> None:
            assert rid not in terminal, (rid, state, terminal[rid])
            terminal[rid] = state

        def _try_admit(req: Request) -> None:
            """Admission control for new arrivals and retry re-entries
            (fence/recovery evictions bypass it — already-admitted work
            re-enters at the queue front). Deterministic: shed on
            brown-out or a full queue; a shed request with budget left
            re-enters after an exponential backoff, else it is terminally
            shed."""
            nonlocal shed_events, retries, retry_seq
            victim = None
            full = (self.max_queue is not None
                    and len(rq) >= self.max_queue)
            if brown:
                victim = req                 # brown-out: always the newest
            elif full:
                if self.shed_policy == "reject-oldest" and rq:
                    victim = rq.popleft()    # make room for the newcomer
                    rq.append(req)
                else:
                    victim = req
            if victim is None:
                rq.append(req)
                return
            shed_events += 1
            a = attempts.get(victim.rid, 0)
            if a < self.retry_budget:
                attempts[victim.rid] = a + 1
                due = tick + backoff_ticks(a + 1,
                                           base=self.retry_backoff_base,
                                           cap=self.retry_backoff_cap)
                retry_seq += 1
                heapq.heappush(retry_heap, (due, retry_seq, victim))
                retries += 1
            else:
                _mark(victim.rid, "shed")

        tick = 0
        while len(terminal) < n_req:
            if tick >= self.max_ticks:
                raise RuntimeError(
                    f"router exceeded max_ticks={self.max_ticks} with "
                    f"{n_req - len(terminal)} request(s) unfinished")

            # 1. scripted faults take effect before anything runs;
            # same-tick events apply in plan-insertion order
            for ev in self.fault_plan.events_at(tick):
                rep = self.replicas[ev.replica]
                if ev.kind == "kill":
                    rep.killed = True
                    killed.append(rep.idx)
                elif ev.kind == "stall":
                    rep.stall_until = max(rep.stall_until,
                                          tick + ev.duration)
                elif ev.kind == "recover":
                    n_rq, n_waste, gap, was_fenced = self._recover(
                        rep, tick, rq, arrival_tick, where)
                    requeued += n_rq
                    wasted += n_waste
                    recovered.append(rep.idx)
                    if was_fenced:
                        recovery_gaps.append(gap)
                else:
                    raise ValueError(f"unknown fault kind {ev.kind!r}")

            # 2. admission: due retries first (they are older work), then
            # trace arrivals whose virtual time has come — both through
            # the bounded-queue shed policy
            if brown:
                brownout_ticks += 1
            while retry_heap and retry_heap[0][0] <= tick:
                _, _, req = heapq.heappop(retry_heap)
                _try_admit(req)
            while arrivals and arrivals[0][0] <= tick:
                _, tr = arrivals.popleft()
                rid = tr.request.rid
                arrival_tick[rid] = tick
                arrival_wall[rid] = time.perf_counter()
                if tr.deadline_ticks is not None:
                    deadline_at[rid] = tick + tr.deadline_ticks
                _try_admit(tr.request)

            # 3. deadline sweep: a request that has not completed by the
            # end of its deadline tick is evicted wherever it sits — the
            # router queue, the backoff heap, or mid-flight in a replica
            # (targeted evict_inflight keeps batch-mates undisturbed)
            if deadline_at:
                keep_q: deque = deque()
                while rq:
                    r = rq.popleft()
                    if deadline_at.get(r.rid, _NO_DEADLINE) < tick:
                        _mark(r.rid, "deadline_missed")
                    else:
                        keep_q.append(r)
                rq = keep_q
                if retry_heap:
                    live = [(d, s, r) for (d, s, r) in retry_heap
                            if deadline_at.get(r.rid, _NO_DEADLINE) >= tick]
                    if len(live) != len(retry_heap):
                        for d, s, r in retry_heap:
                            if deadline_at.get(r.rid, _NO_DEADLINE) < tick:
                                _mark(r.rid, "deadline_missed")
                        retry_heap = live
                        heapq.heapify(retry_heap)
                expired_by_rep: Dict[int, set] = {}
                for rid, idx in where.items():
                    if deadline_at.get(rid, _NO_DEADLINE) < tick:
                        expired_by_rep.setdefault(idx, set()).add(rid)
                for idx in sorted(expired_by_rep):
                    evicted, w = self.replicas[idx].engine.evict_inflight(
                        rids=expired_by_rep[idx])
                    wasted += w
                    for r in evicted:
                        where.pop(r.rid, None)
                        _mark(r.rid, "deadline_missed")

            # 4. failure detection: fence replicas whose heartbeat tick
            # lags too far (killed replicas stop beating; stalls longer
            # than the threshold are indistinguishable from death)
            for rep in self._alive():
                beat = rep.hb.read()
                last = beat["step"] if beat else -1
                if tick - last > self.stale_after_ticks:
                    n_rq, n_waste = self._fence(rep, tick, rq,
                                                arrival_tick, where)
                    fenced.append(rep.idx)
                    requeued += n_rq
                    wasted += n_waste

            if not self._alive() \
                    and not self.fault_plan.has_recovery_after(tick):
                raise RuntimeError(
                    "every replica is dead/fenced with "
                    f"{n_req - len(terminal)} request(s) still to serve")

            # 5. dispatch least-loaded-first; a replica holds at most
            # max_batch requests (slots + its own queue), so at most one
            # batch of in-flight work is lost per fencing
            while rq:
                cands = [r for r in self._alive()
                         if r.outstanding() < self.max_batch]
                if not cands:
                    break
                best = min(cands, key=lambda r: (r.outstanding(), r.idx))
                req = rq.popleft()
                where[req.rid] = best.idx
                best.engine.submit(req, t_enqueue=arrival_wall[req.rid])

            # 6. step every healthy replica (one scheduler round each);
            # healthy replicas beat their heartbeat with the current tick
            toks_this_tick = 0
            for rep in self.replicas:
                if not rep.healthy_at(tick):
                    if not rep.killed:
                        rep.stalled_ticks += 1
                    continue
                t0 = time.perf_counter()
                report = rep.engine.step()
                dt = time.perf_counter() - t0
                rep.hb.beat(tick)
                if report.decoded or report.admitted:
                    rep.watchdog.observe(tick, dt)
                toks_this_tick += len(report.admitted) + report.decoded
                for rid in report.admitted:
                    first_tick[rid] = tick
                    if ov is not None:
                        ttft_win.append((tick, tick - arrival_tick[rid]))
                for rid in report.finished:
                    finish_tick[rid] = tick
                    done_by[rid] = rep.idx
                    rep.completed += 1
                    where.pop(rid, None)
                    _mark(rid, "completed")
                    # harvest now: a later recovery resets this engine
                    out[rid] = list(rep.engine.outputs[rid])
                    per_req[rid] = rep.engine.request_stats[rid]
            toks_at_tick.append(toks_this_tick)

            # 7. end-of-tick accounting + overload controller
            depth = len(rq) + sum(r.engine.queue_depth
                                  for r in self._alive())
            queue_samples.append(depth)
            max_outstanding = max(
                [max_outstanding] + [r.outstanding()
                                     for r in self.replicas])
            if ov is not None:
                depth_win.append(depth)
                while ttft_win and ttft_win[0][0] <= tick - ov.window_ticks:
                    ttft_win.popleft()
                if brown:
                    if len(rq) <= ov.queue_low:
                        brown = False
                else:
                    trig_q = (ov.queue_high is not None
                              and len(depth_win) == ov.window_ticks
                              and all(d > ov.queue_high
                                      for d in depth_win))
                    trig_t = (ov.ttft_p99_high is not None and ttft_win
                              and percentile([t for _, t in ttft_win], 99)
                              > ov.ttft_p99_high)
                    if trig_q or trig_t:
                        brown = True
                        brownouts += 1
            tick += 1

        wall = time.perf_counter() - t_wall0
        for rep in self.replicas:
            rep.engine.finalize()
        stats = self._aggregate(
            trace, n_req=n_req, ticks=tick, tick_s=tick_s, wall=wall,
            out=out, per_req=per_req, terminal=terminal,
            arrival_tick=arrival_tick, first_tick=first_tick,
            finish_tick=finish_tick, done_by=done_by,
            queue_samples=queue_samples, toks_at_tick=toks_at_tick,
            requeued=requeued, wasted=wasted, shed_events=shed_events,
            retries=retries, max_outstanding=max_outstanding,
            killed=killed, fenced=fenced, recovered=recovered,
            recovery_gaps=recovery_gaps, brownouts=brownouts,
            brownout_ticks=brownout_ticks)
        self.last_stats = stats
        return out, stats

    # ---------------------------------------------------------- aggregate

    def _aggregate(self, trace: Trace, *, n_req, ticks, tick_s, wall, out,
                   per_req, terminal, arrival_tick, first_tick,
                   finish_tick, done_by, queue_samples, toks_at_tick,
                   requeued, wasted, shed_events, retries, max_outstanding,
                   killed, fenced, recovered, recovery_gaps, brownouts,
                   brownout_ticks) -> Dict[str, Any]:
        # SLO samples come from COMPLETED requests only: a shed or
        # deadline-missed request has no end-to-end latency to report
        # (its admissions, if any, were discarded as waste)
        ttft_ticks = [first_tick[rid] - arrival_tick[rid]
                      for rid in first_tick if rid in done_by]
        tpot_ticks = [(finish_tick[rid] - first_tick[rid])
                      / (len(out[rid]) - 1)
                      for rid in first_tick
                      if rid in done_by and len(out[rid]) > 1]
        ttft_s = [st.ttft_s for st in per_req.values() if st.new_tokens > 0]
        tpot_s = [t for t in (request_tpot_s(st) for st in per_req.values())
                  if t is not None]
        goodput_toks = sum(len(v) for v in out.values())
        n_shed = sum(1 for v in terminal.values() if v == "shed")
        n_miss = sum(1 for v in terminal.values() if v == "deadline_missed")
        stats: Dict[str, Any] = {
            "replicas": len(self.replicas),
            "ticks": ticks,
            "tick_s": tick_s,
            "wall_s": wall,
            "n_requests": n_req,
            "completed": len(out),
            "shed": n_shed,
            "deadline_missed": n_miss,
            "shed_rate": n_shed / n_req if n_req else 0.0,
            "deadline_miss_rate": n_miss / n_req if n_req else 0.0,
            "shed_events": shed_events,
            "retries": retries,
            "retries_per_request": retries / n_req if n_req else 0.0,
            "requeued": requeued,
            "killed": killed,
            "fenced": fenced,
            "recovered": recovered,
            "recoveries": len(recovered),
            "recovery_ticks": list(recovery_gaps),
            "mean_recovery_ticks": (float(np.mean(recovery_gaps))
                                    if recovery_gaps else 0.0),
            "brownouts": brownouts,
            "brownout_ticks": brownout_ticks,
            "outcomes": dict(terminal),
            "decode_steps": sum(r.total_decode_steps()
                                for r in self.replicas),
            "prefills": sum(r.total_prefills() for r in self.replicas),
            "goodput_toks": goodput_toks,
            "wasted_toks": wasted,
            "goodput_tok_per_s": goodput_toks / max(wall, 1e-9),
            "max_outstanding": max_outstanding,
            "straggler_events": sum(r.straggler_events
                                    for r in self.replicas),
        }
        stats.update(router_slo_summary(ttft_ticks, tpot_ticks, ttft_s,
                                        tpot_s, queue_samples))
        if self.kv_page_size:
            # fleet view of the paged caches: hit rate over all replica-
            # local indexes, page high-water occupancy, prefill work saved
            acc: Dict[str, Any] = {}
            for r in self.replicas:
                _fold_kv(acc, r.total_kv() or None)
            lookups = acc.get("prefix_lookups", 0)
            stats["kvcache"] = {
                **acc,
                "prefix_hit_rate": (acc.get("prefix_hits", 0) / lookups
                                    if lookups else 0.0),
                "pages_live": (acc.get("pages_allocated", 0)
                               - acc.get("pages_freed", 0)),
                "page_occupancy": (acc.get("peak_live_pages", 0)
                                   / acc.get("n_pages", 1)
                                   if acc.get("n_pages") else 0.0),
            }
        if self.spec_k:
            # fleet view of speculative decoding: rates recomputed from
            # the folded sums (never averaged across replicas)
            sacc: Dict[str, Any] = {}
            for r in self.replicas:
                _fold_spec(sacc, r.total_spec() or None)
            proposed = sacc.get("proposed", 0)
            vsteps = sacc.get("verify_steps", 0)
            stats["spec"] = {
                **sacc,
                "k": self.spec_k,
                "acceptance_rate": (sacc.get("accepted", 0) / proposed
                                    if proposed else 0.0),
                "accepted_tokens_per_step": (
                    sacc.get("tokens_emitted", 0) / vsteps
                    if vsteps else 0.0),
            }
        bt = trace.burst_ticks(tick_s, ticks)
        if bt:
            burst_toks = sum(toks_at_tick[k] for k in bt
                             if k < len(toks_at_tick))
            stats["burst"] = {
                "ticks": len(bt),
                "arrivals": sum(1 for rid, t in arrival_tick.items()
                                if t in bt),
                "new_tokens": burst_toks,
                "tok_per_tick": burst_toks / len(bt),
            }
        stats["per_replica"] = [
            {"replica": r.idx,
             "decode_steps": r.total_decode_steps(),
             "prefills": r.total_prefills(),
             "completed": r.completed,
             "evicted": r.evicted,
             "stalled_ticks": r.stalled_ticks,
             "straggler_events": r.straggler_events,
             "recoveries": r.recoveries,
             "killed": r.killed,
             "fenced": not r.alive}
            for r in self.replicas]
        if self.kv_page_size:
            for row, r in zip(stats["per_replica"], self.replicas):
                kv = r.total_kv()
                lk = kv.get("prefix_lookups", 0)
                row["prefix_hits"] = kv.get("prefix_hits", 0)
                row["prefix_hit_rate"] = (row["prefix_hits"] / lk
                                          if lk else 0.0)
                row["peak_live_pages"] = kv.get("peak_live_pages", 0)
        if self.spec_k:
            for row, r in zip(stats["per_replica"], self.replicas):
                sp = r.total_spec()
                prop = sp.get("proposed", 0)
                row["spec_accepted"] = sp.get("accepted", 0)
                row["spec_acceptance_rate"] = (row["spec_accepted"] / prop
                                               if prop else 0.0)
        return stats


def _bump_straggler(rep: _Replica) -> None:
    rep.straggler_events += 1
