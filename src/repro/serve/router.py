"""Multi-replica DP router: trace-driven load balancing over ServeEngines
with heartbeat failover.

PR 5 made ONE tensor-parallel replica bit-exact; production is N replicas
behind a router. `Router` owns N `ServeEngine`s (data-parallel — same
config/params, independent slot pools; each optionally exact-TP via the
engine's `mesh=` path) and drives them with the engine's stepwise API on
a deterministic virtual clock:

  one tick = one scheduler round (admission + one batched decode step)
  on every healthy replica.

Per tick, in order: apply `FaultPlan` events, release trace arrivals
whose virtual time has come, check replica heartbeats and fence stale
replicas (re-queuing their in-flight work), dispatch the router queue
least-loaded-first, then step every healthy replica (which also beats
its heartbeat). Because arrivals, dispatch, admission, and sampling are
all functions of the trace seed and the tick counter — never the wall
clock — every token, queue-depth sample, and tick-denominated latency is
reproducible, which is what lets chaos tests assert exact outcomes and
lets `report.py --compare` gate tail-latency rows across machines.

Failure model (wired through repro.dist.fault):

  * Every replica owns a `HeartbeatFile` and beats its current tick each
    healthy round — the same liveness file the training watchdog uses,
    here exercised by an end-to-end loop for the first time.
  * The router reads each beat and declares a replica DEAD when its last
    beaten tick lags more than `stale_after_ticks` behind (tick-lag
    staleness: the deterministic analogue of `HeartbeatFile.stale()`'s
    wall-clock timeout). A killed replica stops stepping and beating; a
    stalled one freezes for `FaultEvent.duration` ticks — a long enough
    stall is indistinguishable from death and gets fenced too.
  * Fencing a replica evicts its in-flight requests
    (`ServeEngine.evict_inflight`) back onto the router queue, oldest
    first, with their ORIGINAL enqueue times, and the replica never
    rejoins (no resurrection: a fenced replica that wakes up again must
    not double-serve re-queued work). Re-queued requests restart from
    scratch on a survivor; the engine's per-request fold_in(rid, i)
    sample keys make the restarted stream token-for-token identical to
    an undisturbed run — partial tokens from the dead replica are
    discarded and counted as `wasted_toks`.
  * A `StepWatchdog` per replica (EWMA straggler detector) observes real
    step wall-times; its events are reported in the stats but never
    steer scheduling, so they cannot break determinism.

The router is host-side and CPU-testable: `FaultPlan().kill(1, at_tick=8)`
makes failover a deterministic unit-testable event, no process murder
required (tests/test_router_chaos.py).
"""

from __future__ import annotations

import dataclasses
import tempfile
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.fault import HeartbeatFile, StepWatchdog
from repro.serve.engine import (Request, RequestStats, ServeEngine,
                                percentile, request_tpot_s)
from repro.serve.trace import Trace


# --------------------------------------------------------------- fault plan

@dataclasses.dataclass
class FaultEvent:
    """One scripted fault: at `tick`, `replica` is killed (permanently
    stops stepping and beating) or stalled (frozen for `duration` ticks,
    then resumes — unless the router fenced it first)."""
    tick: int
    replica: int
    kind: str                 # "kill" | "stall"
    duration: int = 0         # stall length in ticks (kind == "stall")


class FaultPlan:
    """A deterministic fault-injection script for Router.run.

    Example::

        from repro.serve.router import FaultPlan
        plan = FaultPlan().kill(1, at_tick=8).stall(0, at_tick=3, ticks=2)
        assert len(plan.events_at(8)) == 1
    """

    def __init__(self, events: Optional[List[FaultEvent]] = None):
        self.events: List[FaultEvent] = list(events or [])

    def kill(self, replica: int, *, at_tick: int) -> "FaultPlan":
        self.events.append(FaultEvent(tick=at_tick, replica=replica,
                                      kind="kill"))
        return self

    def stall(self, replica: int, *, at_tick: int, ticks: int
              ) -> "FaultPlan":
        self.events.append(FaultEvent(tick=at_tick, replica=replica,
                                      kind="stall", duration=ticks))
        return self

    def events_at(self, tick: int) -> List[FaultEvent]:
        return [e for e in self.events if e.tick == tick]


# ------------------------------------------------------------- SLO summary

def router_slo_summary(ttft_ticks: List[int], tpot_ticks: List[float],
                       ttft_s: List[float], tpot_s: List[float],
                       queue_depth_samples: List[int]) -> Dict[str, Any]:
    """Fold raw per-request latency samples + per-tick queue depths into
    the router's SLO stats (tails via the shared linear-interpolation
    `percentile`; empty samples degrade to 0.0 — the edge cases are
    pinned by tests/test_serve_stats.py against a hand-computed fixture).

    The `_ticks` metrics are deterministic (virtual-clock) and gateable;
    the `_s` metrics are wall clock and informational."""
    return {
        "p50_ttft_ticks": percentile(ttft_ticks, 50),
        "p99_ttft_ticks": percentile(ttft_ticks, 99),
        "p50_tpot_ticks": percentile(tpot_ticks, 50),
        "p99_tpot_ticks": percentile(tpot_ticks, 99),
        "p50_ttft_s": percentile(ttft_s, 50),
        "p99_ttft_s": percentile(ttft_s, 99),
        "p50_tpot_s": percentile(tpot_s, 50),
        "p99_tpot_s": percentile(tpot_s, 99),
        "mean_queue_depth": (float(np.mean(queue_depth_samples))
                             if queue_depth_samples else 0.0),
        "p99_queue_depth": percentile(queue_depth_samples, 99),
        "max_queue_depth": (int(max(queue_depth_samples))
                            if queue_depth_samples else 0),
    }


# ------------------------------------------------------------------ router

@dataclasses.dataclass
class _Replica:
    idx: int
    engine: ServeEngine
    hb: HeartbeatFile
    watchdog: StepWatchdog
    alive: bool = True            # router's view: dispatchable
    killed: bool = False          # fault plan: permanently dead
    stall_until: int = -1         # frozen through tick stall_until - 1
    fenced_at: int = -1
    completed: int = 0
    evicted: int = 0
    stalled_ticks: int = 0
    straggler_events: int = 0

    def healthy_at(self, tick: int) -> bool:
        """Whether the replica PROCESS runs this tick (steps + beats) —
        independent of the router's alive/fenced view of it."""
        return not self.killed and tick >= self.stall_until

    def outstanding(self) -> int:
        return self.engine.active_count + self.engine.queue_depth


class Router:
    """Load-balance a request trace across N replica ServeEngines.

    Replicas share params (data parallel); each may additionally be
    tensor-parallel via `mesh=` exactly as a standalone engine would.
    `rng_seed` is shared so any replica draws the identical per-request
    sample stream — the property failover correctness rests on.

    Example (tiny model, CPU; see docs/serving.md §Multi-replica
    DP routing)::

        import jax, repro
        from repro.configs.base import get_config, reduce_config
        from repro.serve.router import FaultPlan, Router
        from repro.serve.trace import TraceConfig, generate_trace
        cfg = reduce_config(get_config("qwen2-1.5b"), d_model=64, vocab=128)
        params = repro.build_model(cfg).init_params(jax.random.PRNGKey(0))
        router = Router(cfg, params, replicas=2, max_batch=2, cache_len=64)
        trace = generate_trace(TraceConfig(n_requests=6, out_max=8,
                                           prompt_max=16))
        out, stats = router.run(trace)
        assert stats["completed"] == 6
    """

    def __init__(self, cfg: ModelConfig, params, *, replicas: int = 2,
                 max_batch: int = 4, cache_len: int = 512,
                 rng_seed: int = 0, mesh=None,
                 heartbeat_dir: Optional[str] = None,
                 stale_after_ticks: int = 3,
                 fault_plan: Optional[FaultPlan] = None,
                 max_ticks: int = 100_000):
        if replicas < 1:
            raise ValueError(f"need at least one replica, got {replicas}")
        self.cfg = cfg
        self.max_batch = max_batch
        self.stale_after_ticks = stale_after_ticks
        self.fault_plan = fault_plan or FaultPlan()
        self.max_ticks = max_ticks
        hb_dir = heartbeat_dir or tempfile.mkdtemp(prefix="repro-router-hb-")
        self.heartbeat_dir = hb_dir
        self.replicas: List[_Replica] = []
        for i in range(replicas):
            eng = ServeEngine(cfg, params, max_batch=max_batch,
                              cache_len=cache_len, rng_seed=rng_seed,
                              mesh=mesh)
            rep = _Replica(
                idx=i, engine=eng,
                hb=HeartbeatFile(hb_dir, name=f"REPLICA_{i}"),
                watchdog=StepWatchdog())
            rep.watchdog.on_straggler = (
                lambda step, dt, ewma, _r=rep: _bump_straggler(_r))
            self.replicas.append(rep)
        self.last_stats: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------- pieces

    def _alive(self) -> List[_Replica]:
        return [r for r in self.replicas if r.alive]

    def _fence(self, rep: _Replica, tick: int, rq: deque,
               arrival_tick: Dict[int, int]) -> Tuple[int, int]:
        """Declare rep dead: evict its in-flight work back onto the router
        queue (oldest arrivals first, ahead of newer work) and stop
        dispatching to it forever. Returns (n_requeued, wasted_tokens)."""
        rep.alive = False
        rep.fenced_at = tick
        evicted, wasted = rep.engine.evict_inflight()
        rep.evicted += len(evicted)
        evicted.sort(key=lambda r: arrival_tick[r.rid])
        rq.extendleft(reversed(evicted))
        return len(evicted), wasted

    # ---------------------------------------------------------------- run

    def run(self, trace: Trace, *, tick_s: float = 0.05
            ) -> Tuple[Dict[int, List[int]], Dict[str, Any]]:
        """Drive the trace to completion. Returns ({rid: tokens}, stats).

        tick_s maps the trace's virtual arrival times onto ticks; it has
        no relation to the wall clock (a tick takes however long the
        replicas' decode steps take)."""
        n_req = len(trace.requests)
        arrivals = deque(zip(trace.arrival_ticks(tick_s),
                             trace.requests))       # ordered by t_arrival
        for rep in self.replicas:
            rep.engine.reset()
        t_wall0 = time.perf_counter()

        rq: deque = deque()                  # router-level admission queue
        arrival_tick: Dict[int, int] = {}
        arrival_wall: Dict[int, float] = {}
        first_tick: Dict[int, int] = {}      # last successful admission
        finish_tick: Dict[int, int] = {}
        done_by: Dict[int, int] = {}         # rid -> replica idx
        queue_samples: List[int] = []
        toks_at_tick: List[int] = []         # tokens produced per tick
        requeued = 0
        wasted = 0
        max_outstanding = 0
        killed: List[int] = []
        fenced: List[int] = []

        tick = 0
        while len(done_by) < n_req:
            if tick >= self.max_ticks:
                raise RuntimeError(
                    f"router exceeded max_ticks={self.max_ticks} with "
                    f"{n_req - len(done_by)} request(s) unfinished")

            # 1. scripted faults take effect before anything runs
            for ev in self.fault_plan.events_at(tick):
                rep = self.replicas[ev.replica]
                if ev.kind == "kill":
                    rep.killed = True
                    killed.append(rep.idx)
                elif ev.kind == "stall":
                    rep.stall_until = max(rep.stall_until,
                                          tick + ev.duration)
                else:
                    raise ValueError(f"unknown fault kind {ev.kind!r}")

            # 2. trace arrivals whose virtual time has come
            while arrivals and arrivals[0][0] <= tick:
                _, tr = arrivals.popleft()
                rid = tr.request.rid
                arrival_tick[rid] = tick
                arrival_wall[rid] = time.perf_counter()
                rq.append(tr.request)

            # 3. failure detection: fence replicas whose heartbeat tick
            # lags too far (killed replicas stop beating; stalls longer
            # than the threshold are indistinguishable from death)
            for rep in self._alive():
                beat = rep.hb.read()
                last = beat["step"] if beat else -1
                if tick - last > self.stale_after_ticks:
                    n_rq, n_waste = self._fence(rep, tick, rq,
                                                arrival_tick)
                    fenced.append(rep.idx)
                    requeued += n_rq
                    wasted += n_waste

            if (rq or arrivals) and not self._alive():
                raise RuntimeError(
                    "every replica is dead/fenced with "
                    f"{len(rq) + len(arrivals)} request(s) still to serve")

            # 4. dispatch least-loaded-first; a replica holds at most
            # max_batch requests (slots + its own queue), so at most one
            # batch of in-flight work is lost per fencing
            while rq:
                cands = [r for r in self._alive()
                         if r.outstanding() < self.max_batch]
                if not cands:
                    break
                best = min(cands, key=lambda r: (r.outstanding(), r.idx))
                req = rq.popleft()
                best.engine.submit(req, t_enqueue=arrival_wall[req.rid])

            # 5. step every healthy replica (one scheduler round each);
            # healthy replicas beat their heartbeat with the current tick
            toks_this_tick = 0
            for rep in self.replicas:
                if not rep.healthy_at(tick):
                    if not rep.killed:
                        rep.stalled_ticks += 1
                    continue
                t0 = time.perf_counter()
                report = rep.engine.step()
                dt = time.perf_counter() - t0
                rep.hb.beat(tick)
                if report.decoded or report.admitted:
                    rep.watchdog.observe(tick, dt)
                toks_this_tick += len(report.admitted) + report.decoded
                for rid in report.admitted:
                    first_tick[rid] = tick
                for rid in report.finished:
                    finish_tick[rid] = tick
                    done_by[rid] = rep.idx
                    rep.completed += 1
            toks_at_tick.append(toks_this_tick)

            # 6. end-of-tick accounting
            queue_samples.append(len(rq) + sum(r.engine.queue_depth
                                               for r in self._alive()))
            max_outstanding = max(
                [max_outstanding] + [r.outstanding()
                                     for r in self.replicas])
            tick += 1

        wall = time.perf_counter() - t_wall0

        # merge outputs: after the drain each engine's outputs hold
        # exactly the requests it completed (evicted rids were popped)
        out: Dict[int, List[int]] = {}
        per_req: Dict[int, RequestStats] = {}
        for rep in self.replicas:
            rep.engine.finalize()
            out.update(rep.engine.outputs)
            per_req.update(rep.engine.request_stats)
        stats = self._aggregate(
            trace, n_req=n_req, ticks=tick, tick_s=tick_s, wall=wall,
            out=out, per_req=per_req, arrival_tick=arrival_tick,
            first_tick=first_tick, finish_tick=finish_tick,
            queue_samples=queue_samples, toks_at_tick=toks_at_tick,
            requeued=requeued, wasted=wasted,
            max_outstanding=max_outstanding, killed=killed, fenced=fenced)
        self.last_stats = stats
        return out, stats

    # ---------------------------------------------------------- aggregate

    def _aggregate(self, trace: Trace, *, n_req, ticks, tick_s, wall, out,
                   per_req, arrival_tick, first_tick, finish_tick,
                   queue_samples, toks_at_tick, requeued, wasted,
                   max_outstanding, killed, fenced) -> Dict[str, Any]:
        ttft_ticks = [first_tick[rid] - arrival_tick[rid]
                      for rid in first_tick]
        tpot_ticks = [(finish_tick[rid] - first_tick[rid])
                      / (len(out[rid]) - 1)
                      for rid in first_tick if len(out[rid]) > 1]
        ttft_s = [st.ttft_s for st in per_req.values() if st.new_tokens > 0]
        tpot_s = [t for t in (request_tpot_s(st) for st in per_req.values())
                  if t is not None]
        goodput_toks = sum(len(v) for v in out.values())
        stats: Dict[str, Any] = {
            "replicas": len(self.replicas),
            "ticks": ticks,
            "tick_s": tick_s,
            "wall_s": wall,
            "n_requests": n_req,
            "completed": len(out),
            "requeued": requeued,
            "killed": killed,
            "fenced": fenced,
            "decode_steps": sum(r.engine.last_stats["decode_steps"]
                                for r in self.replicas),
            "prefills": sum(r.engine.last_stats["prefills"]
                            for r in self.replicas),
            "goodput_toks": goodput_toks,
            "wasted_toks": wasted,
            "goodput_tok_per_s": goodput_toks / max(wall, 1e-9),
            "max_outstanding": max_outstanding,
            "straggler_events": sum(r.straggler_events
                                    for r in self.replicas),
        }
        stats.update(router_slo_summary(ttft_ticks, tpot_ticks, ttft_s,
                                        tpot_s, queue_samples))
        bt = trace.burst_ticks(tick_s, ticks)
        if bt:
            burst_toks = sum(toks_at_tick[k] for k in bt
                             if k < len(toks_at_tick))
            stats["burst"] = {
                "ticks": len(bt),
                "arrivals": sum(1 for rid, t in arrival_tick.items()
                                if t in bt),
                "new_tokens": burst_toks,
                "tok_per_tick": burst_toks / len(bt),
            }
        stats["per_replica"] = [
            {"replica": r.idx,
             "decode_steps": r.engine.last_stats["decode_steps"],
             "prefills": r.engine.last_stats["prefills"],
             "completed": r.completed,
             "evicted": r.evicted,
             "stalled_ticks": r.stalled_ticks,
             "straggler_events": r.straggler_events,
             "killed": r.killed,
             "fenced": not r.alive}
            for r in self.replicas]
        return stats


def _bump_straggler(rep: _Replica) -> None:
    rep.straggler_events += 1
