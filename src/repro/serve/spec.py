"""Propose/verify/accept core for speculative decoding.

The slot scheduler (serve/engine.py) pairs the target model with a small
draft model. Each spec round, per active slot:

  propose  the draft runs spec_k sequential decode steps from the last
           committed token, sampling candidates d_0..d_{k-1} from its own
           distribution (greedy rows take the draft argmax);
  verify   ONE (k+1)-position target forward (Model.decode_verify) over
           [cur, d_0..d_{k-1}] yields the target distribution after every
           candidate — logits[j] judges d_j, logits[k] is the bonus
           distribution past a full accept;
  accept   host-side (this module). Greedy (temperature 0): accept the
           longest prefix where d_j == argmax(logits[j]); the first
           mismatch emits the target argmax as a CORRECTION token, a full
           accept emits a BONUS token from logits[k]. Either way the round
           emits the exact prefix plain greedy decoding would have
           produced — the bit-exactness contract the differential tier
           (tests/test_spec_decode.py) pins. Temperature > 0: standard
           rejection sampling — accept d_j with prob min(1, p_t/p_d),
           resample rejections from norm(max(p_t - p_d, 0)) — which makes
           the OUTPUT DISTRIBUTION equal to plain sampling (not the
           bitstream; the draws consume salted keys).

Key schedule: every spec draw derives from the engine's per-request base,
fold_in(fold_in(base_key, rid), token_index), then a salt fold below so
draft/accept/residual/bonus draws can never collide with each other or
with the plain path's un-salted sample stream.

Accounting invariant (property-tested): every emitted token is tagged
"accepted" (a surviving draft token), "rejected" (the correction emitted
at the first rejection) or "bonus" (the extra token after a full accept),
so accepted + rejected + bonus == tokens_emitted — per round and summed.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SALT_DRAFT = 101      # draft proposal draws (engine's _spec_sample)
SALT_ACCEPT = 102     # accept/reject uniforms
SALT_RESIDUAL = 103   # residual-distribution resamples
SALT_BONUS = 104      # bonus draw after a full accept


def _softmax(x: np.ndarray) -> np.ndarray:
    x = x - x.max(axis=-1, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=-1, keepdims=True)


def spec_sample_key(base_key, rid: int, index: int, salt: int):
    """The salted per-request key for spec draw `index` of request `rid`
    — fold_in(fold_in(fold_in(base, rid), index), salt). index is the
    emitted-token index the draw belongs to (n_gen + j), so a re-queued
    request replays the identical draw sequence on any replica."""
    k = jax.random.fold_in(jax.random.fold_in(base_key, rid), index)
    return jax.random.fold_in(k, salt)


def accept_tokens(draft_toks: np.ndarray, draft_logits: np.ndarray,
                  target_logits: np.ndarray, *, temperature: float,
                  base_key, rid: int, n_gen: int
                  ) -> Tuple[List[int], List[str]]:
    """The accept decision for one slot's spec round.

    draft_toks: (k,) candidate tokens; draft_logits: (k, V) the draft
    distribution each candidate was drawn from; target_logits: (k+1, V)
    the verify logits (position j judges d_j, position k is the bonus
    distribution). n_gen: tokens the request has emitted so far — the key
    schedule's base index for this round's draws.

    Returns (emitted, kinds): 1..k+1 tokens with a parallel provenance tag
    per token ("accepted" | "rejected" | "bonus"); a round always emits at
    least one token (the correction at an immediate rejection)."""
    k = len(draft_toks)
    emitted: List[int] = []
    kinds: List[str] = []
    if temperature <= 0.0:
        # greedy: acceptance is argmax agreement, so the emitted prefix is
        # exactly the plain greedy chain (correction token included)
        t_arg = np.argmax(target_logits, axis=-1)
        for j in range(k):
            if int(draft_toks[j]) == int(t_arg[j]):
                emitted.append(int(draft_toks[j]))
                kinds.append("accepted")
                continue
            emitted.append(int(t_arg[j]))
            kinds.append("rejected")
            return emitted, kinds
        emitted.append(int(t_arg[k]))
        kinds.append("bonus")
        return emitted, kinds

    pt = _softmax(target_logits.astype(np.float64) / temperature)
    pd = _softmax(draft_logits.astype(np.float64) / temperature)
    for j in range(k):
        x = int(draft_toks[j])
        u = float(jax.random.uniform(
            spec_sample_key(base_key, rid, n_gen + j, SALT_ACCEPT)))
        if u < pt[j, x] / max(pd[j, x], 1e-30):
            emitted.append(x)
            kinds.append("accepted")
            continue
        resid = np.maximum(pt[j] - pd[j], 0.0)
        tot = float(resid.sum())
        # tot == 0 only when p_t == p_d exactly, where the accept ratio
        # was 1.0 and this branch is unreachable; guard numerically anyway
        probs = resid / tot if tot > 0.0 else pt[j]
        tok = int(jax.random.categorical(
            spec_sample_key(base_key, rid, n_gen + j, SALT_RESIDUAL),
            jnp.asarray(np.log(probs + 1e-300))))
        emitted.append(tok)
        kinds.append("rejected")
        return emitted, kinds
    tok = int(jax.random.categorical(
        spec_sample_key(base_key, rid, n_gen + k, SALT_BONUS),
        jnp.asarray(np.log(pt[k] + 1e-300))))
    emitted.append(tok)
    kinds.append("bonus")
    return emitted, kinds
