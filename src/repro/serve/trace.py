"""Synthetic request traces for serving benchmarks and chaos tests.

The router tier is gated on tail latency under bursty load, so the load
itself must be reproducible: `generate_trace(TraceConfig(...))` derives
every arrival time, prompt, length, and temperature from one
`np.random.default_rng(seed)` stream in a fixed draw order — the same
config produces the identical trace on every host, forever (pinned by
tests/test_router_props.py).

Two arrival processes:

  * "poisson"  — homogeneous Poisson arrivals: i.i.d. exponential gaps at
    `rate_rps` requests per (virtual) second.
  * "bursty"   — a piecewise-constant-rate Poisson approximation of flash
    crowds: the base rate multiplies by `burst_factor` inside periodic
    burst windows ([k*burst_every_s, +burst_len_s) for k >= 1; the first
    period stays calm so the system has a measured steady state to
    compare the burst against). Each gap is drawn at the rate in effect
    at the previous arrival — the standard discretization, good enough
    for load shaping. The windows are recorded on the Trace so the bench
    can report goodput-under-burst.

Lengths are heavy-tailed: prompt and output lengths draw from a discrete
lognormal (median `*_median`, shape `*_sigma`) clipped to [1, `*_max`] —
a few long requests among many short ones, the mix that makes slot-level
continuous batching matter. Times are VIRTUAL seconds: the router maps
them onto scheduler ticks (`Trace.arrival_ticks`), so trace time never
touches the wall clock and every derived scheduling decision is
deterministic.

Deadlines (optional): with `deadline_median > 0` every request also draws
a heavy-tail completion deadline — a slack in ROUTER TICKS after its
arrival tick (`TracedRequest.deadline_ticks`). The router evicts a
request that has not completed within its slack and counts it
`deadline_missed` (docs/serving.md §Overload & recovery). The draw comes
last in the per-request order, and only when enabled, so pre-deadline
traces remain bit-identical per seed.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np

from repro.serve.engine import Request


@dataclasses.dataclass
class TraceConfig:
    """Knobs for one synthetic trace (see module docstring).

    Example::

        from repro.serve.trace import TraceConfig, generate_trace
        tr = generate_trace(TraceConfig(n_requests=16, arrival="bursty",
                                        seed=7))
        assert tr.requests[0].t_arrival < tr.requests[-1].t_arrival
    """
    n_requests: int = 32
    arrival: str = "poisson"          # "poisson" | "bursty"
    rate_rps: float = 8.0             # mean arrivals per virtual second
    burst_factor: float = 6.0         # bursty: rate multiplier in a window
    burst_every_s: float = 4.0        # bursty: window period
    burst_len_s: float = 1.0          # bursty: window length
    prompt_median: int = 8            # lognormal median prompt length
    prompt_sigma: float = 0.6
    prompt_max: int = 64
    out_median: int = 8               # lognormal median max_new_tokens
    out_sigma: float = 0.8
    out_max: int = 48
    temperatures: Tuple[float, ...] = (0.0,)   # sampled per request
    vocab: int = 128
    seed: int = 0
    # Optional per-request deadlines, denominated directly in ROUTER TICKS
    # of slack after arrival (independent of the tick_s mapping, so a
    # trace's deadline pressure is identical at any tick granularity).
    # deadline_median=0 (the default) disables deadlines entirely — no rng
    # draw happens, so pre-deadline traces stay bit-identical per seed.
    # When enabled, each request draws a discrete-lognormal slack
    # (heavy-tail: a few patient requests among many impatient ones),
    # clipped to [deadline_min, deadline_max].
    deadline_median: int = 0          # median slack in ticks; 0 = off
    deadline_sigma: float = 0.6
    deadline_min: int = 1
    deadline_max: int = 10_000
    # Optional shared system prompts (paged K/V prefix reuse — serve/
    # kvcache.py): with shared_prefix_frac > 0, a pool of `prefix_pool`
    # fixed prefixes of length `prefix_len` is drawn up front, and each
    # request prepends one pool member with probability shared_prefix_frac.
    # All draws happen only when enabled (and AFTER the deadline draw), so
    # pre-knob traces stay bit-identical per seed.
    shared_prefix_frac: float = 0.0   # P(request carries a pool prefix)
    prefix_pool: int = 4              # number of distinct shared prefixes
    prefix_len: int = 16              # tokens per shared prefix


@dataclasses.dataclass
class TracedRequest:
    """One request plus its virtual arrival time (seconds from t=0) and,
    optionally, a completion deadline: the request must finish within
    `deadline_ticks` router ticks of its arrival tick or be evicted and
    counted `deadline_missed` (None = no deadline)."""
    t_arrival: float
    request: Request
    deadline_ticks: Optional[int] = None


@dataclasses.dataclass
class Trace:
    cfg: TraceConfig
    requests: List[TracedRequest]               # ordered by t_arrival
    burst_windows: List[Tuple[float, float]]    # [) intervals, maybe empty

    def arrival_ticks(self, tick_s: float) -> List[int]:
        """Each request's arrival quantized onto the router's tick grid
        (floor: a request arriving inside tick k is visible at tick k)."""
        return [int(tr.t_arrival // tick_s) for tr in self.requests]

    def burst_ticks(self, tick_s: float, horizon: int) -> set:
        """The tick indices (< horizon) covered by a burst window."""
        out = set()
        for t0, t1 in self.burst_windows:
            for k in range(int(t0 // tick_s),
                           min(int(math.ceil(t1 / tick_s)), horizon)):
                out.add(k)
        return out

    def plain_requests(self) -> List[Request]:
        """The requests stripped of arrival times — the undisturbed
        single-engine baseline workload for chaos comparisons."""
        return [tr.request for tr in self.requests]

    def max_request_len(self) -> int:
        """Largest prompt_len + max_new_tokens in the trace: the minimum
        cache_len an engine needs to admit every request."""
        return max(len(tr.request.prompt) + tr.request.max_new_tokens
                   for tr in self.requests)


def _in_burst(t: float, cfg: TraceConfig) -> bool:
    if cfg.arrival != "bursty":
        return False
    phase = t % cfg.burst_every_s
    return t >= cfg.burst_every_s and phase < cfg.burst_len_s


def _lognormal_len(rng: np.random.Generator, median: int, sigma: float,
                   max_len: int) -> int:
    draw = rng.lognormal(mean=math.log(max(median, 1)), sigma=sigma)
    return int(np.clip(round(draw), 1, max_len))


def generate_trace(cfg: TraceConfig) -> Trace:
    """Derive the whole trace from one seeded generator (fixed draw order
    per request: gap, prompt length, prompt tokens, output length,
    temperature, then the opt-in deadline and shared-prefix draws) —
    per-seed determinism is part of the contract.

    Example::

        from repro.serve.trace import TraceConfig, generate_trace
        a = generate_trace(TraceConfig(n_requests=8, seed=3))
        b = generate_trace(TraceConfig(n_requests=8, seed=3))
        assert [r.t_arrival for r in a.requests] \\
            == [r.t_arrival for r in b.requests]
    """
    if cfg.arrival not in ("poisson", "bursty"):
        raise ValueError(f"unknown arrival process {cfg.arrival!r} "
                         "(expected 'poisson' or 'bursty')")
    rng = np.random.default_rng(cfg.seed)
    # shared system prompts (drawn up front, only when enabled): requests
    # that pick the same pool member carry an identical token prefix, the
    # workload shape the paged K/V prefix index exists for. The prefix is
    # PREPENDED to the drawn prompt, so prompt lengths grow by prefix_len
    # for participating requests (size cache_len accordingly).
    # the prefix draws come from a DERIVED stream ([seed, 1]): turning the
    # knob on prepends prefixes but leaves every arrival time, length,
    # temperature, and deadline of the base trace bit-identical, so a
    # shared-prompt bench row is an apples-to-apples cold-vs-warm compare.
    prefixes = None
    prng = None
    if cfg.shared_prefix_frac > 0:
        prng = np.random.default_rng([cfg.seed, 1])
        prefixes = [prng.integers(0, cfg.vocab, cfg.prefix_len)
                    .astype(np.int32) for _ in range(cfg.prefix_pool)]
    reqs: List[TracedRequest] = []
    t = 0.0
    for rid in range(cfg.n_requests):
        rate = cfg.rate_rps * (cfg.burst_factor if _in_burst(t, cfg)
                               else 1.0)
        # np.random.Generator.exponential returns > 0, so arrival times
        # are strictly increasing (the monotonicity property)
        t += float(rng.exponential(1.0 / rate))
        plen = _lognormal_len(rng, cfg.prompt_median, cfg.prompt_sigma,
                              cfg.prompt_max)
        prompt = rng.integers(0, cfg.vocab, plen).astype(np.int32)
        n_out = _lognormal_len(rng, cfg.out_median, cfg.out_sigma,
                               cfg.out_max)
        temp = float(rng.choice(np.asarray(cfg.temperatures, np.float64)))
        # the deadline draw comes LAST and only when enabled, so traces
        # generated before the knob existed stay bit-identical per seed
        deadline = None
        if cfg.deadline_median > 0:
            deadline = max(cfg.deadline_min,
                           _lognormal_len(rng, cfg.deadline_median,
                                          cfg.deadline_sigma,
                                          cfg.deadline_max))
        # shared-prefix draws use the derived stream, never the base one
        if prefixes is not None and float(prng.random()) < cfg.shared_prefix_frac:
            pid = int(prng.integers(cfg.prefix_pool))
            prompt = np.concatenate([prefixes[pid], prompt])
        reqs.append(TracedRequest(
            t_arrival=t,
            request=Request(rid=rid, prompt=prompt, max_new_tokens=n_out,
                            temperature=temp),
            deadline_ticks=deadline))
    windows: List[Tuple[float, float]] = []
    if cfg.arrival == "bursty" and reqs:
        horizon = reqs[-1].t_arrival
        k = 1
        while k * cfg.burst_every_s <= horizon:
            t0 = k * cfg.burst_every_s
            windows.append((t0, t0 + cfg.burst_len_s))
            k += 1
    return Trace(cfg=cfg, requests=reqs, burst_windows=windows)
