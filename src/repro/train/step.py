"""Train / serve step builders: model + optimizer + sharding plan -> jit-able
step functions with explicit in/out shardings (the objects the dry-run lowers
and the trainer executes).

Gradient sync is implicit: params are replicated (or FSDP-sharded) over the
dp axes, so XLA inserts the reduce-scatter/all-reduce automatically; with
grad_compress="bf16" gradients are cast before sync so the all-reduce moves
half the bytes (optimizer math stays f32).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist import sharding as shd
from repro.models.layers import DistCtx
from repro.models.registry import Model
from repro.optim.adafactor import make_optimizer
from repro.optim.schedule import linear_warmup_cosine

PyTree = Any


@dataclasses.dataclass
class StepBundle:
    """A lowered-able step: jit(fn, in_shardings=..., out_shardings=...)."""
    fn: Callable
    in_shardings: Tuple
    out_shardings: Any
    abstract_args: Tuple
    donate_argnums: Tuple[int, ...] = ()

    def lower(self, *overrides):
        args = tuple(o if o is not None else a
                     for o, a in zip(overrides, self.abstract_args)) \
            if overrides else self.abstract_args
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        return jitted.lower(*args)


def make_plan(cfg: ModelConfig, mesh, *, kind: str,
              fsdp: Optional[bool] = None,
              kv_seq_shard: Optional[bool] = None,
              ep_data: Optional[bool] = None) -> shd.ShardingPlan:
    """Default sharding policy per arch size & cell kind (overridable).

    MoE: experts shard over `data` (EP — weights stay resident, tokens
    move) instead of FSDP, whose stacked-weight all-gather gets hoisted
    outside the layer scan by XLA (measured: llama4 prefill collective
    717s -> see EXPERIMENTS.md §Perf). Dense >8B params: FSDP in training
    (optimizer+grads sharded); serving is TP-only (params fit) to avoid
    per-layer gathers.
    """
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if ep_data is None:
        ep_data = cfg.family == "moe"
    if fsdp is None:
        big = cfg.param_count() > 8e9
        fsdp = big and kind == "train" and not ep_data
    if kv_seq_shard is None:
        # distributed flash-decode for long caches on attention archs
        kv_seq_shard = kind == "decode" and cfg.family in (
            "dense", "moe", "vlm", "encdec")
    return shd.ShardingPlan(mesh=mesh, dp_axes=dp_axes, fsdp=fsdp,
                            kv_seq_shard=kv_seq_shard, ep_data=ep_data)


def make_dist_ctx(plan: shd.ShardingPlan) -> DistCtx:
    return DistCtx(mesh=plan.mesh, data_axes=plan.dp_axes,
                   model_axis=plan.tp_axis, kv_seq_shard=plan.kv_seq_shard,
                   ep_data=plan.ep_data)


def _param_shardings(model: Model, plan):
    ab = model.abstract_params()
    return ab, shd.params_shardings(plan, model.param_axes, ab)


def _opt_state_shardings(plan, model: Model, opt, ab_params, ps_tree):
    """m/v (AdamW) inherit the param leaf sharding; adafactor vr drops the
    last param dim's axes, vc the second-last (state shapes follow suit)."""
    rep = NamedSharding(plan.mesh, P())
    ab_opt = opt.abstract_state(ab_params)
    if "m" in ab_opt:
        return ab_opt, {"m": ps_tree, "v": ps_tree, "step": rep}

    def build(node, path=""):
        if isinstance(node, dict) and ("vr" in node or "v" in node):
            axes = model.param_axes.get(path)
            if "v" in node:
                ax = axes or (None,) * len(node["v"].shape)
                return {"v": NamedSharding(
                    plan.mesh, shd.spec_for(plan, ax, node["v"].shape))}
            ax = axes or (None,) * (len(node["vr"].shape) + 1)
            vr_ax = ax[:-1]
            vc_ax = ax[:-2] + ax[-1:]
            return {
                "vr": NamedSharding(plan.mesh, shd.spec_for(
                    plan, vr_ax, node["vr"].shape)),
                "vc": NamedSharding(plan.mesh, shd.spec_for(
                    plan, vc_ax, node["vc"].shape)),
            }
        return {k: build(v, f"{path}/{k}" if path else k)
                for k, v in node.items()}

    return ab_opt, {"f": build(ab_opt["f"]), "step": rep}


def build_train_step(model: Model, plan: shd.ShardingPlan, *,
                     optimizer_name: Optional[str] = None,
                     peak_lr: float = 3e-4, warmup: int = 2000,
                     total_steps: int = 100_000,
                     grad_compress: str = "none",
                     microbatches: int = 1):
    """Returns (StepBundle, optimizer). Step signature:
    (params, opt_state, batch) -> (params, opt_state, metrics).

    microbatches > 1 enables gradient accumulation: the global batch is
    split along dim 0 and scanned, with an f32 grad accumulator — the
    standard way to keep per-microbatch activations inside the HBM budget
    (activation footprint scales 1/microbatches at fixed global batch).
    """
    cfg = model.cfg
    opt = make_optimizer(
        optimizer_name or cfg.optimizer,
        functools.partial(linear_warmup_cosine, peak_lr=peak_lr,
                          warmup=warmup, total=total_steps))
    ctx = make_dist_ctx(plan)

    def grad_fn(params, batch):
        def loss_fn(p):
            return model.loss_fn(p, batch, ctx)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            # shard-preserving split: keep the SHARDED batch dim outer
            # ((B//n, n, ...) then swap) so every microbatch spans all data
            # shards — a naive (n, B//n, ...) reshape would put each
            # microbatch on 1/n of the data axis and force resharding.
            from jax.sharding import NamedSharding, PartitionSpec as P
            mb_spec = NamedSharding(plan.mesh, P(plan.dp_axes))

            def split(x):
                y = x.reshape((x.shape[0] // microbatches, microbatches)
                              + x.shape[1:]).swapaxes(0, 1)
                return y

            ub = jax.tree.map(split, batch)

            def body(acc, mb):
                mb = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, NamedSharding(plan.mesh,
                                         P(plan.dp_axes,
                                           *([None] * (x.ndim - 1))))), mb)
                (loss, metrics), grads = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches,
                    acc, grads)
                return acc, (loss, metrics)

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, ms) = jax.lax.scan(body, zero, ub)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), ms)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        if grad_compress == "bf16":
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        new_params, new_opt_state, opt_metrics = opt.update(
            params, grads, opt_state)
        metrics = {**metrics, **opt_metrics, "total_loss": loss}
        return new_params, new_opt_state, metrics

    ab_params, ps = _param_shardings(model, plan)
    ab_opt, os_ = _opt_state_shardings(plan, model, opt, ab_params, ps)

    bundle = StepBundle(
        fn=train_step,
        in_shardings=(ps, os_, None),
        out_shardings=(ps, os_, None),
        abstract_args=(ab_params, ab_opt, None),   # batch given at lower()
        donate_argnums=(0, 1),
    )
    return bundle, opt


def build_prefill_step(model: Model, plan: shd.ShardingPlan) -> StepBundle:
    ctx = make_dist_ctx(plan)

    def prefill_step(params, batch):
        return model.prefill(params, batch, ctx)

    ab_params, ps = _param_shardings(model, plan)
    return StepBundle(fn=prefill_step, in_shardings=(ps, None),
                      out_shardings=None, abstract_args=(ab_params, None))


def build_decode_step(model: Model, plan: shd.ShardingPlan,
                      abstract_cache) -> StepBundle:
    ctx = make_dist_ctx(plan)

    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens, ctx)

    ab_params, ps = _param_shardings(model, plan)
    cs = shd.cache_shardings(plan, model.cache_axes(), abstract_cache)
    return StepBundle(
        fn=decode_step,
        in_shardings=(ps, cs, None),
        out_shardings=(None, cs),
        abstract_args=(ab_params, abstract_cache, None),
        donate_argnums=(1,),
    )
