"""Fault-tolerant training loop.

Wires together: model + sharded train_step (train/step.py), data pipeline
(prefetch), checkpoint manager (atomic + async + auto-resume), watchdog
(straggler detection), heartbeat. The loop is restart-idempotent: kill it
at any step, rerun the same command, and it resumes from the latest valid
checkpoint with bit-identical data order (step-keyed batches).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import (DataConfig, PrefetchIterator, TokenSource,
                                 make_stub_frontend_batch)
from repro.dist.fault import HeartbeatFile, StepWatchdog, resume_or_init
from repro.models.registry import build_model
from repro.train import step as step_lib


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: str = "runs/ckpt"
    seq_len: int = 512
    global_batch: int = 8
    peak_lr: float = 3e-4
    microbatches: int = 1
    grad_compress: str = "none"
    seed: int = 0
    token_file: Optional[str] = None


class Trainer:
    def __init__(self, cfg: ModelConfig, loop: TrainLoopConfig, mesh,
                 *, fsdp: Optional[bool] = None):
        self.cfg = cfg
        self.loop = loop
        self.mesh = mesh
        self.model = build_model(cfg)
        self.plan = step_lib.make_plan(cfg, mesh, kind="train", fsdp=fsdp)
        self.bundle, self.opt = step_lib.build_train_step(
            self.model, self.plan, peak_lr=loop.peak_lr,
            total_steps=loop.total_steps, microbatches=loop.microbatches,
            grad_compress=loop.grad_compress)
        self.ckpt = CheckpointManager(loop.ckpt_dir)
        self.watchdog = StepWatchdog(
            on_straggler=lambda s, dt, ew: print(
                f"[watchdog] step {s} took {dt:.2f}s (ewma {ew:.2f}s) — "
                f"straggler; on a fleet this triggers re-slicing"))
        self.heartbeat = HeartbeatFile(loop.ckpt_dir)

    # ------------------------------------------------------------------ run

    def run(self, *, verbose: bool = True) -> Dict[str, Any]:
        loop = self.loop
        model, plan = self.model, self.plan

        ps = self.bundle.in_shardings[0]
        os_ = self.bundle.in_shardings[1]

        def init_state():
            with jax.set_mesh(self.mesh):
                params = jax.jit(
                    model.init_params, out_shardings=ps)(
                        jax.random.PRNGKey(loop.seed))
                opt_state = jax.jit(
                    self.opt.init, out_shardings=os_)(params)
            return {"params": params, "opt": opt_state}

        start_step, state = resume_or_init(
            self.ckpt, init_state,
            shardings={"params": ps, "opt": os_})
        if verbose and start_step:
            print(f"[trainer] resumed from step {start_step}")

        data_cfg = DataConfig(seq_len=loop.seq_len,
                              global_batch=loop.global_batch,
                              vocab_size=self.cfg.vocab_size,
                              seed=loop.seed, token_file=loop.token_file)
        source = TokenSource(data_cfg)
        it = PrefetchIterator(source, start_step=start_step)

        step_fn = jax.jit(self.bundle.fn,
                          in_shardings=self.bundle.in_shardings[:2] + (None,),
                          out_shardings=self.bundle.out_shardings,
                          donate_argnums=self.bundle.donate_argnums)

        params, opt_state = state["params"], state["opt"]
        metrics = {}
        losses = []
        try:
            for step in range(start_step, loop.total_steps):
                t0 = time.perf_counter()
                data_step, batch = next(it)
                assert data_step == step, (data_step, step)
                batch = make_stub_frontend_batch(self.cfg, batch, loop.seed)
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self.watchdog.observe(step, dt)
                self.heartbeat.beat(step)
                losses.append(float(metrics["loss"]))
                if verbose and step % loop.log_every == 0:
                    print(f"step {step:5d} loss {losses[-1]:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"{dt*1e3:.0f} ms")
                if (step + 1) % loop.ckpt_every == 0 or \
                        step + 1 == loop.total_steps:
                    self.ckpt.save(step + 1,
                                   {"params": params, "opt": opt_state})
        finally:
            it.close()
            self.ckpt.barrier()
        return {"final_loss": losses[-1] if losses else None,
                "losses": losses,
                "start_step": start_step,
                "stragglers": self.watchdog.stragglers,
                "metrics": {k: float(np.asarray(v))
                            for k, v in metrics.items()}}
