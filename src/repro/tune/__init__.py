"""Model-guided, measurement-verified autotuning for every kernel in the
registry (`repro.kernels.api`).

The paper's v8 is a hand-run block-size sweep frozen into one static config;
this package re-runs that sweep per (kernel, problem size, backend, kernel
version): each registered `Kernel` supplies its feasible config space and
analytic roofline model, `tuner` ranks the space with that model, optionally
times the top-K with the real harness in `measure`, and persists the winner
to a JSON cache keyed `(kernel, ProblemKey, backend, version)` so
`api.dispatch(...)` hits a tuned config automatically — gpp's `(blk_ig,
blk_igp, blk_band)`, flash attention's `(blk_q, blk_kv)`, and the ssm
scan's `blk_c` all flow through the same model-then-measure path.
`space` holds the GPP candidate generator (other kernels enumerate theirs
in their kernel_def). See DESIGN.md §Autotuner / §Kernel registry.
"""

from repro.tune.space import candidates
from repro.tune.tuner import (TunedConfig, best_config, cache_key_for, rank,
                              rank_kernel, tune, tune_kernel)

__all__ = ["candidates", "rank", "rank_kernel", "tune", "tune_kernel",
           "best_config", "cache_key_for", "TunedConfig"]
