"""Model-guided, measurement-verified autotuning for the GPP Pallas kernel.

The paper's v8 is a hand-run block-size sweep frozen into one static config;
this package re-runs that sweep per (problem size, backend): `space`
enumerates divisibility- and VMEM-feasible BlockConfigs, `tuner` ranks them
with the analytic roofline model (core.vpu_model), optionally times the
top-K with the real harness in `measure`, and persists the winner to a JSON
cache so `ops.gpp(..., version="v10")` dispatches to a tuned config
automatically. See DESIGN.md §Autotuner.
"""

from repro.tune.space import candidates
from repro.tune.tuner import TunedConfig, best_config, rank, tune

__all__ = ["candidates", "rank", "tune", "best_config", "TunedConfig"]
