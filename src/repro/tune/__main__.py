"""Tune-cache hygiene CLI (docs/analysis.md §Cache hygiene).

    python -m repro.tune validate [--cache-dir DIR]   # exit 1 if stale
    python -m repro.tune prune    [--cache-dir DIR] [--dry-run]

`validate` is read-only (the auditor's CACHE001 calls the same code);
`prune` rewrites the cache atomically with the stale entries dropped.
"""

from __future__ import annotations

import argparse
import sys

from repro.tune import cache_tools, tuner


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.tune",
                                description=__doc__.splitlines()[0])
    sub = p.add_subparsers(dest="cmd", required=True)
    for name in ("validate", "prune"):
        sp = sub.add_parser(name)
        sp.add_argument("--cache-dir", default=None,
                        help="cache directory (default: $REPRO_TUNE_CACHE "
                             "or runs/tune)")
        if name == "prune":
            sp.add_argument("--dry-run", action="store_true",
                            help="report what would be pruned, keep cache")
    args = p.parse_args(argv)

    path = tuner._cache_path(args.cache_dir)
    if args.cmd == "validate":
        issues = cache_tools.validate_cache(args.cache_dir)
        for i in issues:
            print(f"STALE {i.key}: [{i.reason}] {i.detail}")
        n = len(tuner._load_cache(args.cache_dir))
        print(f"{path}: {n} entries, {len(issues)} stale")
        return 1 if issues else 0

    kept, issues = cache_tools.prune_cache(args.cache_dir,
                                           dry_run=args.dry_run)
    verb = "would prune" if args.dry_run else "pruned"
    for i in issues:
        print(f"{verb} {i.key}: [{i.reason}] {i.detail}")
    print(f"{path}: kept {kept}, {verb} {len(issues)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
