"""Tune-cache hygiene: validate / prune `kernel_tune.json` entries against
the CURRENT registry (docs/analysis.md §CACHE001).

The cache outlives code: a kernel family can drop a version, a config
space can shrink (VMEM budget change, new divisibility rule), a config
dataclass can gain a field. A stale entry then silently re-enters dispatch
with a config the current code would never pick — the static auditor's
CACHE001 rule exists to catch exactly that, and this module is its
read-only backend plus the `python -m repro.tune prune` repair tool.

An entry `kernel|dims|backend|version -> {config: {...}}` is stale when:

  unknown-kernel    the kernel family is no longer registered
  unknown-version   the version left the family's `versions` tuple
  malformed-key     the key does not split into 4 `|` fields
  bad-config        `config_from_json` cannot rebuild the config
                    (field drift in the config dataclass)
  outside-space     the config is not in the kernel's CURRENT
                    `config_space(key, version)` (compared ignoring the
                    cosmetic `name` stamp; needs `key_from_dims` — kernels
                    without it get existence-only validation)
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional, Tuple

from repro.tune import tuner


@dataclasses.dataclass(frozen=True)
class CacheIssue:
    """One stale cache entry: the key, a machine-readable reason (the
    vocabulary in the module docstring), and a human detail line."""
    key: str
    reason: str
    detail: str
    kernel: str = ""
    version: str = ""
    dims: str = ""


def _configs_equal(a, b) -> bool:
    """Config identity ignoring the cosmetic `name` stamp (cached winners
    are renamed to the version; space candidates are named 'tune')."""
    if dataclasses.is_dataclass(a) and dataclasses.is_dataclass(b):
        names = {f.name for f in dataclasses.fields(a)}
        if "name" in names:
            a = dataclasses.replace(a, name="")
            b = dataclasses.replace(b, name="")
    return a == b


def validate_cache(cache_dir: Optional[str] = None) -> List[CacheIssue]:
    """Read-only check of every cache entry against the current registry.
    Returns one `CacheIssue` per stale entry (empty list = clean cache; a
    missing cache file is clean). Never mutates the cache — this is what
    the auditor's CACHE001 rule calls.

    Example::

        from repro.tune.cache_tools import validate_cache
        issues = validate_cache()          # default runs/tune cache
        [(i.key, i.reason) for i in issues]
    """
    from repro.kernels import api
    issues: List[CacheIssue] = []
    for ckey, entry in sorted(tuner._load_cache(cache_dir).items()):
        parts = ckey.split("|")
        if len(parts) != 4:
            issues.append(CacheIssue(ckey, "malformed-key",
                                     f"expected 4 '|' fields, got "
                                     f"{len(parts)}"))
            continue
        kname, dims, _backend, version = parts
        try:
            k = api.get_kernel(kname)
        except KeyError:
            issues.append(CacheIssue(ckey, "unknown-kernel",
                                     f"kernel {kname!r} is not registered",
                                     kernel=kname, version=version,
                                     dims=dims))
            continue
        if version not in k.versions:
            issues.append(CacheIssue(ckey, "unknown-version",
                                     f"{kname} no longer has version "
                                     f"{version!r}", kernel=kname,
                                     version=version, dims=dims))
            continue
        try:
            cfg = k.config_from_json(dict(entry.get("config") or {}))
        except Exception as e:
            issues.append(CacheIssue(ckey, "bad-config",
                                     f"config_from_json failed: {e}",
                                     kernel=kname, version=version,
                                     dims=dims))
            continue
        try:
            key = k.key_from_dims(dims)
        except NotImplementedError:
            continue          # existence-only validation for this family
        except Exception as e:
            issues.append(CacheIssue(ckey, "malformed-key",
                                     f"key_from_dims({dims!r}) failed: {e}",
                                     kernel=kname, version=version,
                                     dims=dims))
            continue
        space = k.config_space(key, version)
        if space and not any(_configs_equal(cfg, c) for c in space):
            issues.append(CacheIssue(
                ckey, "outside-space",
                f"cached config {entry.get('config')} not in the current "
                f"{len(space)}-candidate space", kernel=kname,
                version=version, dims=dims))
    return issues


def prune_cache(cache_dir: Optional[str] = None, *, dry_run: bool = False
                ) -> Tuple[int, List[CacheIssue]]:
    """Drop every stale entry `validate_cache` flags and rewrite the cache
    atomically. Returns `(kept, dropped_issues)`; warns with the full list
    of pruned keys so a CI log shows what disappeared. `dry_run=True`
    reports without rewriting.

    Example::

        from repro.tune.cache_tools import prune_cache
        kept, dropped = prune_cache(dry_run=True)
    """
    issues = validate_cache(cache_dir)
    entries = tuner._load_cache(cache_dir)
    stale = {i.key for i in issues}
    kept = {k: v for k, v in entries.items() if k not in stale}
    if stale and not dry_run:
        tuner._store_cache(cache_dir, kept)
        tuner.clear_memo()      # drop in-process copies of pruned entries
    if stale:
        warnings.warn("pruned stale tune-cache entries: "
                      + ", ".join(sorted(stale)), stacklevel=2)
    return len(kept), issues
