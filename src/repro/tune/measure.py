"""Timing harness for the tuner's measurement pass.

Median-of-reps wall clock around the compiled (or interpreted) kernel with
`jax.block_until_ready` fencing — the same discipline as benchmarks/run.py:
warmup calls first (compile/trace cost excluded), then `reps` timed calls,
report the median (robust to scheduler noise).

`time_callable` is the generic harness the registry-wide tuner uses (any
kernel's `run` closure); `time_config` is the original GPP-specific entry,
kept for direct callers.

On CPU the Pallas kernels only run in interpret mode, which is orders of
magnitude slower than a real TPU but preserves the *relative* cost of
configs at small sizes; `tuner.tune_kernel` only enables measurement on CPU
when the kernel's `measure_ok` gate says the problem is small enough (for
gpp: below `MEASURE_MAX_ITERS`) so the pass stays cheap.
"""

from __future__ import annotations

import statistics
import time
from typing import Callable, Dict

import jax

from repro.kernels.gpp import pallas_gpp

# largest size.inner_iters the CPU (interpret-mode) measurement pass will
# time for the GPP kernel; beyond this the model-only ranking is used.
MEASURE_MAX_ITERS = 1 << 17


def time_callable(fn: Callable[[], object], *, warmup: int = 1,
                  reps: int = 3) -> float:
    """Median seconds per call of `fn` (fenced with block_until_ready).

    warmup=0 is honored (callers measuring cold-start/compile cost want the
    first timed call to include it); only negative values are clamped."""
    def call():
        out = fn()
        jax.block_until_ready(out)
        return out

    for _ in range(max(warmup, 0)):
        call()
    times = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        call()
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


def time_config(inputs: Dict, cfg: pallas_gpp.BlockConfig, *,
                interpret: bool, warmup: int = 1, reps: int = 3) -> float:
    """Median seconds per call of the GPP Pallas kernel under `cfg`."""
    return time_callable(
        lambda: pallas_gpp.gpp_pallas(inputs, cfg, interpret=interpret),
        warmup=warmup, reps=reps)
