"""Candidate-space generation for the GPP block-size tuner.

Generalizes `journey.sweep_blocks`' fixed grid to any `GppSize`: a candidate
block size must (a) exactly tile every axis it blocks (the kernel asserts
divisibility), and (b) keep the analytic VMEM working set inside the chip's
VMEM budget (double-buffered inputs + live intermediates, BlockConfig
.vmem_bytes). The menu is geometric — powers of two per axis — because the
TPU's 8x128 VREG/DMA granularity makes intermediate sizes strictly worse
than the nearest power of two on at least one of lane fill or traffic.
"""

from __future__ import annotations

from typing import List, Sequence

from repro.core.hw import TPU_V5E
from repro.kernels.gpp.pallas_gpp import BlockConfig
from repro.kernels.gpp.problem import GppSize

# per-axis menus: every power of two in the plausible range; filtered per
# size by divisibility. ig is the lane-reduction axis (bigger amortizes),
# igp the lane axis (128 fills the VREG), band the sequential axis.
IG_MENU = (8, 16, 32, 64, 128, 256, 512, 1024)
IGP_MENU = (4, 8, 16, 32, 64, 128, 256)
BAND_MENU = (4, 8, 16, 32, 64, 128, 256, 512)


def _divisors(n: int, menu: Sequence[int]) -> List[int]:
    return [b for b in menu if b <= n and n % b == 0]


def candidates(size: GppSize, *, fused: bool = True,
               aqsm_transposed: bool = True,
               vmem_budget: int = TPU_V5E.vmem_bytes) -> List[BlockConfig]:
    """All feasible BlockConfigs for `size`: divisibility-exact on every
    axis and VMEM-feasible. Deterministic order (menu order)."""
    out = []
    for big in _divisors(size.ncouls, IG_MENU):
        for bigp in _divisors(size.ngpown, IGP_MENU):
            for bb in _divisors(size.nbands, BAND_MENU):
                cfg = BlockConfig("tune", big, bigp, bb,
                                  aqsm_transposed=aqsm_transposed,
                                  fused_acc=fused)
                if cfg.vmem_bytes(size.nw) <= vmem_budget:
                    out.append(cfg)
    return out
