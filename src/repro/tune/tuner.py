"""Model-then-measure block-size tuner with a persisted JSON cache.

Flow (DESIGN.md §Autotuner):
  1. `rank(size)` — enumerate the feasible space (tune.space) and sort by
     the analytic roofline model (core.vpu_model.pallas_step_s): compute
     passes + per-instance grid overhead vs HBM traffic, max() of the two.
  2. `tune(size)` — optionally time the model's top-K with the real harness
     (tune.measure) and let measurement override the model's order. On CPU
     the kernel runs in interpret mode, so measurement is only attempted
     below measure.MEASURE_MAX_ITERS; on TPU it always runs (compiled).
  3. The winner is persisted to `<cache_dir>/gpp_tune.json`, keyed by
     (problem dims, backend, kernel version), so repeated
     `ops.gpp(..., version="v10")` calls dispatch straight to the tuned
     config. Cache dir: $REPRO_TUNE_CACHE, else ./runs/tune.

An in-process memo sits in front of the JSON file; `clear_memo()` resets it
(tests point $REPRO_TUNE_CACHE at a tmp dir).
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Dict, List, Optional, Tuple

import jax

from repro.core import vpu_model
from repro.kernels.gpp import pallas_gpp, problem
from repro.tune import measure, space

CACHE_ENV = "REPRO_TUNE_CACHE"
CACHE_FILE = "gpp_tune.json"
DEFAULT_VERSION = "v10"

_MEMO: Dict[str, "TunedConfig"] = {}


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    config: pallas_gpp.BlockConfig
    modeled_s: float
    measured_s: Optional[float]      # None when the measurement pass skipped
    key: str
    source: str                      # "model" | "measured" | "cache"

    def to_json(self) -> Dict:
        return {"config": dataclasses.asdict(self.config),
                "modeled_s": self.modeled_s,
                "measured_s": self.measured_s,
                "key": self.key, "source": self.source}

    @staticmethod
    def from_json(d: Dict) -> "TunedConfig":
        return TunedConfig(config=pallas_gpp.BlockConfig(**d["config"]),
                           modeled_s=d["modeled_s"],
                           measured_s=d.get("measured_s"),
                           key=d["key"], source="cache")


def cache_key(size: problem.GppSize, backend: str, version: str) -> str:
    return (f"{size.ncouls}x{size.ngpown}x{size.nbands}x{size.nw}"
            f"|{backend}|{version}")


def _cache_dir() -> str:
    return os.environ.get(CACHE_ENV, os.path.join("runs", "tune"))


def _cache_path(cache_dir: Optional[str]) -> str:
    return os.path.join(cache_dir or _cache_dir(), CACHE_FILE)


def _load_cache(cache_dir: Optional[str]) -> Dict:
    path = _cache_path(cache_dir)
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return {}


def _store_cache(cache_dir: Optional[str], entries: Dict) -> None:
    path = _cache_path(cache_dir)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    # atomic replace: a crashed writer never leaves a truncated cache
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    with os.fdopen(fd, "w") as fh:
        json.dump(entries, fh, indent=1, sort_keys=True)
    os.replace(tmp, path)


def clear_memo() -> None:
    _MEMO.clear()


def rank(size: problem.GppSize, *, version: str = DEFAULT_VERSION
         ) -> List[Tuple[pallas_gpp.BlockConfig, float]]:
    """Feasible configs sorted by modeled step time (deterministic
    tie-break: bigger blocks first — fewer grid instances)."""
    fused = version not in ("v6", "v7", "v8")
    mix = vpu_model.OP_MIX.get(version, vpu_model.OP_MIX["v9"])
    scored = [(cfg, vpu_model.pallas_step_s(size, cfg, mix))
              for cfg in space.candidates(size, fused=fused)]
    scored.sort(key=lambda ct: (ct[1], -ct[0].blk_band, -ct[0].blk_ig,
                                -ct[0].blk_igp))
    return scored


def _should_measure(size: problem.GppSize, backend: str) -> bool:
    if backend == "tpu":
        return True
    return size.inner_iters <= measure.MEASURE_MAX_ITERS


def tune(size: problem.GppSize, *, version: str = DEFAULT_VERSION,
         backend: Optional[str] = None, measure_mode: Optional[bool] = None,
         top_k: int = 3, warmup: int = 1, reps: int = 3,
         cache_dir: Optional[str] = None, use_cache: bool = True,
         seed: int = 0) -> TunedConfig:
    """Pick the best BlockConfig for (size, backend, version).

    measure_mode: True forces the timing pass, False forces model-only,
    None (default) measures iff the backend is TPU or the size is small
    enough for CPU interpret timing. The result is memoized in-process and
    persisted to the JSON cache (use_cache=False bypasses both)."""
    backend = backend or jax.default_backend()
    key = cache_key(size, backend, version)
    # memo per cache *file*, not just per key — two explicit cache_dirs must
    # not see each other's results
    memo_key = (os.path.abspath(_cache_path(cache_dir)), key)

    if use_cache:
        if memo_key in _MEMO:
            return _MEMO[memo_key]
        disk = _load_cache(cache_dir)
        if key in disk:
            try:
                tc = TunedConfig.from_json(disk[key])
            except (KeyError, TypeError):
                pass    # schema-stale entry (e.g. BlockConfig field rename)
            else:       # -> fall through and re-tune
                _MEMO[memo_key] = tc
                return tc

    ranked = rank(size, version=version)
    if not ranked:
        raise ValueError(f"no feasible BlockConfig for {size}")

    do_measure = (measure_mode if measure_mode is not None
                  else _should_measure(size, backend))
    best_cfg, best_model_s = ranked[0]
    measured_s = None
    if do_measure and top_k > 0:
        inputs = problem.make_inputs(size, seed=seed)
        interpret = backend != "tpu"
        timed = []
        for cfg, model_s in ranked[:top_k]:
            t = measure.time_config(inputs, cfg, interpret=interpret,
                                    warmup=warmup, reps=reps)
            timed.append((t, model_s, cfg))
        timed.sort(key=lambda x: x[0])
        measured_s, best_model_s, best_cfg = timed[0]

    tc = TunedConfig(config=dataclasses.replace(best_cfg, name=version),
                     modeled_s=best_model_s, measured_s=measured_s, key=key,
                     source="measured" if measured_s is not None else "model")
    if use_cache:
        _MEMO[memo_key] = tc
        disk = _load_cache(cache_dir)
        disk[key] = tc.to_json()
        _store_cache(cache_dir, disk)
    return tc


def best_config(size: problem.GppSize, **kwargs) -> pallas_gpp.BlockConfig:
    """The tuned BlockConfig for `size` (tune() shorthand)."""
    return tune(size, **kwargs).config
