"""Model-then-measure config tuner with a persisted JSON cache, generalized
over the kernel registry (`repro.kernels.api`).

Flow (DESIGN.md §Autotuner):
  1. `rank_kernel(kernel, key)` — enumerate the kernel's feasible config
     space and sort by its analytic roofline-model hook (for gpp that is
     core.vpu_model.pallas_step_s: compute passes + per-instance grid
     overhead vs HBM traffic, max() of the two).
  2. `tune_kernel(kernel, key)` — optionally time the model's top-K with
     the real harness (tune.measure) and let measurement override the
     model's order. On CPU the kernels run in interpret mode, so the
     timing pass only runs when the kernel's `measure_ok(key)` says the
     problem is small enough; on TPU it always runs (compiled).
  3. The winner is persisted to `<cache_dir>/kernel_tune.json`, keyed by
     `(kernel, ProblemKey dims, backend, version)`, so repeated
     dispatches go straight to the tuned config. Cache dir:
     $REPRO_TUNE_CACHE, else ./runs/tune.

`tune`/`rank`/`best_config`/`cache_key` keep their original GPP-only
signatures as wrappers over the generic flow — existing callers and the
`ops.gpp(..., version="v10")` shim are unchanged.

An in-process memo sits in front of the JSON file; `clear_memo()` resets it
(tests point $REPRO_TUNE_CACHE at a tmp dir).
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any, Dict, List, Optional, Tuple

from repro import backend as backend_lib
from repro.kernels.gpp import pallas_gpp, problem
from repro.tune import measure

CACHE_ENV = "REPRO_TUNE_CACHE"
CACHE_FILE = "kernel_tune.json"
DEFAULT_VERSION = "v10"

_MEMO: Dict[Tuple[str, str], "TunedConfig"] = {}


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    config: Any                      # kernel-specific (BlockConfig, ...)
    modeled_s: float
    measured_s: Optional[float]      # None when the measurement pass skipped
    key: str
    source: str                      # "model" | "measured" | "cache"
    kernel: str = "gpp"

    def to_json(self) -> Dict:
        from repro.kernels import api
        return {"kernel": self.kernel,
                "config": api.get_kernel(self.kernel).config_to_json(
                    self.config),
                "modeled_s": self.modeled_s,
                "measured_s": self.measured_s,
                "key": self.key, "source": self.source}

    @staticmethod
    def from_json(d: Dict) -> "TunedConfig":
        from repro.kernels import api
        kernel = d.get("kernel", "gpp")
        return TunedConfig(
            config=api.get_kernel(kernel).config_from_json(d["config"]),
            modeled_s=d["modeled_s"], measured_s=d.get("measured_s"),
            key=d["key"], source="cache", kernel=kernel)


def cache_key_for(kernel: str, key, backend: str, version: str) -> str:
    """The generalized cache key: (kernel, ProblemKey dims, backend,
    version)."""
    return f"{kernel}|{key.key_dims()}|{backend}|{version}"


def cache_key(size: problem.GppSize, backend: str, version: str) -> str:
    """Legacy GPP-only form of cache_key_for."""
    return cache_key_for("gpp", size, backend, version)


def _cache_dir() -> str:
    return os.environ.get(CACHE_ENV, os.path.join("runs", "tune"))


def _cache_path(cache_dir: Optional[str]) -> str:
    return os.path.join(cache_dir or _cache_dir(), CACHE_FILE)


def _load_cache(cache_dir: Optional[str]) -> Dict:
    path = _cache_path(cache_dir)
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return {}


def _store_cache(cache_dir: Optional[str], entries: Dict) -> None:
    path = _cache_path(cache_dir)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    # atomic replace: a crashed writer never leaves a truncated cache
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    with os.fdopen(fd, "w") as fh:
        json.dump(entries, fh, indent=1, sort_keys=True)
    os.replace(tmp, path)


def clear_memo() -> None:
    _MEMO.clear()


def rank_kernel(kernel: str, key, *, version: Optional[str] = None
                ) -> List[Tuple[Any, float]]:
    """Feasible configs for (kernel, key) sorted by the kernel's modeled
    step time (deterministic tie-break via Kernel.tie_break)."""
    from repro.kernels import api
    k = api.get_kernel(kernel)
    version = version or k.default_version
    scored = [(cfg, k.model_step_s(key, cfg, version))
              for cfg in k.config_space(key, version)]
    scored.sort(key=lambda ct: (ct[1],) + tuple(k.tie_break(ct[0])))
    return scored


def rank(size: problem.GppSize, *, version: str = DEFAULT_VERSION
         ) -> List[Tuple[pallas_gpp.BlockConfig, float]]:
    """Legacy GPP-only form of rank_kernel."""
    return rank_kernel("gpp", size, version=version)


def tune_kernel(kernel: str, key, *, version: Optional[str] = None,
                backend: Optional[str] = None,
                measure_mode: Optional[bool] = None,
                top_k: int = 3, warmup: int = 1, reps: int = 3,
                cache_dir: Optional[str] = None, use_cache: bool = True,
                seed: int = 0) -> TunedConfig:
    """Pick the best config for (kernel, key, backend, version) — the
    model-then-measure flow: rank the kernel's feasible configs by its
    analytic roofline model, then (when measurement is allowed) time the
    top_k on synthetic inputs and let wall clock break the near-ties.

    measure_mode: True forces the timing pass, False forces model-only,
    None (default) measures iff the backend is TPU or the kernel's
    measure_ok(key) allows CPU interpret timing. The result is memoized
    in-process and persisted to the JSON cache (use_cache=False bypasses
    both); TunedConfig.source records which path chose it
    (model | measured | cache).

    Example::

        import repro
        from repro.kernels.flash.kernel_def import FlashKey
        tc = repro.tune_kernel(
            "flash", FlashKey(b=4, h=8, kvh=2, sq=256, skv=256, hd=64),
            measure_mode=False)
        tc.config.blk_q, tc.source      # (256, 'model')
    """
    from repro.kernels import api
    k = api.get_kernel(kernel)
    version = version or k.default_version
    backend = backend or backend_lib.backend_name()
    ckey = cache_key_for(kernel, key, backend, version)
    # memo per cache *file*, not just per key — two explicit cache_dirs must
    # not see each other's results
    memo_key = (os.path.abspath(_cache_path(cache_dir)), ckey)

    if use_cache:
        if memo_key in _MEMO:
            return _MEMO[memo_key]
        disk = _load_cache(cache_dir)
        if ckey in disk:
            try:
                tc = TunedConfig.from_json(disk[ckey])
            except (KeyError, TypeError):
                pass    # schema-stale entry (e.g. config field rename)
            else:       # -> fall through and re-tune
                _MEMO[memo_key] = tc
                return tc

    ranked = rank_kernel(kernel, key, version=version)
    if not ranked:
        raise ValueError(f"no feasible {kernel} config for {key}")

    do_measure = (measure_mode if measure_mode is not None
                  else backend == "tpu" or k.measure_ok(key))
    best_cfg, best_model_s = ranked[0]
    measured_s = None
    if do_measure and top_k > 0:
        args, kwargs = k.make_example(key, seed=seed)
        interpret = backend != "tpu"
        timed = []
        for cfg, model_s in ranked[:top_k]:
            t = measure.time_callable(
                lambda cfg=cfg: k.run(*args, version=version, config=cfg,
                                      interpret=interpret, **kwargs),
                warmup=warmup, reps=reps)
            timed.append((t, model_s, cfg))
        timed.sort(key=lambda x: x[0])
        measured_s, best_model_s, best_cfg = timed[0]

    tc = TunedConfig(config=k.finalize_config(best_cfg, version),
                     modeled_s=best_model_s, measured_s=measured_s,
                     key=ckey,
                     source="measured" if measured_s is not None else "model",
                     kernel=kernel)
    if use_cache:
        _MEMO[memo_key] = tc
        disk = _load_cache(cache_dir)
        disk[ckey] = tc.to_json()
        _store_cache(cache_dir, disk)
    return tc


def tune(size: problem.GppSize, *, version: str = DEFAULT_VERSION,
         **kwargs) -> TunedConfig:
    """Legacy GPP-only form of tune_kernel (same keyword surface)."""
    return tune_kernel("gpp", size, version=version, **kwargs)


def best_config(size: problem.GppSize, **kwargs) -> pallas_gpp.BlockConfig:
    """The tuned BlockConfig for `size` (tune() shorthand)."""
    return tune(size, **kwargs).config
