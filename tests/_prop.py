"""Property-test shim: `from _prop import given, settings, st`.

With hypothesis installed (requirements-dev.txt) this re-exports the real
thing. Without it, a deterministic fallback runs each property over a
small seeded sample of examples — weaker shrinking/coverage, but tier-1
collection never fails on a missing dev dependency.
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic sampled-example fallback
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 8      # cap per property; keeps tier-1 cheap

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    class st:  # noqa: N801 — mimics `strategies as st`
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

    def given(**strategies):
        def deco(fn):
            def wrapper():
                # @settings may sit above @given (attr lands on wrapper) or
                # below it (attr lands on fn) — hypothesis allows both
                limit = getattr(wrapper, "_max_examples",
                                getattr(fn, "_max_examples",
                                        _FALLBACK_EXAMPLES))
                n = min(limit, _FALLBACK_EXAMPLES)
                rng = random.Random(0)
                for _ in range(n):
                    fn(**{k: s.example(rng) for k, s in strategies.items()})
            # plain def (no functools.wraps): pytest must see a zero-arg
            # signature, not the strategy params as fixture requests
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def settings(max_examples=_FALLBACK_EXAMPLES, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
