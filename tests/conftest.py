import atexit
import os
import shutil
import sys
import tempfile

# src layout import without install; single real CPU device (the dry-run's
# 512 forced host devices are scoped to launch/dryrun.py and the subprocess
# tests ONLY — per the multi-pod dry-run contract).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# the GPP autotuner persists winners to $REPRO_TUNE_CACHE (default
# ./runs/tune) — point the whole test session at a throwaway dir so tests
# never read or write a developer's real cache (unconditionally: an
# inherited value would leak stale tuned configs into the tests and test
# winners into the developer's cache).
_tune_cache = tempfile.mkdtemp(prefix="repro-tune-test-")
os.environ["REPRO_TUNE_CACHE"] = _tune_cache
atexit.register(shutil.rmtree, _tune_cache, ignore_errors=True)
