import os
import sys

# src layout import without install; single real CPU device (the dry-run's
# 512 forced host devices are scoped to launch/dryrun.py and the subprocess
# tests ONLY — per the multi-pod dry-run contract).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
