"""Deliberately-broken fixture kernel for the static-auditor tests
(docs/analysis.md §Testing the gate).

Registers `badfix`: a kernel whose only config declares a VMEM working set
double the hardware budget — the auditor must flag it VMEM001 (error) and
`python -m repro.analyze --strict --extra-module fixture_badkernel
--kernel badfix` must exit nonzero. Import-time registration is the point:
the CLI's `--extra-module` hook exists exactly so out-of-tree kernels join
the audit this way.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.hw import TPU_V5E
from repro.kernels import api


@dataclasses.dataclass(frozen=True)
class FixKey:
    n: int = 256
    name: str = "fix"

    def key_dims(self) -> str:
        return str(self.n)


@dataclasses.dataclass(frozen=True)
class FixConfig:
    name: str = "bad"
    # f32 elements; 2x the whole VMEM on purpose
    blk: int = 2 * TPU_V5E.vmem_bytes // 4


class BadKernel(api.Kernel):
    name = "badfix"
    versions = ("pallas",)
    default_version = "pallas"

    def static_config(self, key: FixKey, version: str) -> FixConfig:
        return FixConfig()

    def make_example(self, key: FixKey, seed: int = 0) -> Tuple[tuple, dict]:
        return (jnp.asarray(np.linspace(0, 1, key.n, dtype=np.float32)),), {}

    def config_from_json(self, d: Dict) -> FixConfig:
        return FixConfig(**d)

    def canonical_keys(self) -> List[FixKey]:
        return [FixKey()]

    def key_from_dims(self, dims: str) -> FixKey:
        return FixKey(n=int(dims))

    def config_vmem_bytes(self, config: FixConfig, key: FixKey
                          ) -> Optional[int]:
        return 4 * config.blk

    def run(self, x, *, version: str, config: Optional[FixConfig],
            interpret: Optional[bool]):
        return jnp.tanh(x) * x + x


KERNEL = api.register(BadKernel())
