"""Static kernel auditor tests (docs/analysis.md).

Tiers here:
  * golden census — FLOPs and FMA-pairable FLOPs for every gpp version at
    TINY, pinned EXACTLY (the census is a deterministic jaxpr walk; a
    changed number means the kernel's arithmetic changed, which is
    precisely what the auditor exists to surface);
  * rule engine — each rule driven to fire via a minimal fake kernel fed
    straight to `audit_kernel` (no registry pollution);
  * the lint gate — the registry audits clean under --strict, and the
    deliberately-broken `fixture_badkernel` fails it with VMEM001;
  * tune-cache hygiene — validate/prune against a synthetic stale cache.
"""

import dataclasses
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analyze import audit_registry
from repro.analyze.census import census_kernel
from repro.analyze.rules import RULES, audit_kernel
from repro.kernels import api
from repro.kernels.gpp import problem
from repro.tune import cache_tools, tuner

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TESTS_DIR = os.path.join(REPO_ROOT, "tests")


# ---------------------------------------------------------------------------
# golden census: gpp v0–v10 at TINY, exact
# ---------------------------------------------------------------------------

# (total_flops, fma_pairable_flops) per version — regenerate with:
#   PYTHONPATH=src python -c "from repro.analyze.census import census_kernel;
#     from repro.kernels import api; from repro.kernels.gpp import problem;
#     [print(v, census_kernel(api.get_kernel('gpp'), v, problem.TINY).flops)
#      for v in api.get_kernel('gpp').versions]"
GOLDEN_GPP_TINY = {
    "v0": (737544.0, 462984.0),
    "v1": (762120.0, 462984.0),
    "v2": (745736.0, 462984.0),
    "v3": (729352.0, 462984.0),
    "v4": (833752.0, 479368.0),
    "v5": (800920.0, 462984.0),
    "v6": (800360.0, 461992.0),
    "v7": (800360.0, 461992.0),
    "v8": (800360.0, 461992.0),
    "v9": (800360.0, 461992.0),
    "v10": (800360.0, 461992.0),
}


def test_gpp_census_golden():
    k = api.get_kernel("gpp")
    assert set(GOLDEN_GPP_TINY) == set(k.versions)
    for version, (flops, fma_flops) in GOLDEN_GPP_TINY.items():
        c = census_kernel(k, version, problem.TINY)
        assert c.flops == flops, (version, c.flops)
        assert c.fma_flops == fma_flops, (version, c.fma_flops)
        assert 0.0 < c.fma_fraction < 1.0
        # census agrees with the paper-derived analytic count within 2x
        assert 0.5 < c.flops / problem.TINY.total_flops() < 2.0


def test_gpp_census_pallas_structure():
    """The Pallas versions carry grid/VMEM structure the pure-JAX ones
    don't, and the census must see through scan+cond+pallas_call."""
    k = api.get_kernel("gpp")
    c = census_kernel(k, "v10", problem.TINY)
    assert c.grid_instances >= 1
    assert c.vmem_block_bytes and c.vmem_block_bytes > 0
    assert c.vmem_config_bytes and c.vmem_config_bytes > 0
    assert c.model_s is not None and c.model_s > 0
    assert c.bound_s > 0 and c.model_s > c.bound_s * 0.4
    assert c.float_dtypes == ("complex64", "float32")
    v0 = census_kernel(k, "v0", problem.TINY)
    assert v0.grid_instances == 0 and v0.vmem_block_bytes is None
    assert v0.hbm_bytes == c.hbm_bytes      # same planar operands/results


def test_flash_ssm_census():
    fk = api.get_kernel("flash")
    c = census_kernel(fk, "pallas", fk.canonical_keys()[0])
    assert c.dot_flops == 8388608.0          # 2 matmuls x 2BH x S^2 x hd x 2
    assert c.dot_flops / c.flops > 0.9       # attention is MXU-dominated
    assert "bfloat16" in c.float_dtypes      # operand dtype must be seen
    sk = api.get_kernel("ssm")
    s = census_kernel(sk, "pallas", sk.canonical_keys()[0])
    assert s.dot_flops == 0.0                # scan form never hits the MXU
    chunked = census_kernel(sk, "chunked", sk.canonical_keys()[0])
    assert chunked.dot_flops > 0             # chunk-parallel form does
    assert s.grid_instances == 2             # c=64 / blk_c=32... or menu


# ---------------------------------------------------------------------------
# rule engine via minimal fake kernels (fed straight to audit_kernel)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Key:
    n: int = 64
    name: str = "fake"

    def key_dims(self) -> str:
        return str(self.n)


@dataclasses.dataclass(frozen=True)
class _Cfg:
    name: str = "cfg"
    blk: int = 8


class _FakeKernel(api.Kernel):
    name = "fake"
    versions = ("v",)
    default_version = "v"

    def static_config(self, key, version):
        return _Cfg()

    def make_example(self, key, seed=0):
        x = jnp.asarray(np.ones((key.n, key.n), np.float32))
        return (x,), {}

    def config_from_json(self, d):
        return _Cfg(**d)

    def run(self, x, *, version, config, interpret):
        return jnp.tanh(x) + x


def _rules_fired(k, key=_Key()):
    _, findings = audit_kernel(k, "v", key)
    return {f.rule for f in findings}, findings


def test_rule_vmem001():
    class K(_FakeKernel):
        def config_vmem_bytes(self, config, key):
            return 1 << 30                      # 1 GiB >> 16 MiB budget

    fired, findings = _rules_fired(K())
    assert fired == {"VMEM001"}
    f = [x for x in findings if x.rule == "VMEM001"][0]
    assert f.severity == "error" and "VMEM" in f.message


def test_rule_blk001():
    class K(_FakeKernel):
        def config_divides(self, config, key):
            return [f"n={key.n} not tiled by block 7"]

    fired, findings = _rules_fired(K())
    assert fired == {"BLK001"}
    assert findings[0].severity == "error"


def test_rule_dtype001():
    class K(_FakeKernel):
        def allowed_float_dtypes(self, version):
            return frozenset({"bfloat16"})      # but run computes in f32

    fired, findings = _rules_fired(K())
    assert fired == {"DTYPE001"}
    assert "float32" in findings[0].message


def test_rule_dup001():
    class K(_FakeKernel):
        def run(self, x, *, version, config, interpret):
            return x * x + x * x                # identical expensive muls

    fired, findings = _rules_fired(K())
    assert fired == {"DUP001"}
    assert findings[0].severity == "warning"    # advisory, not a gate fail


def test_rule_model001():
    class K(_FakeKernel):
        def model_step_s(self, key, config, version):
            return 1e-15                        # faster than the hardware

    fired, findings = _rules_fired(K())
    assert fired == {"MODEL001"}
    f = findings[0]
    assert f.severity == "error" and dict(f.data)["ratio"] < 0.4


def test_sane_model_no_drift():
    class K(_FakeKernel):
        def model_step_s(self, key, config, version):
            return 1.0                          # way above any bound: fine

    fired, _ = _rules_fired(K())
    assert fired == set()


# ---------------------------------------------------------------------------
# the lint gate: clean registry, broken fixture, CLI exit codes
# ---------------------------------------------------------------------------

def test_registry_audits_clean():
    """The acceptance bar: every registered (kernel, version, canonical
    shape) passes with zero error findings."""
    report = audit_registry()
    assert len(report.censuses) == sum(
        len(api.get_kernel(n).canonical_keys()) * len(api.get_kernel(n).versions)
        for n in api.list_kernels())
    assert report.errors == [], [f.row() for f in report.errors]
    payload = report.to_json()
    assert payload["schema"] == "repro-analyze/v1"
    assert payload["n_errors"] == 0
    assert set(payload["rules"]) == set(RULES)


def test_broken_fixture_fails_strict(tmp_path):
    """fixture_badkernel registers a VMEM-oversized kernel; the CLI must
    surface VMEM001 and --strict must exit nonzero (the CI gate works)."""
    from repro.analyze.__main__ import main
    sys.path.insert(0, TESTS_DIR)
    try:
        out = tmp_path / "report.json"
        rc = main(["--strict", "--no-cache", "--kernel", "badfix",
                   "--json", str(out), "--extra-module", "fixture_badkernel"])
        assert rc == 1
        payload = json.loads(out.read_text())
        rules_hit = {f["rule"] for f in payload["findings"]}
        assert "VMEM001" in rules_hit
        assert payload["n_errors"] >= 1
        # non-strict: same findings, but exit 0 (report-only mode)
        assert main(["--no-cache", "--kernel", "badfix"]) == 0
    finally:
        sys.path.remove(TESTS_DIR)
        api._REGISTRY.pop("badfix", None)


@pytest.mark.slow
def test_cli_strict_subprocess():
    """End-to-end: the exact invocation the CI static-analysis job runs
    exits 0 on the real registry, and nonzero with the broken fixture."""
    src = os.path.join(REPO_ROOT, "src")
    env = dict(os.environ, PYTHONPATH=src + os.pathsep + TESTS_DIR)
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analyze", "--strict", "--no-cache"],
        capture_output=True, text=True, timeout=560, cwd=REPO_ROOT, env=env)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analyze", "--strict", "--no-cache",
         "--kernel", "badfix", "--extra-module", "fixture_badkernel"],
        capture_output=True, text=True, timeout=560, cwd=REPO_ROOT, env=env)
    assert bad.returncode == 1, bad.stdout + bad.stderr
    assert "VMEM001" in bad.stdout


# ---------------------------------------------------------------------------
# tune-cache hygiene (validate / prune / CACHE001)
# ---------------------------------------------------------------------------

def _write_cache(tmp_path, entries):
    d = tmp_path / "tune"
    d.mkdir(exist_ok=True)
    (d / tuner.CACHE_FILE).write_text(json.dumps(entries))
    return str(d)


def _gpp_entry(version="v10", dims="64x8x8x2", blk_ig=64):
    return {"kernel": "gpp",
            "config": {"name": version, "blk_ig": blk_ig, "blk_igp": 8,
                       "blk_band": 8, "aqsm_transposed": True,
                       "fused_acc": True}}


def test_validate_cache_flags_stale(tmp_path):
    cache_dir = _write_cache(tmp_path, {
        "gpp|64x8x8x2|cpu|v10": _gpp_entry(),                  # valid
        "gpp|64x8x8x2|cpu|v99": _gpp_entry("v99"),             # gone version
        "gone|64x8x8x2|cpu|v1": _gpp_entry(),                  # gone kernel
        "gpp|64x8x8x2|cpu": _gpp_entry(),                      # malformed
        "gpp|64x8x8x2|tpu|v10": {"kernel": "gpp",              # bad config
                                 "config": {"name": "x", "nope": 1}},
    })
    issues = cache_tools.validate_cache(cache_dir)
    reasons = {i.key: i.reason for i in issues}
    assert reasons == {
        "gpp|64x8x8x2|cpu|v99": "unknown-version",
        "gone|64x8x8x2|cpu|v1": "unknown-kernel",
        "gpp|64x8x8x2|cpu": "malformed-key",
        "gpp|64x8x8x2|tpu|v10": "bad-config",
    }


def test_validate_cache_outside_space(tmp_path):
    # blk_ig=3 divides nothing in the menu: not a current candidate
    cache_dir = _write_cache(tmp_path, {
        "gpp|64x8x8x2|cpu|v10": _gpp_entry(blk_ig=3)})
    issues = cache_tools.validate_cache(cache_dir)
    assert [i.reason for i in issues] == ["outside-space"]
    assert issues[0].kernel == "gpp" and issues[0].version == "v10"


def test_validate_cache_clean_and_missing(tmp_path):
    assert cache_tools.validate_cache(str(tmp_path / "nope")) == []
    cache_dir = _write_cache(tmp_path, {
        "gpp|64x8x8x2|cpu|v10": _gpp_entry()})
    assert cache_tools.validate_cache(cache_dir) == []


def test_prune_cache(tmp_path):
    cache_dir = _write_cache(tmp_path, {
        "gpp|64x8x8x2|cpu|v10": _gpp_entry(),
        "gpp|64x8x8x2|cpu|v99": _gpp_entry("v99"),
    })
    with pytest.warns(UserWarning, match="v99"):
        kept, dropped = cache_tools.prune_cache(cache_dir)
    assert kept == 1 and [i.reason for i in dropped] == ["unknown-version"]
    left = json.loads((tmp_path / "tune" / tuner.CACHE_FILE).read_text())
    assert list(left) == ["gpp|64x8x8x2|cpu|v10"]
    assert cache_tools.validate_cache(cache_dir) == []


def test_prune_dry_run(tmp_path):
    cache_dir = _write_cache(tmp_path, {
        "gpp|64x8x8x2|cpu|v99": _gpp_entry("v99")})
    with pytest.warns(UserWarning):
        kept, dropped = cache_tools.prune_cache(cache_dir, dry_run=True)
    assert kept == 0 and len(dropped) == 1
    # dry run left the file untouched
    assert len(json.loads(
        (tmp_path / "tune" / tuner.CACHE_FILE).read_text())) == 1


def test_tune_cli(tmp_path):
    from repro.tune.__main__ import main
    cache_dir = _write_cache(tmp_path, {
        "gpp|64x8x8x2|cpu|v10": _gpp_entry(),
        "gpp|64x8x8x2|cpu|v99": _gpp_entry("v99"),
    })
    assert main(["validate", "--cache-dir", cache_dir]) == 1
    with pytest.warns(UserWarning):
        assert main(["prune", "--cache-dir", cache_dir]) == 0
    assert main(["validate", "--cache-dir", cache_dir]) == 0


def test_audit_registry_reports_cache_findings(tmp_path):
    cache_dir = _write_cache(tmp_path, {
        "gpp|64x8x8x2|cpu|v99": _gpp_entry("v99")})
    report = audit_registry(["ssm"], cache_dir=cache_dir)
    cache_findings = [f for f in report.findings if f.rule == "CACHE001"]
    assert len(cache_findings) == 1
    assert cache_findings[0].severity == "error"
    assert dict(cache_findings[0].data)["reason"] == "unknown-version"
    # and the validator is read-only: the stale entry is still there
    assert len(json.loads(
        (tmp_path / "tune" / tuner.CACHE_FILE).read_text())) == 1
