"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step + prefill + decode on CPU, asserting output
shapes and no NaNs. Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ARCH_IDS, applicable_shapes, get_config,
                                reduce_config)
from repro.models.registry import build_model

B, S = 2, 64


def _batch(cfg, rng):
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            rng, (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["vis"] = 0.02 * jax.random.normal(
            rng, (B, cfg.n_vis_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    batch = _batch(cfg, rng)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss NaN"
    assert float(metrics["ntokens"]) > 0
    # one SGD-flavored step moves the loss (gradient flows end to end)
    grads = jax.jit(jax.grad(lambda p: model.loss_fn(p, batch)[0]))(params)
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch}: degenerate grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init_params(rng)
    batch = _batch(cfg, rng)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    tok = batch["tokens"][:, :1]
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, tok)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_applicable_shapes_policy(arch):
    cfg = get_config(arch)
    shapes = applicable_shapes(cfg)
    assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)
    if cfg.subquadratic:
        assert "long_500k" in shapes     # ssm/hybrid run the 500k cell
    else:
        assert "long_500k" not in shapes  # quadratic archs skip it


def test_param_counts_match_published():
    """Config param formulas vs hand-checked published sizes (±15%)."""
    expected = {
        "qwen2_1_5b": 1.54e9,
        "phi4_mini_3_8b": 3.8e9,
        "codeqwen1_5_7b": 8.2e9,   # from the ASSIGNED config (d_ff=13440, MHA); hf release is 7.25B
        "qwen2_5_32b": 32.5e9,
        "rwkv6_7b": 7.6e9,
        "deepseek_moe_16b": 16.4e9,
        "hymba_1_5b": 1.5e9,
    }
    for arch, n in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.15, (arch, got, n)


def test_moe_active_params():
    cfg = get_config("llama4_maverick_400b_a17b")
    assert cfg.active_param_count() < 0.05 * cfg.param_count()
    ds = get_config("deepseek_moe_16b")
    assert 2e9 < ds.active_param_count() < 4e9   # ~2.8B active (paper)
