"""Roofline analyzer + HLO parser unit tests (canned HLO text — no compile),
including the paper's own FMA-ratio example as the customized-ceiling check."""

import numpy as np
from _prop import given, settings, st

from repro.core import hlo_analysis as H
from repro.core import roofline
from repro.core.hw import TPU_V5E, HardwareSpec

CANNED = """\
HloModule jit_step

%region_1.3 (arg: (s32[], f32[64,64])) -> pred[] {
  %arg = (s32[], f32[64,64]) parameter(0)
  %gte = s32[] get-tuple-element(%arg), index=0
  %constant.7 = s32[] constant(12)
  ROOT %cmp = pred[] compare(%gte, %constant.7), direction=LT
}

%region_0.2 (arg2: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %arg2 = (s32[], f32[64,64]) parameter(0)
  %g0 = s32[] get-tuple-element(%arg2), index=0
  %g1 = f32[64,64]{1,0} get-tuple-element(%arg2), index=1
  %dotx = f32[64,64]{1,0} dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[64,64]{1,0} all-reduce(%dotx), replica_groups={}, to_apply=%adder
  %c1 = s32[] constant(1)
  %next = s32[] add(%g0, %c1)
  ROOT %tup = (s32[], f32[64,64]) tuple(%next, %ar)
}

ENTRY %main.5 (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %tuple = (s32[], f32[64,64]) tuple(%c0, %p0)
  %while.5 = (s32[], f32[64,64]) while(%tuple), condition=%region_1.3, body=%region_0.2
  %ag = f32[64,128]{1,0} all-gather(%p0), dimensions={1}, replica_groups={{0,1}}
  ROOT %out = f32[64,64]{1,0} get-tuple-element(%while.5), index=1
}
"""


def test_module_cost_scales_while_body():
    mc = H.module_cost(CANNED)
    # dot: 2*64*64*64 flops, executed 12 times by the while loop
    assert mc.dot_flops == 12 * 2 * 64 * 64 * 64
    assert 12 in mc.while_trips
    # all-reduce inside the loop: 12 x 64*64*4 bytes; all-gather outside: 1x
    assert mc.collective_bytes_by_kind["all-reduce"] == 12 * 64 * 64 * 4
    assert mc.collective_bytes_by_kind["all-gather"] == 64 * 64 * 4
    assert mc.collective_count_by_kind["all-reduce"] == 12


def test_collect_collectives_flat():
    st_ = H.collect_collectives(CANNED)
    # flat (non-loop-aware) view: one of each
    assert st_.count_by_kind["all-reduce"] == 1
    assert st_.count_by_kind["all-gather"] == 1
    assert st_.bytes_by_kind["all-gather"] == 64 * 64 * 4


def test_parse_def_handles_tuple_comments():
    line = ("  %while.187 = (s32[], bf16[8,128,512]{2,1,0}, "
            "/*index=5*/f32[128,4096]{1,0}) while(%tuple), "
            "condition=%c, body=%b")
    ins = H._parse_def(line)
    assert ins is not None and ins.op == "while"
    dts = [d for d, _ in ins.shapes]
    assert dts == ["s32", "bf16", "f32"]


def test_customized_ceiling_paper_example():
    """The paper: 58% FMA *instruction* ratio => attainable =
    (2*.58+.42)/2 = 79% of peak = 5.3 TFLOP/s on V100. Our MXU/VPU
    formulation reduces to exactly that formula with P_fast = 2 * P_slow
    (FMA = 2 flops/issue vs 1) once the instruction ratio r is converted
    to the flop fraction 2r/(r+1)."""
    hw = HardwareSpec(name="v100-like", mxu_flops=6.7e12, vpu_flops=3.35e12,
                      hbm_bw=900e9, ici_bw=25e9, vmem_bytes=1, hbm_bytes=1)
    total = 100.0
    r = 0.58                                  # instruction ratio (paper)
    fast_flop_fraction = 2 * r / (r + 1)      # flop share done as FMAs
    ceiling = roofline.customized_ceiling(total, total * fast_flop_fraction,
                                          hw)
    expected = (2 * r + (1 - r)) / 2 * 6.7e12    # the paper's 5.3 TFLOP/s
    np.testing.assert_allclose(ceiling, expected, rtol=1e-6)
    np.testing.assert_allclose(expected, 5.3e12, rtol=0.01)


@settings(max_examples=20, deadline=None)
@given(flops=st.floats(1e6, 1e15), nbytes=st.floats(1e3, 1e13),
       coll=st.floats(0, 1e12), mxu=st.floats(0, 1.0))
def test_report_invariants(flops, nbytes, coll, mxu):
    rep = roofline.analyze_counts(
        "t", flops=flops, hbm_bytes=nbytes, collective_bytes=coll,
        mxu_flops=mxu * flops, mesh_shape=(4, 2))
    assert rep.chips == 8
    assert rep.modeled_step_s == max(rep.compute_s, rep.memory_s,
                                     rep.collective_s)
    assert 0 <= rep.roofline_fraction <= 1.0 + 1e-9
    assert rep.dominant in ("compute", "memory", "collective")
    # customized ceiling between VPU and MXU peaks
    assert TPU_V5E.vpu_flops * (1 - 1e-9) <= rep.customized_peak_flops \
        <= TPU_V5E.mxu_flops * (1 + 1e-9)
    # achieved never exceeds the customized ceiling
    ach = rep.flops_per_chip / rep.modeled_step_s
    assert ach <= rep.customized_peak_flops * (1 + 1e-6)


def test_format_table_runs():
    rep = roofline.analyze_counts("cell", flops=1e12, hbm_bytes=1e9,
                                  mesh_shape=(2,))
    md = roofline.format_table([rep])
    assert "cell" in md and "|" in md
