"""Distribution-layer tests. Multi-device cases run in SUBPROCESSES with
XLA_FLAGS forcing 8 host devices — the main pytest process keeps the single
real CPU device (per the dry-run isolation contract)."""

import os
import subprocess
import sys
import textwrap
import types

import pytest
from _prop import given, settings, st

from repro.dist.sharding import ShardingPlan, spec_for

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fake_mesh(**axes):
    return types.SimpleNamespace(shape=dict(axes))


def _plan(fsdp=False, kv=False, **axes):
    return ShardingPlan(mesh=_fake_mesh(**axes), dp_axes=tuple(
        a for a in ("pod", "data") if a in axes), fsdp=fsdp, kv_seq_shard=kv)


# ------------------------------------------------------------ spec_for rules

def test_tp_divisible_shards():
    p = _plan(data=16, model=16)
    spec = spec_for(p, ("d_model", "d_ff"), (1536, 8960))
    assert tuple(spec) == (None, "model")


def test_tp_fallback_replicates():
    """qwen2-1.5b: 12 heads / kv=2 don't divide 16 -> replicated."""
    p = _plan(data=16, model=16)
    assert tuple(spec_for(p, ("d_model", "heads"), (1536, 12 * 128))) \
        == (None, "model")  # 1536 lanes... heads dim = 12*128=1536 divisible!
    # a truly non-divisible dim:
    spec = spec_for(p, ("d_model", "kv_heads"), (1536, 2 * 3))
    assert tuple(spec) in ((), (None,), (None, None))


def test_batch_prefers_all_dp_axes():
    p = _plan(pod=2, data=16, model=16)
    spec = spec_for(p, ("batch", "seq"), (256, 4096), is_param=False)
    assert spec[0] == ("pod", "data")
    # batch=1 can't shard at all
    spec = spec_for(p, ("batch", "seq"), (1, 4096), is_param=False)
    assert tuple(spec) in ((), (None,), (None, None))


def test_kv_seq_shard_takes_model_axis_before_kv_heads():
    p = _plan(data=16, model=16, kv=True)
    spec = spec_for(p, ("layers", "batch", "kv_seq", "kv_heads", None),
                    (48, 128, 32768, 8, 128), is_param=False)
    assert spec[2] == "model"            # seq gets the model axis
    assert len(spec) < 4 or spec[3] is None   # kv_heads falls back


def test_fsdp_adds_dp_axes_to_largest_dim():
    p = _plan(data=16, model=16, fsdp=True)
    spec = spec_for(p, ("d_model", "d_ff"), (5120, 27648))
    # d_ff takes model; fsdp adds data onto the largest dim that divides
    flat = [a for s in spec if s for a in (s if isinstance(s, tuple) else (s,))]
    assert "data" in flat and "model" in flat


@settings(max_examples=40, deadline=None)
@given(
    dm=st.sampled_from([128, 1536, 5120, 6144]),
    dff=st.sampled_from([1408, 8960, 27648, 12345]),
    heads=st.sampled_from([2, 5, 8, 12, 16, 25, 40, 48]),
    fsdp=st.booleans(),
)
def test_spec_never_violates_divisibility(dm, dff, heads, fsdp):
    """Property: every mesh axis assigned to a dim divides that dim, and no
    mesh axis appears twice in one spec."""
    p = _plan(pod=2, data=16, model=16, fsdp=fsdp)
    axes = ("d_model", "d_ff", "heads", "batch")
    shape = (dm, dff, heads * 64, 64)
    spec = spec_for(p, axes, shape, is_param=True)
    used = []
    for i, s in enumerate(spec):
        if s is None:
            continue
        names = s if isinstance(s, tuple) else (s,)
        div = 1
        for a in names:
            assert a not in used, f"axis {a} reused in {spec}"
            used.append(a)
            div *= p.mesh.shape[a]
        assert shape[i] % div == 0, (spec, shape)


# --------------------------------------------------- fault-tolerance pieces

def test_heartbeat_roundtrip(tmp_path):
    from repro.dist.fault import HeartbeatFile
    hb = HeartbeatFile(str(tmp_path))
    assert hb.read() is None and hb.stale(1e9)
    hb.beat(7)
    b = hb.read()
    assert b["step"] == 7
    assert not hb.stale(60.0)
    assert hb.age_s() < 60.0


def test_heartbeat_clear_resets_liveness(tmp_path):
    """clear() hands the identity to a replacement process: the file is
    gone (reads as never-beaten / stale) and clearing twice is a no-op —
    the recovery path in serve/router.py relies on both."""
    from repro.dist.fault import HeartbeatFile
    hb = HeartbeatFile(str(tmp_path))
    hb.beat(5)
    assert hb.read() is not None
    hb.clear()
    assert hb.read() is None and hb.stale(1e9)
    hb.clear()                                 # idempotent on a missing file
    hb.beat(6)                                 # the replacement's first beat
    assert hb.read()["step"] == 6


def test_backoff_ticks_deterministic_exponential():
    """base * 2**(attempt-1), capped — pure integer arithmetic, so the
    router's retry schedule is a function of the attempt count alone."""
    from repro.dist.fault import backoff_ticks
    assert [backoff_ticks(k) for k in (1, 2, 3, 4, 5, 6)] \
        == [1, 2, 4, 8, 16, 32]
    assert [backoff_ticks(k, base=2, cap=12) for k in (1, 2, 3, 4)] \
        == [2, 4, 8, 12]
    assert backoff_ticks(60, base=3, cap=17) == 17   # no overflow blowup
    assert backoff_ticks(1, base=0) == 0             # immediate retry
    with pytest.raises(ValueError):
        backoff_ticks(0)
    with pytest.raises(ValueError):
        backoff_ticks(1, base=-1)


def test_heartbeat_staleness_survives_wall_clock_skew(tmp_path, monkeypatch):
    """NTP can step the wall clock in either direction between a beat and a
    supervisor poll; staleness math must run on CLOCK_MONOTONIC (shared by
    all processes within one boot), with the wall-clock field kept only
    for human-readable logs."""
    import time as _time

    from repro.dist import fault
    from repro.dist.fault import HeartbeatFile
    if fault._boot_id() is None:
        pytest.skip("no boot id: mono is never trusted on this platform")
    hb = HeartbeatFile(str(tmp_path))
    hb.beat(3)
    b = hb.read()
    assert b["step"] == 3 and "time" in b and "mono" in b
    # wall clock jumps 1h BACKWARDS after the beat: still fresh
    monkeypatch.setattr(_time, "time", lambda: b["time"] - 3600.0)
    assert hb.age_s() < 60.0
    assert not hb.stale(60.0)
    # ...and 1h FORWARDS: must not fake staleness either
    monkeypatch.setattr(_time, "time", lambda: b["time"] + 3600.0)
    assert not hb.stale(60.0)


def test_heartbeat_legacy_beat_falls_back_to_wall_clock(tmp_path):
    import json
    import time as _time

    from repro.dist.fault import HeartbeatFile
    hb = HeartbeatFile(str(tmp_path))
    with open(hb.path, "w") as fh:   # beat from an older worker: no "mono"
        json.dump({"step": 1, "time": _time.time() - 10.0}, fh)
    assert 5.0 < hb.age_s() < 60.0
    assert hb.stale(5.0) and not hb.stale(60.0)


def test_heartbeat_cross_boot_mono_falls_back_to_wall_clock(tmp_path):
    """CLOCK_MONOTONIC is per-boot: a beat written on another boot/host
    carries a mono value that is meaningless here (smaller OR larger than
    the reader's — either direction can fake freshness or staleness).
    Only a matching boot id makes mono trustworthy; otherwise staleness
    falls back to wall-clock age."""
    import json
    import time as _time

    from repro.dist.fault import HeartbeatFile
    hb = HeartbeatFile(str(tmp_path))
    # dead worker from a previous boot: huge mono, old wall time, no/other
    # boot id -> wall fallback says stale
    for boot in (None, "some-other-boot"):
        beat = {"step": 1, "time": _time.time() - 600.0,
                "mono": _time.monotonic() + 1e9}
        if boot:
            beat["boot"] = boot
        with open(hb.path, "w") as fh:
            json.dump(beat, fh)
        assert hb.age_s() > 300.0
        assert hb.stale(300.0)
    # live worker on another host (reader's uptime much larger): a naive
    # mono diff would be hugely positive -> must NOT fake staleness
    with open(hb.path, "w") as fh:
        json.dump({"step": 1, "time": _time.time() - 1.0,
                   "mono": _time.monotonic() - 1e9,
                   "boot": "some-other-boot"}, fh)
    assert not hb.stale(300.0)


def test_heartbeat_same_boot_future_mono_clamps_to_wall_clock(tmp_path):
    """Regression: a deserialized/hand-restored beat can carry THIS boot's
    id with a `mono` value ahead of the reader's clock — non-monotonic,
    impossible for a beat this kernel produced. The watchdog used to let
    the wall-clock fallback's max(0, ...) clamp such a beat to age 0
    whenever its wall time was also in the future, making a dead worker
    read fresh FOREVER. It must clamp to the wall-clock fallback path and
    read stale when that clock is untrustworthy too."""
    import json
    import time as _time

    from repro.dist import fault
    from repro.dist.fault import HeartbeatFile
    boot = fault._boot_id()
    if boot is None:
        pytest.skip("no boot id: mono is never trusted on this platform")
    hb = HeartbeatFile(str(tmp_path))
    # future mono, OLD wall time: falls back to the wall clock -> stale
    with open(hb.path, "w") as fh:
        json.dump({"step": 1, "time": _time.time() - 600.0,
                   "mono": _time.monotonic() + 1e6, "boot": boot}, fh)
    assert hb.age_s() > 300.0
    assert hb.stale(300.0)
    # future mono AND future wall time: wholly untrustworthy -> treated
    # as never-beaten (the bug: age clamped to 0.0, fresh forever)
    with open(hb.path, "w") as fh:
        json.dump({"step": 1, "time": _time.time() + 1e6,
                   "mono": _time.monotonic() + 1e6, "boot": boot}, fh)
    assert hb.age_s() is None
    assert hb.stale(300.0)
    # beat missing the wall-time field entirely must not crash the poll
    with open(hb.path, "w") as fh:
        json.dump({"step": 1, "mono": _time.monotonic() + 1e6,
                   "boot": boot}, fh)
    assert hb.age_s() is None and hb.stale(300.0)
    # a healthy beat still reads fresh through the mono path
    hb.beat(2)
    assert hb.age_s() < 60.0 and not hb.stale(60.0)


def test_watchdog_flags_straggler_after_warmup():
    from repro.dist.fault import StepWatchdog
    hits = []
    wd = StepWatchdog(on_straggler=lambda s, dt, ew: hits.append(s),
                      factor=3.0, warmup=3)
    assert not wd.observe(0, 30.0)          # compile step trains the EWMA
    for i in range(1, 6):
        assert not wd.observe(i, 1.0)
    assert wd.observe(6, 50.0)              # 50x the settled baseline
    assert hits == [6] and wd.stragglers[0][0] == 6
    assert not wd.observe(7, 1.0)           # one outlier didn't poison EWMA
    # a sustained slowdown (every step 40s vs ~12s EWMA) alarms at first,
    # then re-baselines instead of alarming forever
    flags = [wd.observe(8 + i, 40.0) for i in range(40)]
    assert flags[0], "sustained slowdown never flagged at all"
    assert not flags[-1], "watchdog never re-baselined"


def test_resume_or_init_fresh_and_resumed(tmp_path):
    from repro.ckpt.checkpoint import CheckpointManager
    from repro.dist.fault import resume_or_init
    import numpy as np
    mgr = CheckpointManager(str(tmp_path))
    step, state = resume_or_init(mgr, lambda: {"w": np.zeros(3)})
    assert step == 0 and state["w"].sum() == 0
    mgr.save(5, {"w": np.ones(3)}, blocking=True)
    step, state = resume_or_init(mgr, lambda: {"w": np.zeros(3)})
    assert step == 5 and state["w"].sum() == 3


def test_bubble_fraction_model():
    from repro.dist.pipeline import bubble_fraction
    assert bubble_fraction(1, 4) == 0.0              # no pipeline, no bubble
    assert abs(bubble_fraction(4, 4) - 3 / 7) < 1e-9
    # more microbatches amortize the fixed fill/drain cost
    assert bubble_fraction(4, 32) < bubble_fraction(4, 8)


def test_batch_and_params_sharding_trees():
    """Tree builders produce NamedSharding leaves (real 1-device mesh; the
    axis-assignment rules themselves are covered by the fakes above)."""
    import jax
    from repro.dist.sharding import (ShardingPlan, batch_shardings,
                                     params_shardings)
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    p = ShardingPlan(mesh=mesh, dp_axes=("pod", "data"))
    bs = batch_shardings(p, {"tokens": types.SimpleNamespace(shape=(256, 4096)),
                             "labels": types.SimpleNamespace(shape=(256, 4096)),
                             "cur_len": types.SimpleNamespace(shape=())})
    assert set(bs) == {"tokens", "labels", "cur_len"}
    assert tuple(bs["tokens"].spec)[0] == ("pod", "data")
    assert tuple(bs["cur_len"].spec) in ((), (None,))  # scalar replicates
    ps = params_shardings(
        p, {"ffn/wi": ("layers", "d_model", "d_ff")},
        {"ffn": {"wi": types.SimpleNamespace(shape=(48, 5120, 27648))},
         "norm": types.SimpleNamespace(shape=(48, 5120))})
    assert tuple(ps["ffn"]["wi"].spec) == (None, None, "model")
    assert tuple(ps["norm"].spec) in ((), (None,), (None, None))


# ------------------------------------------------- multi-device (subprocess)

def _run_sub(code: str):
    src = os.path.join(REPO_ROOT, "src")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=(src + os.pathsep + os.environ["PYTHONPATH"]
                           if os.environ.get("PYTHONPATH") else src))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=560,
                       cwd=REPO_ROOT, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_flash_decode_matches_plain():
    _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.models.attention import flash_decode_sharded, decode_attention
        from repro.models.layers import DistCtx
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = DistCtx(mesh=mesh)
        B,H,KvH,Hd,L = 4, 8, 2, 64, 256
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B,1,H,Hd), jnp.bfloat16)
        k = jax.random.normal(ks[1], (B,L,KvH,Hd), jnp.bfloat16)
        v = jax.random.normal(ks[2], (B,L,KvH,Hd), jnp.bfloat16)
        clen = jnp.int32(200)
        ref = decode_attention(q, k, v, clen)
        with jax.set_mesh(mesh):
            kd = jax.device_put(k, NamedSharding(mesh, P("data","model",None,None)))
            vd = jax.device_put(v, NamedSharding(mesh, P("data","model",None,None)))
            out = jax.jit(lambda q,k,v,c: flash_decode_sharded(q,k,v,c,ctx=ctx))(q,kd,vd,clen)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)-ref.astype(jnp.float32))))
        assert err < 5e-3, err
        print("flash decode ok", err)
    """)


@pytest.mark.slow
def test_int8_allreduce_close_to_exact():
    _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.optim.compress import allreduce_int8, init_residual
        mesh = jax.make_mesh((8,), ("data",))
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 64))}
        res = init_residual(g)
        with jax.set_mesh(mesh):
            gd = jax.device_put(g, {"w": NamedSharding(mesh, P("data", None))})
            # rank-major layout: row i is rank i's gradient
            out, res2 = jax.jit(
                lambda g, r: allreduce_int8(g, r, mesh, ("data",)))(gd, res)
        exact = np.asarray(g["w"]).mean(0)      # mean across ranks
        got = np.asarray(out["w"])              # every rank slot = the mean
        err = np.abs(got - exact[None]).max()
        assert err < 0.05, err
        print("int8 allreduce ok", err)
    """)


@pytest.mark.slow
def test_sharded_train_step_runs():
    """End-to-end: reduced model, debug mesh, 2 jitted sharded train steps
    (params+opt donated), loss finite and changing."""
    _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config, reduce_config
        from repro.models.registry import build_model
        from repro.train import step as step_lib
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = reduce_config(get_config("phi4-mini-3.8b"))
        model = build_model(cfg)
        plan = step_lib.make_plan(cfg, mesh, kind="train")
        bundle, opt = step_lib.build_train_step(model, plan, microbatches=2)
        with jax.set_mesh(mesh):
            params = jax.jit(model.init_params,
                             out_shardings=bundle.in_shardings[0])(
                                 jax.random.PRNGKey(0))
            opt_state = jax.jit(opt.init,
                                out_shardings=bundle.in_shardings[1])(params)
            step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                           out_shardings=bundle.out_shardings,
                           donate_argnums=bundle.donate_argnums)
            B, S = 4, 64
            batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B,S), 0, cfg.vocab_size),
                     "labels": jax.random.randint(jax.random.PRNGKey(1), (B,S), 0, cfg.vocab_size)}
            l0 = None
            for i in range(2):
                params, opt_state, m = step(params, opt_state, batch)
                l = float(m["loss"]); assert np.isfinite(l)
                if l0 is None: l0 = l
        assert l != l0, "params did not update"
        print("sharded train ok", l0, "->", l)
    """)


@pytest.mark.slow
def test_pipeline_parallel_matches_dense():
    _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import pipelined_apply, bubble_fraction
        mesh = jax.make_mesh((4,), ("model",))
        L, M, B, D = 8, 4, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        w = jax.random.normal(ks[0], (L, D, D)) * 0.1
        x = jax.random.normal(ks[1], (M, B, D))
        def layer(p, h):
            return jnp.tanh(h @ p)
        # dense reference
        def dense(x1):
            def body(c, p): return layer(p, c), None
            y, _ = jax.lax.scan(body, x1, w)
            return y
        ref = jax.vmap(dense)(x)
        with jax.set_mesh(mesh):
            out = jax.jit(lambda w, x: pipelined_apply(
                layer, w, x, mesh=mesh, pp_axis="model"))(w, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-4, err
        assert abs(bubble_fraction(4, 4) - 3/7) < 1e-9
        print("pipeline ok", err)
    """)


@pytest.mark.slow
def test_elastic_checkpoint_reshard():
    """Checkpoint written under one mesh restores onto a smaller mesh
    (elastic scaling: 8 -> 4 devices)."""
    _run_sub("""
        import jax, jax.numpy as jnp, numpy as np, tempfile, os
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.ckpt.checkpoint import CheckpointManager
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)
        mesh8 = jax.make_mesh((8,), ("model",))
        w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        with jax.set_mesh(mesh8):
            wd = jax.device_put(w, NamedSharding(mesh8, P("model", None)))
        mgr.save(1, {"w": wd}, blocking=True)
        # "lose half the fleet": restore onto a 4-device mesh
        devs = jax.devices()[:4]
        from jax.sharding import Mesh
        mesh4 = Mesh(np.array(devs), ("model",))
        sh = {"w": NamedSharding(mesh4, P("model", None))}
        step, tree = mgr.restore(shardings=sh)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(w))
        assert len(tree["w"].sharding.device_set) == 4
        print("elastic reshard ok")
    """)
