"""Docs health (fast tier mirror of the CI `docs` job).

Link-checks README.md + docs/*.md via tools/check_docs.py and pins the
README quickstart block to a command that actually exists (the CI job
runs it verbatim; running it here would double the fast tier's wall
time for no extra signal)."""

import importlib.util
import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(REPO_ROOT, "tools", "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_exist():
    for f in ("README.md", "docs/kernels.md", "docs/serving.md",
              "docs/benchmarks.md"):
        assert os.path.exists(os.path.join(REPO_ROOT, f)), f


def test_all_relative_links_resolve():
    cd = _check_docs()
    errors = cd.check_links()
    assert not errors, "\n".join(errors)
    assert len(cd.doc_files()) >= 4


def test_link_checker_catches_breakage(tmp_path):
    """The checker itself must flag a dead link and a dead anchor —
    otherwise a silently-green docs job proves nothing."""
    cd = _check_docs()
    bad = tmp_path / "bad.md"
    bad.write_text("# T\n[a](./does-not-exist.md) [b](#no-such-anchor)\n")
    errors = cd.check_links(files=[str(bad)])
    assert len(errors) == 2
    assert any("broken link" in e for e in errors)
    assert any("broken anchor" in e for e in errors)


def test_public_surface_docstrings():
    """Every lazily-exported name on `import repro` documents itself with
    a real docstring including a runnable example (the satellite
    contract: help(repro.X) answers 'how do I call this')."""
    import repro
    for name in repro.__all__:
        obj = getattr(repro, name)
        doc = obj.__doc__ or ""
        assert len(doc.strip()) > 80, f"repro.{name}: docstring too thin"
        assert "Example" in doc or ">>>" in doc or "::" in doc, \
            f"repro.{name}: docstring has no example"


def test_quickstart_block_is_the_documented_entrypoint():
    cd = _check_docs()
    cmd = cd.quickstart_block()
    assert "examples/quickstart.py" in cmd
    assert "PYTHONPATH=src" in cmd
    script = cmd.split()[-1]
    assert os.path.exists(os.path.join(REPO_ROOT, script))
