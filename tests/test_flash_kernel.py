"""Flash-attention Pallas kernel: shape/dtype sweeps vs the pure-jnp oracle
(ref.py), forward and backward (custom VJP), in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash.flash import flash_attention_bhsd, vmem_bytes
from repro.kernels.flash.ops import flash_attention
from repro.kernels.flash.ref import reference
from repro.models.attention import chunked_causal_attention


def _mk(bh, bkv, s, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (bh, s, hd), dtype)
    k = jax.random.normal(ks[1], (bkv, s, hd), dtype)
    v = jax.random.normal(ks[2], (bkv, s, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("bh,bkv,s,hd", [
    (4, 2, 128, 32),     # GQA group 2
    (2, 2, 64, 64),      # MHA
    (8, 2, 128, 16),     # group 4
])
def test_forward_sweep(bh, bkv, s, hd, dtype):
    q, k, v = _mk(bh, bkv, s, hd, dtype)
    out = flash_attention_bhsd(q, k, v, blk_q=32, blk_kv=32, interpret=True)
    ref = reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=0.03, rtol=0.05)


@pytest.mark.parametrize("blk_q,blk_kv", [(32, 32), (64, 32), (32, 64),
                                          (128, 128)])
def test_block_shape_sweep(blk_q, blk_kv):
    q, k, v = _mk(4, 2, 128, 32, jnp.bfloat16, seed=1)
    out = flash_attention_bhsd(q, k, v, blk_q=blk_q, blk_kv=blk_kv,
                               interpret=True)
    ref = reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=0.03, rtol=0.05)


def test_non_causal():
    q, k, v = _mk(2, 2, 64, 32, jnp.float32, seed=2)
    out = flash_attention_bhsd(q, k, v, blk_q=32, blk_kv=32, causal=False,
                               interpret=True)
    ref = reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_backward_matches_xla():
    B, S, H, KvH, Hd = 2, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, Hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KvH, Hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KvH, Hd), jnp.bfloat16)

    def lf(q, k, v):
        return jnp.sum(flash_attention(q, k, v, blk_q=32, blk_kv=32,
                                       interpret=True).astype(jnp.float32) ** 2)

    def lr(q, k, v):
        return jnp.sum(chunked_causal_attention(
            q, k, v, chunk=32).astype(jnp.float32) ** 2)

    gf = jax.grad(lf, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gf, gr):
        af, bf = np.asarray(a, np.float32), np.asarray(b, np.float32)
        scale = np.abs(bf).max() + 1e-6
        assert np.abs(af - bf).max() / scale < 0.06, name


def test_vmem_budget():
    from repro.core.hw import TPU_V5E
    # the default 256x256 blocks at head_dim 128 must fit VMEM
    assert vmem_bytes(256, 256, 128) < TPU_V5E.vmem_bytes
