"""GPP kernel correctness: every journey variant + the Pallas kernel
(interpret mode) against the complex128 numpy oracle, across shape sweeps,
plus hypothesis property tests on the kernel's algebraic invariants."""

import dataclasses

import jax
import numpy as np
import pytest
from _prop import given, settings, st

from repro.kernels.gpp import ops, pallas_gpp, problem, ref, variants

RTOL = 5e-5


def _run_ref(inp):
    return ref.ref_numpy(inp)


def _rel(a, b):
    return float(np.max(np.abs(np.asarray(a) - b)) / np.max(np.abs(b)))


SIZES = [
    problem.GppSize("s1", nbands=8, ngpown=8, ncouls=64),
    problem.GppSize("s2", nbands=16, ngpown=4, ncouls=128),
    problem.GppSize("s3", nbands=4, ngpown=16, ncouls=32),
]


@pytest.mark.parametrize("size", SIZES, ids=lambda s: s.name)
@pytest.mark.parametrize("version", list(variants.VARIANTS))
def test_variants_match_oracle(size, version):
    inp = problem.make_inputs(size, seed=1)
    ach, asx = _run_ref(inp)
    a, x = jax.jit(variants.VARIANTS[version])(inp)
    assert _rel(a, ach) < RTOL, version
    assert _rel(x, asx) < RTOL, version


@pytest.mark.parametrize("size", SIZES, ids=lambda s: s.name)
@pytest.mark.parametrize("version", ["v6", "v7", "v8", "v9"])
def test_pallas_matches_oracle(size, version):
    cfg = pallas_gpp.CONFIGS[version]
    cfg = dataclasses.replace(
        cfg,
        blk_ig=min(cfg.blk_ig, size.ncouls),
        blk_igp=min(cfg.blk_igp, size.ngpown),
        blk_band=min(cfg.blk_band, size.nbands))
    inp = problem.make_inputs(size, seed=2)
    ach, asx = _run_ref(inp)
    a, x = pallas_gpp.gpp_pallas(inp, cfg, interpret=True)
    assert _rel(a, ach) < RTOL
    assert _rel(x, asx) < RTOL


def test_pallas_block_shape_sweep():
    size = problem.GppSize("sw", nbands=16, ngpown=16, ncouls=64)
    inp = problem.make_inputs(size, seed=3)
    ach, asx = _run_ref(inp)
    # (aqsm_transposed, fused_acc): fused always rides the v7+ layout
    for blk_ig in (16, 32, 64):
        for blk_igp in (4, 16):
            for blk_band in (4, 8, 16):
                for tr, fused in ((False, False), (True, False),
                                  (True, True)):
                    cfg = pallas_gpp.BlockConfig(
                        "t", blk_ig, blk_igp, blk_band, tr, fused_acc=fused)
                    a, x = pallas_gpp.gpp_pallas(inp, cfg, interpret=True)
                    assert _rel(a, ach) < RTOL, cfg
                    assert _rel(x, asx) < RTOL, cfg


def test_ops_dispatch():
    inp = problem.make_inputs(problem.TINY)
    ach, asx = _run_ref(inp)
    for v in ("v0", "v5"):
        a, x = ops.gpp(inp, version=v)
        assert _rel(a, ach) < RTOL
    cfg = dataclasses.replace(pallas_gpp.V8, blk_ig=32, blk_igp=4, blk_band=4)
    a, x = ops.gpp(inp, version="v8", block_config=cfg, interpret=True)
    assert _rel(a, ach) < RTOL
    # static Pallas versions auto-clamp their blocks to small problems
    a, x = ops.gpp(inp, version="v9")
    assert _rel(a, ach) < RTOL
    with pytest.raises(ValueError):
        ops.gpp(inp, version="v99")


def test_jitted_variant_cached():
    """gpp() must reuse one jitted callable per version (the per-call
    re-jit rebuilt the dispatch wrapper every time)."""
    assert ops.jitted_variant("v5") is ops.jitted_variant("v5")


# ---------------------------------------------------------------------------
# property tests (hypothesis): algebraic invariants of the contraction
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), alpha=st.floats(0.25, 4.0))
def test_linearity_in_aqsn(seed, alpha):
    """out is linear in aqsn: scaling aqsn scales both outputs by alpha."""
    inp = problem.make_inputs(problem.TINY, seed=seed)
    a0, x0 = jax.jit(variants.v5)(inp)
    inp2 = dict(inp)
    inp2["aqsn_re"] = inp["aqsn_re"] * alpha
    inp2["aqsn_im"] = inp["aqsn_im"] * alpha
    a1, x1 = jax.jit(variants.v5)(inp2)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a0) * alpha,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x0) * alpha,
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_ig_permutation_invariance(seed):
    """The reduction over ig is permutation invariant (all ig-indexed
    arrays permuted consistently)."""
    rng = np.random.default_rng(seed)
    inp = problem.make_inputs(problem.TINY, seed=seed)
    perm = rng.permutation(problem.TINY.ncouls)
    inp2 = dict(inp)
    for k in ("wtilde_re", "wtilde_im", "eps_re", "eps_im",
              "aqsn_re", "aqsn_im", "vcoul"):
        inp2[k] = inp[k][perm]
    a0, x0 = jax.jit(variants.v5)(inp)
    a1, x1 = jax.jit(variants.v5)(inp2)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a0),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_band_additivity(seed):
    """Splitting the band axis and summing the two halves' outputs equals
    the full reduction (additivity of the band sum)."""
    inp = problem.make_inputs(problem.TINY, seed=seed)
    nb = problem.TINY.nbands
    half = nb // 2

    def slice_bands(lo, hi):
        out = dict(inp)
        out["aqsn_re"] = inp["aqsn_re"][:, lo:hi]
        out["aqsn_im"] = inp["aqsn_im"][:, lo:hi]
        out["aqsm_re"] = inp["aqsm_re"][:, lo:hi]
        out["aqsm_im"] = inp["aqsm_im"][:, lo:hi]
        out["wx"] = inp["wx"][:, lo:hi]
        return out

    a, x = jax.jit(variants.v5)(inp)
    a1, x1 = jax.jit(variants.v5)(slice_bands(0, half))
    a2, x2 = jax.jit(variants.v5)(slice_bands(half, nb))
    np.testing.assert_allclose(np.asarray(a1 + a2), np.asarray(a),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(x1 + x2), np.asarray(x),
                               rtol=1e-4, atol=1e-5)


def test_f32_error_budget_vs_complex128():
    """DESIGN.md's precision claim: planar f32 within 1e-4 relative of the
    complex128 oracle at BENCH size."""
    inp = problem.make_inputs(problem.BENCH, seed=0)
    ach, asx = _run_ref(inp)
    a, x = jax.jit(variants.v5)(inp)
    assert _rel(a, ach) < 1e-4
    assert _rel(x, asx) < 1e-4
