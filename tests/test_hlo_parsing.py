"""Regression fixtures for the `repro.analyze.hlo` parsing layer.

Canned `compiled.as_text()` excerpts covering the spellings XLA actually
emits that naive regexes drop:

  * classic `%name = shape op(%a, %b)` lines;
  * post-SPMD bare spellings (`name = f32[8]{0} add(a, b)`) — no `%`
    anywhere, operands recovered from top-level commas;
  * tuple result shapes with `/*index=N*/` comments (which contain `=`
    and break split-on-`=` parsers);
  * bounded-dynamic dims (`f32[<=8,4]`) counted at the bound.

Plus the back-compat contract: `repro.core.hlo_analysis` re-exports the
whole surface (the serve/dist bench paths import it from there).
"""

from repro.analyze import hlo

# classic spelling: %-prefixed names, tuple-shaped result with /*index=N*/
CLASSIC = """
HloModule m

ENTRY %main (p0: f32[128,256], p1: f32[256,64]) -> (f32[128,64], f32[128]) {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = f32[256,64]{1,0} parameter(1)
  %dot.1 = f32[128,64]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[128,64]{1,0} all-gather(%dot.1), replica_groups={{0,1}}, dimensions={0}
  %red = f32[128]{0} reduce(%dot.1, %c0), dimensions={1}, to_apply=%sum
  ROOT %tup = (f32[128,64]{1,0} /*index=0*/, f32[128]{0} /*index=1*/) tuple(%ag, %red)
}
"""

# post-SPMD spelling: bare names everywhere, literal operands mixed in
BARE = """
HloModule spmd_m

ENTRY main (p0: f32[128,256], p1: f32[256,64]) -> f32[128,64] {
  p0 = f32[128,256]{1,0} parameter(0)
  p1 = f32[256,64]{1,0} parameter(1)
  dot.1 = f32[128,64]{1,0} dot(p0, p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  c1 = f32[] constant(2.5)
  b1 = f32[128,64]{1,0} broadcast(c1), dimensions={}
  scaled = f32[128,64]{1,0} multiply(dot.1, b1)
  ars = f32[128,64]{1,0} all-reduce-start(scaled), to_apply=add_comp
  ROOT ard = f32[128,64]{1,0} all-reduce-done(ars)
}
"""

# bounded-dynamic dims from a padded/donated serving module
BOUNDED = """
ENTRY main (p0: f32[<=8,4]) -> f32[<=8,4] {
  p0 = f32[<=8,4]{1,0} parameter(0)
  ROOT neg = f32[<=8,4]{1,0} negate(p0)
}
"""


def test_classic_def_and_operands():
    instrs = hlo._parse_instructions(CLASSIC)
    by_name = {i.name: i for i in instrs}
    assert by_name["dot.1"].operands == ["p0", "p1"]
    # tuple result with /*index=N*/ comments: both member shapes parsed
    tup = by_name["tup"]
    assert tup.op == "tuple"
    assert ("f32", [128, 64]) in tup.shapes and ("f32", [128]) in tup.shapes
    assert tup.operands == ["ag", "red"]


def test_bare_name_defs_not_dropped():
    """Post-SPMD dumps print `name = ...` without `%` — every instruction
    must still parse, with operands recovered from the call body."""
    instrs = hlo._parse_instructions(BARE)
    by_name = {i.name: i for i in instrs}
    assert set(by_name) == {"p0", "p1", "dot.1", "c1", "b1", "scaled",
                            "ars", "ard"}
    assert by_name["dot.1"].operands == ["p0", "p1"]
    assert by_name["scaled"].operands == ["dot.1", "b1"]
    # constant(2.5): the literal is not an operand name
    assert by_name["c1"].operands == []


def test_dot_flops_same_both_spellings():
    want = 2.0 * 128 * 64 * 256
    assert hlo.collect_dot_flops(CLASSIC) == want
    assert hlo.collect_dot_flops(BARE) == want


def test_collectives_both_spellings():
    c = hlo.collect_collectives(CLASSIC)
    assert c.count_by_kind == {"all-gather": 1}
    assert c.bytes_by_kind["all-gather"] == 128 * 64 * 4
    b = hlo.collect_collectives(BARE)
    # async start/done pair counts once, on the start half
    assert b.count_by_kind == {"all-reduce": 1}
    assert b.bytes_by_kind["all-reduce"] == 128 * 64 * 4


def test_bounded_dynamic_dims_count_at_bound():
    instrs = hlo._parse_instructions(BOUNDED)
    by_name = {i.name: i for i in instrs}
    assert by_name["neg"].shapes == [("f32", [8, 4])]
    assert hlo._shape_list_bytes(by_name["neg"].shapes) == 8 * 4 * 4


def test_census_bare_spelling():
    cen = hlo.census(BARE)
    assert cen.op_counts["dot"] == 1
    assert cen.op_counts["multiply"] == 1


def test_core_shim_reexports():
    """serve/dist/roofline keep importing from repro.core.hlo_analysis —
    the shim must expose the same objects (not copies)."""
    from repro.core import hlo_analysis as shim
    for name in hlo.__all__:
        assert getattr(shim, name) is getattr(hlo, name), name
    # private helpers some callers/tests reach for are re-exported too
    assert shim._parse_instructions is hlo._parse_instructions
    assert shim._split_computations is hlo._split_computations
