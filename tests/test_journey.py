"""GPP journey coverage: every optimization step stays correct against the
complex128 oracle, the modeled-throughput trajectory moves the right way,
and the v8 block sweep only proposes feasible configs.

Complements tests/test_system.py::test_journey_trajectory (which checks the
paper's Table-I shape); this file pins per-version numerics and the sweep's
feasibility invariants."""

import dataclasses

import numpy as np
import pytest

from repro.core.hw import TPU_V5E
from repro.core.journey import (OP_MIX, _model_report, run_journey,
                                sweep_blocks)
from repro.kernels.gpp import pallas_gpp, problem, ref, variants

ORDER = ["v0", "v1", "v2", "v3", "v4", "v5", "v6", "v7", "v8", "v9", "v10"]
PAPER_ORDER = ORDER[:9]                       # the paper stops at v8

# per-version oracle tolerance at TINY: planar-f32 arithmetic vs complex128.
# The reciprocal rewrite (v1+) and the Pallas accumulation order (v6+) each
# cost a little precision; all stay comfortably inside the 1e-5 budget the
# system test enforces. v9/v10 share v8's arithmetic (fused accumulation
# changes where partial sums live, not their order within a block).
TOL = {"v0": 1e-6, "v1": 1e-6, "v2": 1e-6, "v3": 1e-6,
       "v4": 2e-6, "v5": 2e-6, "v6": 2e-6, "v7": 2e-6, "v8": 2e-6,
       "v9": 2e-6, "v10": 2e-6}


def _rel_err(got, want):
    return float(np.max(np.abs(np.asarray(got) - want)) / np.max(np.abs(want)))


@pytest.mark.parametrize("version", ORDER)
def test_every_version_matches_oracle_at_tiny(version):
    inputs = problem.make_inputs(problem.TINY)
    ar, xr = ref.ref_numpy(inputs)
    if version not in variants.VARIANTS:
        cfg = dataclasses.replace(pallas_gpp.CONFIGS.get(version,
                                                         pallas_gpp.V9),
                                  blk_ig=32, blk_igp=4, blk_band=4)
        a, x = pallas_gpp.gpp_pallas(inputs, cfg, interpret=True)
    else:
        a, x = variants.VARIANTS[version](inputs)
    err = max(_rel_err(a, ar), _rel_err(x, xr))
    assert err < TOL[version], (version, err)


def test_modeled_tflops_non_decreasing_within_tolerance():
    """The trajectory climbs: each step's modeled TFLOP/s is no worse than
    97% of the previous step's. The only dips are the documented ones —
    v2's select-for-branch trade and v6's lane-misaligned aqsm layout (the
    journey's deliberate regression, recovered by v7/v8) — and both stay
    within the 3% band. End to end the gain must be real."""
    rows = run_journey("si214", measure_cpu=False, verbose=False)
    byv = {r.version: r for r in rows}
    tf = [byv[v].modeled_tflops for v in ORDER]
    for a, b, va, vb in zip(tf, tf[1:], ORDER, ORDER[1:]):
        assert b >= a * 0.97, (f"{vb} ({b:.3f} TF/s) regressed >3% vs "
                               f"{va} ({a:.3f} TF/s)")
    assert tf[-1] > tf[0] * 1.2          # headline: v10 >= 1.2x v0
    # within the paper's steps the peak is v5 (the Pallas steps pay grid
    # overhead for exact traffic); the beyond-paper fused/tuned steps must
    # take the overall lead
    paper_tf = tf[:len(PAPER_ORDER)]
    assert max(paper_tf) == pytest.approx(tf[ORDER.index("v5")], rel=0.01)
    assert max(tf) == tf[-1]             # v10 leads end-to-end
    assert byv["v9"].modeled_tflops >= byv["v8"].modeled_tflops
    assert byv["v10"].modeled_tflops >= byv["v9"].modeled_tflops


def test_sweep_configs_feasible_and_sorted():
    size = problem.SIZES["si214"]
    rows = sweep_blocks("si214")
    assert rows, "sweep returned no configs"
    times = [r["modeled_s"] for r in rows]
    assert times == sorted(times), "sweep not sorted by modeled time"
    for r in rows:
        # VMEM-feasible
        assert r["vmem_mib"] * 2 ** 20 <= TPU_V5E.vmem_bytes, r
        # divisibility-respecting: blocks tile the problem exactly
        assert size.ncouls % r["blk_ig"] == 0, r
        assert size.ngpown % r["blk_igp"] == 0, r
        assert size.nbands % r["blk_band"] == 0, r
        assert r["instances"] == ((size.ncouls // r["blk_ig"])
                                  * (size.ngpown // r["blk_igp"])
                                  * (size.nbands // r["blk_band"]))


def test_v8_config_at_or_near_sweep_top():
    """The shipped v8 block config must model within 5% of the sweep's
    best time (block-size tuning is the whole point of step 8)."""
    rows = sweep_blocks("si214")
    best = rows[0]["modeled_s"]
    v8 = _model_report("v8", problem.SIZES["si214"])
    assert v8.modeled_step_s <= best * 1.05, (v8.modeled_step_s, best)


def test_op_mix_census_consistent():
    """Pass counts never increase along the journey, and the flop census
    is stable from v3 on (memory/layout steps don't change arithmetic)."""
    passes = [OP_MIX[v].passes for v in ORDER]
    assert all(a >= b for a, b in zip(passes, passes[1:])), passes
    flops = {OP_MIX[v].flops for v in ORDER[3:]}
    assert len(flops) <= 2, flops
