"""Unified kernel registry (repro.kernels.api): registration + dispatch for
all three families, the generalized (kernel, ProblemKey, backend, version)
tune cache, shared backend policy (REPRO_INTERPRET), the deprecation shims'
bit-identical forwarding, the lazy `import repro` surface, and the bench
artifact's config-provenance (config-churn) channel."""

import json
import os
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import backend
from repro.kernels import api
from repro.kernels.flash import ops as flash_ops
from repro.kernels.flash.kernel_def import FlashBlockConfig, FlashKey
from repro.kernels.gpp import ops as gpp_ops
from repro.kernels.gpp import problem, ref
from repro.kernels.ssm import ops as ssm_ops
from repro.kernels.ssm.kernel_def import SsmKey, SsmScanConfig
from repro.tune import tuner

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _flash_inputs(seed=0, b=2, s=64, h=4, kvh=2, hd=16, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kvh, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kvh, hd), dtype)
    return q, k, v


def _ssm_inputs(key=SsmKey(b=2, t=32, c=8, n=4), seed=0):
    return api.get_kernel("ssm").make_example(key, seed=seed)[0]


# ---------------------------------------------------------------------------
# registry + dispatch
# ---------------------------------------------------------------------------

def test_all_families_registered():
    assert {"gpp", "flash", "ssm"} <= set(api.list_kernels())
    for name in api.list_kernels():
        k = api.get_kernel(name)
        assert k.default_version in k.versions
        assert set(k.tunable) <= set(k.versions)


def test_unknown_kernel_and_version():
    with pytest.raises(KeyError):
        api.get_kernel("nope")
    inp = problem.make_inputs(problem.TINY)
    with pytest.raises(ValueError):
        api.dispatch("gpp", inp, version="v99")


def test_dispatch_each_kernel_matches_reference():
    """Every registered family dispatches at TINY size on CPU interpret and
    agrees with its reference implementation (the CI registry-smoke
    contract)."""
    # gpp: default (tuned v10) vs complex128 oracle
    inp = problem.make_inputs(problem.TINY)
    ar, xr = ref.ref_numpy(inp)
    a, x = api.dispatch("gpp", inp)
    assert float(np.max(np.abs(np.asarray(a) - ar))
                 / np.max(np.abs(ar))) < 1e-5
    # flash: pallas vs exact-softmax ref
    q, k, v = _flash_inputs()
    out = api.dispatch("flash", q, k, v)
    out_ref = api.dispatch("flash", q, k, v, version="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=1e-4, rtol=1e-4)
    # ssm: pallas (tuned blk_c) vs sequential-scan ref
    args = _ssm_inputs()
    y, hT = api.dispatch("ssm", *args)
    y_ref, hT_ref = api.dispatch("ssm", *args, version="ref")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref),
                               atol=1e-4, rtol=1e-4)


def test_ssm_chunked_version_matches_ref():
    args = _ssm_inputs()
    y_c, h_c = api.dispatch("ssm", *args, version="chunked")
    y_r, h_r = api.dispatch("ssm", *args, version="ref")
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               atol=1e-4, rtol=1e-4)


def test_problem_keys_are_stable():
    q, k, v = _flash_inputs()
    fk = api.get_kernel("flash").problem_key(q, k, v, causal=True)
    assert fk == FlashKey(b=2, h=4, kvh=2, sq=64, skv=64, hd=16, causal=True)
    assert fk.key_dims() == "2x4x2x64x64x16c"
    sk = api.get_kernel("ssm").problem_key(*_ssm_inputs())
    assert sk.key_dims() == "2x32x8x4"
    assert problem.TINY.key_dims() == "64x8x8x2"


# ---------------------------------------------------------------------------
# generalized tune cache
# ---------------------------------------------------------------------------

def test_flash_and_ssm_tune_through_generalized_cache(tmp_path):
    """Acceptance: flash and ssm each get a tuned config through the
    generalized repro.tune cache, keyed (kernel, ProblemKey, backend,
    version), and a fresh process state reloads it from disk."""
    cache = str(tmp_path / "tune")
    tuner.clear_memo()
    fkey = FlashKey(b=2, h=4, kvh=2, sq=64, skv=64, hd=16)
    skey = SsmKey(b=2, t=32, c=8, n=4)
    tcs = {
        "flash": tuner.tune_kernel("flash", fkey, cache_dir=cache,
                                   measure_mode=False),
        "ssm": tuner.tune_kernel("ssm", skey, cache_dir=cache,
                                 measure_mode=False),
    }
    assert isinstance(tcs["flash"].config, FlashBlockConfig)
    assert isinstance(tcs["ssm"].config, SsmScanConfig)
    # key format: kernel|dims|backend|version
    assert tcs["flash"].key == "flash|2x4x2x64x64x16c|cpu|pallas"
    assert tcs["ssm"].key == "ssm|2x32x8x4|cpu|pallas"
    on_disk = json.load(open(os.path.join(cache, tuner.CACHE_FILE)))
    assert set(on_disk) == {tcs["flash"].key, tcs["ssm"].key}

    # fresh process state -> disk hit, config reconstructed per kernel
    tuner.clear_memo()
    for kernel, key in (("flash", fkey), ("ssm", skey)):
        tc2 = tuner.tune_kernel(kernel, key, cache_dir=cache)
        assert tc2.source == "cache"
        assert tc2.config == tcs[kernel].config
        assert tc2.kernel == kernel


def test_gpp_and_flash_keys_do_not_collide(tmp_path):
    """The kernel name is part of the key — same dims under two kernels
    stay distinct cache entries."""
    cache = str(tmp_path / "tune")
    tuner.clear_memo()
    tc_g = tuner.tune(problem.TINY, cache_dir=cache, measure_mode=False)
    assert tc_g.key.startswith("gpp|")
    tc_f = tuner.tune_kernel(
        "flash", FlashKey(b=2, h=4, kvh=2, sq=64, skv=64, hd=16),
        cache_dir=cache, measure_mode=False)
    on_disk = json.load(open(os.path.join(cache, tuner.CACHE_FILE)))
    assert tc_g.key in on_disk and tc_f.key in on_disk


def test_tuned_config_feasible_for_every_kernel():
    """rank_kernel's winners tile the problem exactly and fit VMEM."""
    from repro.core.hw import TPU_V5E
    fkey = FlashKey(b=8, h=16, kvh=4, sq=4096, skv=4096, hd=128)
    cfg, _ = tuner.rank_kernel("flash", fkey)[0]
    assert fkey.sq % cfg.blk_q == 0 and fkey.skv % cfg.blk_kv == 0
    assert cfg.vmem_bytes(fkey.hd) <= TPU_V5E.vmem_bytes
    skey = SsmKey(b=16, t=4096, c=6400, n=16)
    scfg, _ = tuner.rank_kernel("ssm", skey)[0]
    assert skey.c % scfg.blk_c == 0
    assert scfg.vmem_bytes(skey) <= TPU_V5E.vmem_bytes


# ---------------------------------------------------------------------------
# backend policy (REPRO_INTERPRET)
# ---------------------------------------------------------------------------

def test_backend_interpret_env_override(monkeypatch):
    monkeypatch.delenv(backend.INTERPRET_ENV, raising=False)
    assert backend.default_interpret() is True       # CPU container
    monkeypatch.setenv(backend.INTERPRET_ENV, "1")
    assert backend.default_interpret() is True
    monkeypatch.setenv(backend.INTERPRET_ENV, "0")
    assert backend.default_interpret() is False
    assert backend.resolve_interpret(None) is False  # env wins over default
    assert backend.resolve_interpret(True) is True   # explicit wins over env
    monkeypatch.setenv(backend.INTERPRET_ENV, "maybe")
    with pytest.raises(ValueError):
        backend.default_interpret()


def test_on_tpu_false_on_cpu():
    assert backend.on_tpu() is False
    assert backend.backend_name() == "cpu"


# ---------------------------------------------------------------------------
# deprecation shims: bit-identical + a single warning
# ---------------------------------------------------------------------------

def test_gpp_shim_bit_identical_across_versions():
    inp = problem.make_inputs(problem.TINY)
    for v in ("v0", "v1", "v2", "v3", "v4", "v5", "v6", "v7", "v8", "v9",
              "v10"):
        a_old, x_old = gpp_ops.gpp(inp, version=v)
        a_new, x_new = api.dispatch("gpp", inp, version=v)
        assert np.array_equal(np.asarray(a_old), np.asarray(a_new)), v
        assert np.array_equal(np.asarray(x_old), np.asarray(x_new)), v


def test_flash_shim_bit_identical():
    q, k, v = _flash_inputs(seed=7)
    old = flash_ops.flash_attention(q, k, v, blk_q=32, blk_kv=32)
    new = api.dispatch("flash", q, k, v,
                       config=FlashBlockConfig("x", 32, 32))
    assert np.array_equal(np.asarray(old), np.asarray(new))
    # the shim's frozen default (256/256, clamped) == explicit legacy config
    old_def = flash_ops.flash_attention(q, k, v)
    new_def = api.dispatch("flash", q, k, v,
                           config=FlashBlockConfig("x", 256, 256))
    assert np.array_equal(np.asarray(old_def), np.asarray(new_def))


@pytest.mark.parametrize("call", [
    lambda: gpp_ops.gpp(problem.make_inputs(problem.TINY), version="v5"),
    lambda: flash_ops.flash_attention(*_flash_inputs(), blk_q=32, blk_kv=32),
], ids=["gpp", "flash"])
def test_shims_warn_exactly_once(call, monkeypatch):
    import repro.kernels as kernels_pkg
    monkeypatch.setattr(kernels_pkg, "_WARNED", set())
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        call()
        call()
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
           and "deprecated" in str(w.message)]
    assert len(dep) == 1, [str(w.message) for w in rec]


def test_dispatch_odd_shapes_fall_back_to_clamped_static():
    """Shapes the power-of-two tune menu can't tile (empty config space)
    must still dispatch via the clamped static config — the legacy entry
    points handled e.g. sq=48 or c=6 by clamping, and dispatch must not
    regress that."""
    # s=48: the clamp (min) happens to tile; s=300: nothing in the menu
    # divides it and a plain min() clamp (256) would silently NaN the tail
    # rows — the divisor clamp must pick a tiling block instead
    for s in (48, 300):
        q, k, v = _flash_inputs(s=s)
        out = api.dispatch("flash", q, k, v)
        out_ref = api.dispatch("flash", q, k, v, version="ref")
        assert not np.any(np.isnan(np.asarray(out))), s
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                                   atol=1e-4, rtol=1e-4)
    # c=6: clamp (min) tiles; c=130: 128 doesn't divide it — the divisor
    # clamp must pick a tiling block instead of tripping the kernel assert
    for c in (6, 130):
        args = _ssm_inputs(SsmKey(b=2, t=16, c=c, n=4))
        y, hT = api.dispatch("ssm", *args)
        y_ref, _ = api.dispatch("ssm", *args, version="ref")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)


def test_dispatch_rejects_stray_kwargs():
    """A misspelled or legacy kwarg must raise, not be silently swallowed
    (e.g. the old flash signature's blk_q, or causal typoed as casual)."""
    q, k, v = _flash_inputs()
    with pytest.raises(TypeError):
        api.dispatch("flash", q, k, v, blk_q=32, blk_kv=32)
    with pytest.raises(TypeError):
        api.dispatch("flash", q, k, v, casual=False)
    with pytest.raises(TypeError):
        api.dispatch("gpp", problem.make_inputs(problem.TINY), blk_ig=32)


def test_ssm_ops_is_not_deprecated():
    """The new ssm op layer is a first-class wrapper, no warning."""
    args = _ssm_inputs()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ssm_ops.ssm_scan(*args, version="ref")
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)]


# ---------------------------------------------------------------------------
# lazy public surface
# ---------------------------------------------------------------------------

def test_repro_public_surface():
    assert set(repro.__all__) >= {"get_kernel", "dispatch", "list_kernels",
                                  "ServeEngine", "build_model",
                                  "run_journey"}
    assert repro.dispatch is api.dispatch
    assert repro.get_kernel is api.get_kernel
    from repro.serve.engine import ServeEngine
    assert repro.ServeEngine is ServeEngine
    with pytest.raises(AttributeError):
        repro.not_a_symbol


# ---------------------------------------------------------------------------
# bench artifact: config provenance + churn notes
# ---------------------------------------------------------------------------

def test_artifact_kernel_config_and_churn_note(tmp_path):
    sys.path.insert(0, ROOT)
    from benchmarks import report
    kc_old = {"kernel": "flash", "version": "pallas",
              "config": {"name": "pallas", "blk_q": 256, "blk_kv": 256},
              "source": "model"}
    kc_new = dict(kc_old, config={"name": "pallas", "blk_q": 512,
                                  "blk_kv": 128}, source="cache")
    old = [{"name": "tuned_flash", "us_per_call": None,
            "derived": "modeled_s=1.0", "kernel_config": kc_old}]
    new = [{"name": "tuned_flash", "us_per_call": None,
            "derived": "modeled_s=1.0", "kernel_config": kc_new}]
    art_old = report.make_artifact(old)
    assert art_old["rows"][0]["kernel_config"] == kc_old
    regs, imps, notes = report.compare(art_old, report.make_artifact(new))
    assert not regs and not imps
    assert any("config churn" in n and "tuned_flash" in n for n in notes)
    # identical configs -> no churn note
    _, _, notes2 = report.compare(art_old, report.make_artifact(old))
    assert not any("config churn" in n for n in notes2)
