"""Paged K/V cache tier: pool accounting, prefix reuse, the bit-exactness
contract, the int8 quantized route, and the paged_decode kernel family
(docs/serving.md §Paged K/V cache).

The load-bearing claims:

  * bf16 paged serving is BIT-EXACT against the static-cache engine for
    every model family — paging is a storage/sharing layer, never a
    numerics change;
  * page accounting conserves: pages_allocated == pages_freed + live on
    every terminal path (finish, evict, router fence/recover), and the
    FIFO free list makes identical runs allocate identical page ids;
  * prefix reuse actually skips prefill (hits > 0, tokens saved) while
    staying bit-exact at temperature 0;
  * the int8 pool's error is bounded and pinned (per-page symmetric
    scales), and the paged_decode kernel's int8 route stays within it;
  * the auditor's KV001 rule catches a paged kernel whose VMEM model
    forgets its gather buffers.
"""

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduce_config
from repro.kernels import api
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvcache import (PagedKVCache, dequantize_page,
                                 quantize_page)
from repro.serve.router import Router
from repro.serve.trace import TraceConfig, generate_trace

VOCAB = 128

# shared-system-prompt workload at temperature 0: the bit-exactness runs
# compare token lists, so greedy sampling keeps the claim about caching,
# not sampling luck
PREFIX_TRACE = TraceConfig(
    n_requests=12, rate_rps=16.0, prompt_median=6, prompt_sigma=0.6,
    prompt_max=16, out_median=6, out_sigma=0.6, out_max=12,
    temperatures=(0.0,), vocab=VOCAB, seed=3,
    shared_prefix_frac=0.8, prefix_pool=2, prefix_len=16)


def small_cfg(arch="qwen2-1.5b"):
    return reduce_config(get_config(arch), layers=2, d_model=64, vocab=VOCAB)


@pytest.fixture(scope="module")
def dense():
    cfg = small_cfg()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# pool accounting
# ---------------------------------------------------------------------------

def test_alloc_free_conservation_and_fifo():
    kv = PagedKVCache(small_cfg(), max_batch=2, cache_len=32, page_size=8,
                      prefix_reuse=False)
    p1 = np.arange(10, dtype=np.int32)
    p2 = np.arange(20, dtype=np.int32)
    kv.admit(0, p1, len(p1), 4)          # ceil(10/8) = 2 pages
    kv.admit(1, p2, len(p2), 4)          # ceil(20/8) = 3 pages
    assert kv.pages_live == 5
    kv.check_conservation()
    kv.release(0)
    assert kv.pages_live == 3 and kv.pages_freed == 2
    kv.check_conservation()
    # FIFO determinism: freed pages go to the back; a fresh admit takes
    # the oldest never-used ids first, so identical runs allocate
    # identical pages
    kv2 = PagedKVCache(small_cfg(), max_batch=2, cache_len=32, page_size=8,
                       prefix_reuse=False)
    kv2.admit(0, p1, len(p1), 4)
    assert kv2._tables[0].pages() == [0, 1]
    kv2.release(0)
    kv2.admit(1, p2, len(p2), 4)
    assert kv2._tables[1].pages() == [2, 3, 4]   # not the recycled 0/1


def test_release_is_exactly_once_and_grow_allocates():
    kv = PagedKVCache(small_cfg(), max_batch=2, cache_len=32, page_size=4,
                      prefix_reuse=False)
    p = np.arange(6, dtype=np.int32)
    kv.admit(0, p, len(p), 8)            # 2 pages cover 6 tokens
    assert kv.pages_live == 2
    kv.grow(0, 8)                        # still inside page 2
    assert kv.pages_live == 2
    kv.grow(0, 9)                        # crosses into page 3
    assert kv.pages_live == 3
    kv.release(0)
    kv.check_conservation()
    with pytest.raises(AssertionError):  # double release must be loud
        kv.release(0)


def test_pool_exhaustion_raises():
    kv = PagedKVCache(small_cfg(), max_batch=1, cache_len=16, page_size=4,
                      n_pages=2, prefix_reuse=False)
    kv.admit(0, np.arange(8, dtype=np.int32), 8, 1)
    with pytest.raises(RuntimeError, match="page pool exhausted"):
        kv.admit(1, np.arange(8, dtype=np.int32), 8, 1)


def test_unpageable_families_fall_through():
    for arch in ("rwkv6-7b", "hymba-1.5b"):
        kv = PagedKVCache(small_cfg(arch), max_batch=2, cache_len=32,
                          page_size=8)
        assert not kv.pageable and not kv.prefix_reuse
        kv.admit(0, np.arange(5, dtype=np.int32), 5, 4)
        assert kv.pages_live == 0        # no pool held
        kv.release(0)
        kv.check_conservation()


def test_int8_page_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 2, 16),
                          jnp.bfloat16)
    q, scale = quantize_page(x)
    back = dequantize_page(q, scale)
    # symmetric per-page quantization: half a quantization step
    # (scale/2), plus one bf16 ulp at amax (2^-8 relative) for the
    # rounding of the dequantized product back to the pool dtype
    err = float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                - back.astype(jnp.float32))))
    amax = float(scale) * 127.0
    bound = 0.5 * float(scale) + amax * 2.0 ** -8
    assert 0.0 < err <= bound + 1e-3


# ---------------------------------------------------------------------------
# engine: bit-exactness + prefix reuse
# ---------------------------------------------------------------------------

def _family_requests(cfg, n=4):
    extra = None
    if cfg.family == "encdec":
        extra = {"frames": jnp.zeros((1, cfg.enc_seq, cfg.d_model),
                                     jnp.bfloat16)}
    if cfg.family == "vlm":
        extra = {"vis": jnp.zeros((1, cfg.n_vis_tokens, cfg.d_model),
                                  jnp.bfloat16)}
    return [Request(rid=i, prompt=np.arange(4 + 3 * i) % VOCAB,
                    max_new_tokens=3 + 2 * (i % 2), extra=extra)
            for i in range(n)]


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "deepseek-moe-16b",
                                  "rwkv6-7b", "hymba-1.5b",
                                  "whisper-small", "internvl2-26b"])
def test_paged_bit_exact_vs_static_all_families(arch):
    """The tentpole contract: default-dtype paged serving returns the
    identical token lists the static-cache engine does, family by
    family (unpageable families fall through to the unpaged path)."""
    cfg = small_cfg(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    reqs = _family_requests(cfg)
    base = ServeEngine(cfg, params, max_batch=2, cache_len=64).run(reqs)
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      kv_page_size=8)
    out = eng.run(reqs)
    assert out == base
    eng.kv.check_conservation()
    # every terminal request released its pages; only index-owned prefix
    # pages (dense publishes the arange-prompt prefixes) may stay live
    assert eng.kv.pages_live == eng.kv._index_pages


def test_prefix_reuse_hits_and_stays_bit_exact(dense):
    cfg, params = dense
    reqs = generate_trace(PREFIX_TRACE).plain_requests()
    base = ServeEngine(cfg, params, max_batch=4, cache_len=64).run(reqs)
    eng = ServeEngine(cfg, params, max_batch=4, cache_len=64,
                      kv_page_size=8)
    out = eng.run(reqs)
    assert out == base                           # temperature-0 bit-exact
    kv = eng.last_stats["kvcache"]
    assert kv["prefix_hits"] > 0
    assert kv["prefill_tokens_saved"] > 0
    assert kv["prefix_hit_rate"] > 0.5           # shared-prompt workload
    assert kv["bytes_per_slot_reduction"] > 0
    eng.kv.check_conservation()


def test_prefix_reuse_identical_prompts_share_pages(dense):
    """Two identical prompts: the second admission must take refcounted
    references on the first's index pages instead of allocating."""
    cfg, params = dense
    kv = PagedKVCache(cfg, max_batch=2, cache_len=32, page_size=4)
    prompt = np.arange(9, dtype=np.int32)
    hit = kv.admit(0, prompt, len(prompt), 2)
    assert hit is None                           # cold
    # simulate the engine publishing the prefix after prefill
    shape = (cfg.n_layers, 2, 32, cfg.n_kv_heads, cfg.head_dim)
    cache = {"k": jnp.zeros(shape, jnp.bfloat16),
             "v": jnp.zeros(shape, jnp.bfloat16)}
    kv.insert_prefix(prompt, 0, cache, 0)
    live_before = kv.pages_live
    hit2 = kv.admit(1, prompt, len(prompt), 2)
    assert hit2 is not None and hit2.tokens == 8  # 2 pages, cap leaves 1
    # only the 1-token tail needed a private page
    assert kv.pages_live == live_before + 1
    kv.release(0)
    kv.release(1)
    kv.check_conservation()


def test_evict_inflight_releases_pages(dense):
    cfg, params = dense
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      kv_page_size=8, prefix_reuse=False)
    eng.reset()
    for i in range(4):
        eng.submit(Request(rid=i, prompt=np.arange(10) % VOCAB,
                           max_new_tokens=6))
    eng.step()
    assert eng.kv.pages_live > 0
    evicted, _ = eng.evict_inflight()
    assert evicted
    eng.kv.check_conservation()
    assert eng.kv.pages_live == 0                # queued ones never held


def test_mesh_paging_rejected(dense):
    cfg, params = dense
    with pytest.raises(ValueError, match="tensor parallel"):
        ServeEngine(cfg, params, max_batch=2, cache_len=64,
                    kv_page_size=8, mesh=object())


def test_int8_paged_engine_runs_and_accounts(dense):
    cfg, params = dense
    reqs = generate_trace(PREFIX_TRACE).plain_requests()
    eng = ServeEngine(cfg, params, max_batch=4, cache_len=64,
                      kv_page_size=8, kv_dtype="int8")
    out = eng.run(reqs)
    assert sorted(out) == sorted(r.rid for r in reqs)
    for r in reqs:
        assert len(out[r.rid]) == r.max_new_tokens
    eng.kv.check_conservation()
    assert eng.last_stats["kvcache"]["kv_dtype"] == "int8"


# ---------------------------------------------------------------------------
# router: replica-local prefix reuse (satellite)
# ---------------------------------------------------------------------------

def test_router_prefix_reuse_two_replicas_bit_exact(dense):
    """Two replicas under the shared-prompt trace: each replica's LOCAL
    index produces hits (the shared prompt prefills once per replica),
    outputs stay bit-exact vs the cold single-engine baseline, and page
    conservation holds on every replica."""
    cfg, params = dense
    trace = generate_trace(PREFIX_TRACE)
    base = ServeEngine(cfg, params, max_batch=4, cache_len=64,
                       rng_seed=0).run(trace.plain_requests())
    rt = Router(cfg, params, replicas=2, max_batch=4, cache_len=64,
                rng_seed=0, kv_page_size=8)
    out, stats = rt.run(trace)
    assert out == base
    kv = stats["kvcache"]
    assert kv["prefix_hits"] > 0 and kv["prefix_hit_rate"] > 0
    for rep in rt.replicas:
        rep.engine.kv.check_conservation()
    per = {pr["replica"]: pr for pr in stats["per_replica"]}
    assert sum(p["prefix_hits"] for p in per.values()) == kv["prefix_hits"]


# ---------------------------------------------------------------------------
# the paged_decode kernel family
# ---------------------------------------------------------------------------

def _example():
    ks = api.get_kernel("paged_decode")
    key = ks.canonical_keys()[0]
    args, kwargs = ks.make_example(key, seed=7)
    return ks, key, args, kwargs


def test_paged_kernel_registered_with_versions():
    ks = api.get_kernel("paged_decode")
    assert ks.versions == ("ref", "gather", "int8", "verify")
    assert ks.default_version == "gather"
    assert set(ks.tunable) == {"gather", "int8", "verify"}
    assert "paged_decode" in api.list_kernels()


def test_paged_gather_matches_ref_all_configs():
    from repro.kernels.paged.kernel_def import PagedBlockConfig
    ks, key, args, _ = _example()
    ref = api.dispatch("paged_decode", *args, version="ref")
    for cfg in ks.config_space(key, "gather"):
        got = api.dispatch("paged_decode", *args, version="gather",
                           config=cfg)
        np.testing.assert_allclose(
            np.asarray(ref, np.float32), np.asarray(got, np.float32),
            rtol=0, atol=8e-3, err_msg=str(cfg))
    # a non-dividing pages_per_block clamps instead of dropping pages
    got = api.dispatch("paged_decode", *args, version="gather",
                       config=PagedBlockConfig("t", 3))
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(got, np.float32),
                               rtol=0, atol=8e-3)


def test_paged_int8_error_pinned():
    """The quantized-cache route's accuracy delta, pinned: nonzero (it
    is lossy) but within the per-page-scale bound at unit-variance
    inputs. Bumping this bound is an API-contract change."""
    _, _, args, _ = _example()
    ref = api.dispatch("paged_decode", *args, version="ref")
    i8 = api.dispatch("paged_decode", *args, version="int8")
    err = float(np.max(np.abs(np.asarray(ref, np.float32)
                              - np.asarray(i8, np.float32))))
    assert 0.0 < err < 0.02


def test_paged_int8_pool_form_matches_quantize_on_the_fly():
    from repro.kernels.paged.paged import quantize_pool
    _, _, (q, kp, vp, tbl, cl), _kw = _example()
    auto = api.dispatch("paged_decode", q, kp, vp, tbl, cl,
                        version="int8")
    kq, kscale = quantize_pool(kp)
    vq, vscale = quantize_pool(vp)
    explicit = api.dispatch("paged_decode", q, kq, vq, tbl, cl,
                            version="int8", kscale=kscale, vscale=vscale)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(explicit))
    with pytest.raises(ValueError, match="kscale/vscale"):
        api.dispatch("paged_decode", q, kq, vq, tbl, cl, version="int8")


def test_paged_kernel_audits_clean_and_models_vmem():
    from repro.analyze.rules import audit_kernel
    ks, key, _, _ = _example()
    for version in ks.versions:
        census, findings = audit_kernel(ks, version, key)
        assert findings == [], (version, findings)
        assert census.flops > 0
    cfg = ks.static_config(key, "gather")
    gb = ks.gather_buffer_bytes(cfg, key)
    assert gb and ks.config_vmem_bytes(cfg, key) >= gb
    assert ks.key_from_dims(key.key_dims()) == key


def test_kv001_flags_uncovered_gather_buffers():
    """A paged-style kernel that declares gather buffers but whose VMEM
    model doesn't cover them must be caught by KV001 — that is the
    rule's whole reason to exist."""
    from repro.analyze.rules import audit_kernel

    @dataclasses.dataclass(frozen=True)
    class Key:
        n: int = 64
        name: str = "lazy"

        def key_dims(self) -> str:
            return str(self.n)

    @dataclasses.dataclass(frozen=True)
    class Cfg:
        name: str = "lazy"
        blk: int = 16

    class LazyPaged(api.Kernel):
        name = "lazypaged"
        versions = ("v0",)
        default_version = "v0"

        def static_config(self, key, version) -> Cfg:
            return Cfg()

        def make_example(self, key, seed: int = 0) -> Tuple[tuple, dict]:
            return (jnp.ones((key.n,), jnp.float32),), {}

        def canonical_keys(self) -> List[Key]:
            return [Key()]

        def gather_buffer_bytes(self, config, key) -> int:
            return 4 * config.blk * key.n

        def config_vmem_bytes(self, config, key) -> Optional[int]:
            return None                   # "forgot" the gather buffers

        def run(self, x, *, version, config, interpret):
            return x * 2.0

    k = LazyPaged()
    _, findings = audit_kernel(k, "v0", Key())
    assert [f.rule for f in findings] == ["KV001"]
    assert findings[0].severity == "error"
    # covering the buffers clears the finding
    k.config_vmem_bytes = lambda config, key: 4 * config.blk * key.n + 128
    _, findings = audit_kernel(k, "v0", Key())
    assert findings == []


# ---------------------------------------------------------------------------
# trace knobs (satellite)
# ---------------------------------------------------------------------------

def test_shared_prefix_knob_leaves_base_trace_intact():
    base = generate_trace(dataclasses.replace(PREFIX_TRACE,
                                              shared_prefix_frac=0.0))
    on = generate_trace(PREFIX_TRACE)
    assert [t.t_arrival for t in on.requests] \
        == [t.t_arrival for t in base.requests]
    assert [t.request.max_new_tokens for t in on.requests] \
        == [t.request.max_new_tokens for t in base.requests]
    prefixed = [i for i, (a, b) in enumerate(zip(on.requests,
                                                 base.requests))
                if len(a.request.prompt) != len(b.request.prompt)]
    assert prefixed                              # the knob did something
    for i in prefixed:
        extra = len(on.requests[i].request.prompt) \
            - len(base.requests[i].request.prompt)
        assert extra == PREFIX_TRACE.prefix_len
        np.testing.assert_array_equal(
            on.requests[i].request.prompt[PREFIX_TRACE.prefix_len:],
            base.requests[i].request.prompt)


def test_shared_prefix_knob_deterministic_and_pooled():
    a = generate_trace(PREFIX_TRACE)
    b = generate_trace(PREFIX_TRACE)
    for ta, tb in zip(a.requests, b.requests):
        np.testing.assert_array_equal(ta.request.prompt, tb.request.prompt)
    # prefixed prompts draw from at most prefix_pool distinct prefixes
    heads = {tuple(t.request.prompt[:PREFIX_TRACE.prefix_len])
             for t in a.requests
             if len(t.request.prompt) > PREFIX_TRACE.prefix_len + 1}
    assert 1 <= len(heads) <= PREFIX_TRACE.prefix_pool
