"""Numerical equivalence tests for the model internals: chunked-vs-exact
attention, WKV6/Mamba chunked-vs-scan, prefill/decode consistency, MoE
dispatch vs naive per-token routing."""

import jax
import jax.numpy as jnp
import numpy as np
from _prop import given, settings, st

from repro.configs.base import get_config, reduce_config
from repro.models import mamba as mamba_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.attention import chunked_causal_attention, decode_attention
from repro.models.layers import PARAM_DTYPE
from repro.models.moe import moe_ffn
from repro.models.registry import build_model


def _naive_causal(q, k, v, window=0):
    b, s, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    qr = q.reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qr.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    i = jnp.arange(s)[:, None]
    j = jnp.arange(skv)[None, :]
    mask = j <= i
    if window:
        mask &= j > i - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, -1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000),
       chunk=st.sampled_from([16, 32, 64]),
       window=st.sampled_from([0, 24]))
def test_chunked_attention_matches_naive(seed, chunk, window):
    rng = jax.random.PRNGKey(seed)
    ks = jax.random.split(rng, 3)
    B, S, H, KvH, Hd = 2, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, Hd), PARAM_DTYPE)
    k = jax.random.normal(ks[1], (B, S, KvH, Hd), PARAM_DTYPE)
    v = jax.random.normal(ks[2], (B, S, KvH, Hd), PARAM_DTYPE)
    ref = _naive_causal(q, k, v, window)
    out = chunked_causal_attention(q, k, v, chunk=chunk, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=0.03, rtol=0.05)


def test_chunked_attention_non_divisible_seq():
    """whisper's 1500-frame encoder: chunk falls back to a divisor."""
    rng = jax.random.PRNGKey(0)
    B, S, H, Hd = 1, 150, 2, 8
    q = jax.random.normal(rng, (B, S, H, Hd), PARAM_DTYPE)
    out = chunked_causal_attention(q, q, q, chunk=64)
    assert out.shape == (B, S, H, Hd)
    assert np.all(np.isfinite(np.asarray(out, np.float32)))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000), chunk=st.sampled_from([16, 32]))
def test_wkv6_chunked_vs_scan(seed, chunk):
    B, T, H, D = 2, 64, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, D)) - 1))
    u = 0.3 * jax.random.normal(ks[4], (H, D))
    s0 = 0.1 * jax.random.normal(ks[5], (B, H, D, D))
    y1, h1 = rwkv_lib.wkv6_scan(r, k, v, w, u, s0)
    y2, h2 = rwkv_lib.wkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000))
def test_ssm_chunked_vs_scan(seed):
    B, T, C, N = 2, 64, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    x = jax.random.normal(ks[0], (B, T, C))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, C)) - 2)
    bm = jax.random.normal(ks[2], (B, T, N))
    cm = jax.random.normal(ks[3], (B, T, N))
    alog = jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32))[None].repeat(C, 0)
    d = jax.random.normal(ks[5], (C,))
    h0 = 0.1 * jax.random.normal(ks[6], (B, C, N))
    y1, h1 = mamba_lib.ssm_scan(x, dt, bm, cm, alog, d, h0)
    y2, h2 = mamba_lib.ssm_chunked(x, dt, bm, cm, alog, d, h0, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-4, rtol=1e-4)


def test_decode_matches_prefill_dense():
    """decode_step at position S must equal last-token logits of a prefill
    over S+1 tokens (KV-cache correctness, dense family)."""
    cfg = reduce_config(get_config("phi4-mini-3.8b"))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    _, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :S]})
    pad = [(0, 0), (0, 0), (0, 8), (0, 0), (0, 0)]
    cache = {**cache,
             "k": jnp.pad(cache["k"], pad), "v": jnp.pad(cache["v"], pad)}
    lg_d, _ = jax.jit(model.decode_step)(params, cache, toks[:, S:S + 1])
    lg_f, _ = jax.jit(model.prefill)(params, {"tokens": toks})
    err = float(jnp.max(jnp.abs(lg_d - lg_f)))
    scale = float(jnp.max(jnp.abs(lg_f))) + 1e-6
    assert err / scale < 0.05, (err, scale)


def test_decode_matches_prefill_rwkv():
    cfg = reduce_config(get_config("rwkv6-7b"))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    _, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :S]})
    lg_d, _ = jax.jit(model.decode_step)(params, cache, toks[:, S:S + 1])
    lg_f, _ = jax.jit(model.prefill)(params, {"tokens": toks})
    err = float(jnp.max(jnp.abs(lg_d - lg_f)))
    scale = float(jnp.max(jnp.abs(lg_f))) + 1e-6
    assert err / scale < 0.05, (err, scale)


def test_moe_dispatch_matches_naive():
    """Capacity-based gather/scatter dispatch == per-token expert loop
    (capacity high enough that nothing drops)."""
    T, D, E, K, F = 32, 16, 4, 2, 24
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (T, D), PARAM_DTYPE)
    params = {
        "router": 0.5 * jax.random.normal(ks[1], (D, E), PARAM_DTYPE),
        "wi": jax.random.normal(ks[2], (E, D, F), PARAM_DTYPE) * 0.1,
        "wg": jax.random.normal(ks[3], (E, D, F), PARAM_DTYPE) * 0.1,
        "wo": jax.random.normal(ks[4], (E, F, D), PARAM_DTYPE) * 0.1,
    }
    out, aux = moe_ffn(x, params, n_experts=E, k=K, capacity_factor=8.0)

    # naive reference
    logits = np.asarray(x, np.float32) @ np.asarray(params["router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    ref = np.zeros((T, D), np.float32)
    for t in range(T):
        top = np.argsort(-probs[t])[:K]
        wsum = probs[t, top].sum()
        for e in top:
            xe = np.asarray(x[t], np.float32)
            h = xe @ np.asarray(params["wi"][e], np.float32)
            g = xe @ np.asarray(params["wg"][e], np.float32)
            act = h * (g / (1 + np.exp(-g)))
            y = act @ np.asarray(params["wo"][e], np.float32)
            ref[t] += probs[t, e] / wsum * y
    np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                               atol=0.05, rtol=0.1)
    assert np.isfinite(float(aux))


def test_decode_matches_prefill_whisper():
    """encdec decode (self+cross cache) must continue the prefill exactly."""
    cfg = reduce_config(get_config("whisper-small"))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    frames = 0.02 * jax.random.normal(jax.random.PRNGKey(2),
                                      (B, cfg.enc_seq, cfg.d_model),
                                      PARAM_DTYPE)
    _, cache = jax.jit(model.prefill)(
        params, {"frames": frames, "tokens": toks[:, :S]})
    pad = [(0, 0), (0, 0), (0, 4), (0, 0), (0, 0)]
    cache = {**cache,
             "k": jnp.pad(cache["k"], pad), "v": jnp.pad(cache["v"], pad)}
    lg_d, _ = jax.jit(model.decode_step)(params, cache, toks[:, S:S + 1])
    lg_f, _ = jax.jit(model.prefill)(
        params, {"frames": frames, "tokens": toks})
    err = float(jnp.max(jnp.abs(lg_d - lg_f)))
    scale = float(jnp.max(jnp.abs(lg_f))) + 1e-6
    assert err / scale < 0.05, (err, scale)


def test_hymba_ring_buffer_decode():
    """hybrid decode past the attention window: ring buffer must roll, and
    decode must keep matching a fresh prefill (window + SSM state carry)."""
    cfg = reduce_config(get_config("hymba-1.5b"))   # window=64
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B = 1
    S = cfg.attn_window + 16                         # cross the window
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    _, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :S]})
    lg_d, cache2 = jax.jit(model.decode_step)(params, cache,
                                              toks[:, S:S + 1])
    lg_f, _ = jax.jit(model.prefill)(params, {"tokens": toks})
    err = float(jnp.max(jnp.abs(lg_d - lg_f)))
    scale = float(jnp.max(jnp.abs(lg_f))) + 1e-6
    assert err / scale < 0.08, (err, scale)
    assert int(cache2["pos"]) == S + 1
