"""Optimizer convergence, checkpoint roundtrip/atomicity/resume, data
pipeline determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, PrefetchIterator, TokenSource
from repro.optim.adafactor import Adafactor
from repro.optim.adamw import AdamW, clip_by_global_norm
from repro.optim.compress import ef_int8_compress, ef_int8_decompress, init_residual
from repro.optim.schedule import linear_warmup_cosine


# ---------------------------------------------------------------- optimizers

def _quadratic_losses(opt, steps=60):
    target = jnp.asarray([1.0, -2.0, 0.5, 3.0])
    params = {"w": jnp.zeros(4), "b": jnp.zeros(())}

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2) + (p["b"] - 1.0) ** 2

    state = opt.init(params)
    losses = []
    for _ in range(steps):
        g = jax.grad(loss_fn)(params)
        params, state, _ = opt.update(params, g, state)
        losses.append(float(loss_fn(params)))
    return losses


def test_adamw_converges():
    losses = _quadratic_losses(AdamW(lr_fn=lambda s: 0.1, weight_decay=0.0))
    assert losses[-1] < 0.05 * losses[0]


def test_adafactor_converges():
    losses = _quadratic_losses(
        Adafactor(lr_fn=lambda s: 0.3, weight_decay=0.0))
    assert losses[-1] < 0.2 * losses[0]


def test_adafactor_state_is_factored():
    opt = Adafactor(lr_fn=lambda s: 1e-3)
    params = {"w": jnp.zeros((64, 32)), "v": jnp.zeros(16)}
    st_ = opt.init(params)
    assert st_["f"]["w"]["vr"].shape == (64,)
    assert st_["f"]["w"]["vc"].shape == (32,)
    assert st_["f"]["v"]["v"].shape == (16,)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), max_norm=st.floats(0.1, 10.0))
def test_clip_by_global_norm_property(seed, max_norm):
    rng = jax.random.PRNGKey(seed)
    g = {"a": 10 * jax.random.normal(rng, (8, 3)),
         "b": jax.random.normal(rng, (5,))}
    clipped, gnorm = clip_by_global_norm(g, max_norm)
    new_norm = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                                  for x in jax.tree.leaves(clipped))))
    assert new_norm <= max_norm * 1.01
    if float(gnorm) <= max_norm:  # below threshold: untouched
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(g["a"]), rtol=1e-5)


def test_schedule_shape():
    lrs = [float(linear_warmup_cosine(s, peak_lr=1.0, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0          # warmup rises
    assert max(lrs) <= 1.0
    assert lrs[-1] < lrs[20]               # cosine decays
    assert lrs[-1] >= 0.099                # floor


def test_ef_int8_roundtrip_and_error_feedback():
    rng = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(rng, (32, 8))}
    res = init_residual(g)
    q, scales, res2 = ef_int8_compress(g, res)
    deq = ef_int8_decompress(q, scales)
    err1 = float(jnp.max(jnp.abs(deq["w"] - g["w"])))
    assert err1 < float(scales["w"]) * 1.01            # bounded by 1 quantum
    # residual carries exactly the quantization error
    np.testing.assert_allclose(np.asarray(res2["w"]),
                               np.asarray(g["w"] - deq["w"]), atol=1e-6)


# --------------------------------------------------------------- checkpoints

def test_checkpoint_roundtrip_bf16(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"params": {"w": jnp.ones((4, 3), jnp.bfloat16) * 1.5,
                       "b": jnp.arange(3, dtype=jnp.float32)},
            "opt": {"step": jnp.int32(7)}}
    mgr.save(5, tree, blocking=True)
    step, restored = mgr.restore()
    assert step == 5
    assert restored["params"]["w"].dtype.name == "bfloat16"
    np.testing.assert_array_equal(np.asarray(restored["params"]["b"]),
                                  np.asarray(tree["params"]["b"]))
    assert int(restored["opt"]["step"]) == 7


def test_checkpoint_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.ones(2) * s}, blocking=True)
    assert mgr.latest_step() == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]  # gc kept last 2
    _, t = mgr.restore(3)
    assert float(t["x"][0]) == 3.0


def test_checkpoint_no_partial_visibility(tmp_path):
    """A tmp dir from a 'crashed' save must not be visible via LATEST."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.zeros(1)}, blocking=True)
    os.makedirs(os.path.join(tmp_path, ".tmp_step_00000002"))
    assert mgr.latest_step() == 1


def test_checkpoint_latest_survives_crash_before_pointer(tmp_path):
    """A kill in the window between the atomic step_* rename and the
    LATEST pointer update must not lose the newer checkpoint: the step
    dir is complete on disk, so latest_step() finds it by scan even
    though the pointer still names the previous step."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.zeros(1)}, blocking=True)
    mgr.save(2, {"x": jnp.ones(1)}, blocking=True)
    # simulate the crash window: rewind LATEST to the previous step
    with open(os.path.join(tmp_path, "LATEST"), "w") as fh:
        fh.write("step_00000001")
    assert mgr.latest_step() == 2
    step, t = mgr.restore()
    assert step == 2 and float(t["x"][0]) == 1.0
    # first-save variant: checkpoint complete, pointer never written
    os.remove(os.path.join(tmp_path, "LATEST"))
    assert mgr.latest_step() == 2


def test_checkpoint_latest_pointer_never_torn(tmp_path):
    """The pointer write is mkstemp + atomic replace (the tune/tuner.py
    discipline): a truncated/garbage LATEST — the artifact of the old
    fixed-name tmp write dying mid-write — must never be trusted, and no
    fixed-name tmp file is used (concurrent writers cannot tear it)."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, {"x": jnp.zeros(1)}, blocking=True)
    with open(os.path.join(tmp_path, "LATEST")) as fh:
        assert fh.read() == "step_00000003"
    # no .LATEST_* tmp droppings survive a clean save
    assert [f for f in os.listdir(tmp_path)
            if f.startswith(".LATEST_")] == []
    # a torn pointer (crash mid-write in the legacy scheme) falls back
    # to the scan instead of crashing or returning None
    with open(os.path.join(tmp_path, "LATEST"), "w") as fh:
        fh.write("step_000")                   # truncated garbage
    assert mgr.latest_step() == 3
    with open(os.path.join(tmp_path, "LATEST"), "w") as fh:
        fh.write("")                           # empty
    assert mgr.latest_step() == 3


# ---------------------------------------------------------------------- data

def test_data_determinism_and_shapes():
    cfg = DataConfig(seq_len=16, global_batch=8, vocab_size=100, seed=3)
    a = TokenSource(cfg, dp_rank=0, dp_size=2)
    b = TokenSource(cfg, dp_rank=0, dp_size=2)
    c = TokenSource(cfg, dp_rank=1, dp_size=2)
    ba, bb, bc = a.batch_at(7), b.batch_at(7), c.batch_at(7)
    np.testing.assert_array_equal(ba["tokens"], bb["tokens"])   # reproducible
    assert not np.array_equal(ba["tokens"], bc["tokens"])       # rank-distinct
    assert ba["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(ba["tokens"][:, 1:], ba["labels"][:, :-1])


def test_data_token_file(tmp_path):
    toks = np.arange(1000, dtype=np.uint16)
    path = str(tmp_path / "tokens.bin")
    toks.tofile(path)
    cfg = DataConfig(seq_len=10, global_batch=4, vocab_size=2 ** 16,
                     token_file=path)
    src = TokenSource(cfg)
    b = src.batch_at(0)
    # windows are contiguous slices: labels = tokens shifted by one
    np.testing.assert_array_equal(b["tokens"][:, 1:] , b["labels"][:, :-1])
    assert (b["tokens"] >= 0).all() and (b["tokens"] < 1000).all()


def test_prefetch_iterator_order():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab_size=50)
    it = PrefetchIterator(TokenSource(cfg), start_step=5)
    try:
        steps = [next(it)[0] for _ in range(4)]
        assert steps == [5, 6, 7, 8]
    finally:
        it.close()
