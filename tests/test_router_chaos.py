"""Router fault-injection (chaos) tier — deterministic by construction.

Every scenario scripts its faults with `FaultPlan` on the router's
virtual tick clock, so "kill replica 1 mid-decode" is an exact, seeded,
CPU-reproducible event rather than process murder. The core contract
under test: a fenced replica's in-flight requests re-queue onto
survivors and RESTART from scratch, and because sample keys are
per-request (fold_in(rid, i)) and replicas share rng_seed, the re-served
tokens are bit-exact against an undisturbed single-engine run — no
request dropped, none duplicated, partial tokens discarded as waste.

Run by the CI `router-chaos` job alongside tests/test_router_props.py.
"""

import jax
import pytest

from repro.configs.base import get_config, reduce_config
from repro.models.registry import build_model
from repro.serve.engine import ServeEngine
from repro.serve.router import FaultPlan, Router
from repro.serve.trace import TraceConfig, generate_trace


# greedy decoding so the bit-exactness claim is about scheduling and
# sample-key placement, not one lucky temperature draw
TRACE = TraceConfig(n_requests=10, arrival="poisson", rate_rps=40.0,
                    prompt_median=4, prompt_sigma=0.4, prompt_max=12,
                    out_median=6, out_sigma=0.5, out_max=10,
                    temperatures=(0.0,), vocab=128, seed=11)


@pytest.fixture(scope="module")
def small():
    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2, d_model=64,
                        vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def baseline(small):
    """The undisturbed single-engine run every chaos scenario must
    reproduce token-for-token."""
    cfg, params = small
    trace = generate_trace(TRACE)
    eng = ServeEngine(cfg, params, max_batch=4, cache_len=64, rng_seed=0)
    out = eng.run(trace.plain_requests())
    return trace, out


def _assert_no_drop_no_dup(trace, out):
    want = sorted(tr.request.rid for tr in trace.requests)
    assert sorted(out.keys()) == want          # every rid exactly once
    for tr in trace.requests:
        assert len(out[tr.request.rid]) == tr.request.max_new_tokens


def test_kill_replica_mid_decode_bit_exact(small, baseline, tmp_path):
    """Kill replica 1 while it holds in-flight work: the router fences
    it, survivors absorb the re-queued requests, every request completes
    with tokens identical to the undisturbed baseline."""
    cfg, params = small
    trace, base_out = baseline
    rt = Router(cfg, params, replicas=2, max_batch=2, cache_len=64,
                rng_seed=0, heartbeat_dir=str(tmp_path),
                stale_after_ticks=2,
                fault_plan=FaultPlan().kill(1, at_tick=3))
    out, stats = rt.run(trace)
    assert stats["completed"] == TRACE.n_requests
    assert stats["killed"] == [1] and stats["fenced"] == [1]
    # the kill landed mid-decode: work was actually lost and re-served
    assert stats["requeued"] > 0
    assert stats["wasted_toks"] > 0
    _assert_no_drop_no_dup(trace, out)
    assert out == base_out                     # bit-exact failover
    # the dead replica served nothing to completion after the fence
    dead = stats["per_replica"][1]
    assert dead["killed"] and dead["fenced"] and dead["evicted"] > 0
    assert stats["per_replica"][0]["completed"] + dead["completed"] \
        == TRACE.n_requests


def test_long_stall_gets_fenced_and_completes(small, baseline, tmp_path):
    """A stall longer than stale_after_ticks is indistinguishable from
    death: the replica is fenced (and never resurrected, even though the
    process wakes up) and the run still completes bit-exact."""
    cfg, params = small
    trace, base_out = baseline
    rt = Router(cfg, params, replicas=2, max_batch=2, cache_len=64,
                rng_seed=0, heartbeat_dir=str(tmp_path),
                stale_after_ticks=2,
                fault_plan=FaultPlan().stall(0, at_tick=2, ticks=8))
    out, stats = rt.run(trace)
    assert stats["completed"] == TRACE.n_requests
    assert stats["fenced"] == [0] and stats["killed"] == []
    _assert_no_drop_no_dup(trace, out)
    assert out == base_out
    # no resurrection: everything after the fence lands on replica 1
    assert stats["per_replica"][1]["completed"] == TRACE.n_requests


def test_short_stall_rides_through_without_fencing(small, baseline,
                                                   tmp_path):
    """A stall within the staleness budget is a blip, not a failure: no
    fencing, no re-queue, identical outputs."""
    cfg, params = small
    trace, base_out = baseline
    rt = Router(cfg, params, replicas=2, max_batch=2, cache_len=64,
                rng_seed=0, heartbeat_dir=str(tmp_path),
                stale_after_ticks=4,
                fault_plan=FaultPlan().stall(1, at_tick=2, ticks=2))
    out, stats = rt.run(trace)
    assert stats["completed"] == TRACE.n_requests
    assert stats["fenced"] == [] and stats["requeued"] == 0
    assert stats["wasted_toks"] == 0
    assert stats["per_replica"][1]["stalled_ticks"] == 2
    _assert_no_drop_no_dup(trace, out)
    assert out == base_out


def test_all_replicas_dead_raises(small, tmp_path):
    """Killing every replica with work outstanding must fail loudly, not
    hang or silently drop requests."""
    cfg, params = small
    trace = generate_trace(TRACE)
    rt = Router(cfg, params, replicas=2, max_batch=2, cache_len=64,
                rng_seed=0, heartbeat_dir=str(tmp_path),
                stale_after_ticks=1,
                fault_plan=FaultPlan().kill(0, at_tick=1).kill(1, at_tick=1))
    with pytest.raises(RuntimeError, match="dead/fenced"):
        rt.run(trace)


def test_chaos_run_is_seed_deterministic(small, tmp_path):
    """The same trace + fault plan reproduces the identical outputs AND
    the identical tick-denominated stats — the property that lets the
    bench gate tail latencies across machines."""
    cfg, params = small
    trace = generate_trace(TRACE)
    runs = []
    for i in range(2):
        rt = Router(cfg, params, replicas=2, max_batch=2, cache_len=64,
                    rng_seed=0, heartbeat_dir=str(tmp_path / f"hb{i}"),
                    stale_after_ticks=2,
                    fault_plan=FaultPlan().kill(1, at_tick=3))
        runs.append(rt.run(trace))
    (out_a, st_a), (out_b, st_b) = runs
    assert out_a == out_b
    for k in ("ticks", "requeued", "wasted_toks", "decode_steps",
              "prefills", "goodput_toks", "p50_ttft_ticks",
              "p99_ttft_ticks", "p50_tpot_ticks", "p99_tpot_ticks",
              "max_queue_depth"):
        assert st_a[k] == st_b[k], k


def test_paged_chaos_conserves_pages_and_stays_bit_exact(small, baseline,
                                                         tmp_path):
    """Chaos + paging: a replica flap (kill -> recover) while the engines
    run paged K/V caches. The fence path releases every in-flight block
    table through evict_inflight, recovery resets the pool, and the page
    conservation invariant (allocated == freed + live) must hold on EVERY
    replica afterwards — with outputs still bit-exact vs the undisturbed
    unpaged baseline (paging is storage, never numerics)."""
    cfg, params = small
    trace, base_out = baseline
    rt = Router(cfg, params, replicas=2, max_batch=2, cache_len=64,
                rng_seed=0, heartbeat_dir=str(tmp_path),
                stale_after_ticks=2, kv_page_size=8,
                fault_plan=FaultPlan().flap(1, at_tick=3, down_ticks=4))
    out, stats = rt.run(trace)
    assert stats["completed"] == TRACE.n_requests
    _assert_no_drop_no_dup(trace, out)
    assert out == base_out                     # paged failover bit-exact
    for rep in rt.replicas:
        rep.engine.kv.check_conservation()
        assert rep.engine.kv.pages_live == rep.engine.kv._index_pages
    # the fleet kvcache stats fold history across the recovery reset
    kv = stats["kvcache"]
    assert kv["pages_allocated"] >= kv["pages_freed"]
    assert kv["pages_allocated"] > 0


def test_spec_chaos_flap_mid_verify_stays_bit_exact(small, baseline,
                                                    tmp_path):
    """Chaos + speculative decoding + paging: a replica flap while every
    engine runs spec rounds over a paged target cache. Fencing can land
    between a verify launch and its accept, so this scenario leans on the
    evict_inflight rollback (device pos back to the last COMMITTED token,
    draft cache included) — a re-queued request must restart clean on a
    survivor and, at temperature 0, the fleet output must still match the
    undisturbed PLAIN single-engine baseline token-for-token (spec is
    scheduling, never numerics). Page conservation must hold with draft
    K/V lines in play (slot-resident, never page-accounted)."""
    cfg, params = small
    dcfg = reduce_config(get_config("qwen2-1.5b"), layers=1, d_model=64,
                         vocab=128)
    dparams = build_model(dcfg).init_params(jax.random.PRNGKey(1))
    trace, base_out = baseline
    rt = Router(cfg, params, replicas=2, max_batch=2, cache_len=64,
                rng_seed=0, heartbeat_dir=str(tmp_path),
                stale_after_ticks=2, kv_page_size=8,
                draft_cfg=dcfg, draft_params=dparams, spec_k=2,
                fault_plan=FaultPlan().flap(1, at_tick=3, down_ticks=4))
    out, stats = rt.run(trace)
    assert stats["completed"] == TRACE.n_requests
    _assert_no_drop_no_dup(trace, out)
    assert out == base_out                     # spec failover bit-exact
    for rep in rt.replicas:
        rep.engine.kv.check_conservation()
        assert rep.engine.kv.pages_live == rep.engine.kv._index_pages
    # fleet spec stats fold across the recovery reset and keep the
    # accounting identity; the flapped replica's wasted rounds inflate
    # proposed, never tokens_emitted
    sp = stats["spec"]
    assert sp["k"] == 2
    assert sp["accepted"] + sp["rejected"] + sp["bonus"] \
        == sp["tokens_emitted"]
    assert sp["tokens_emitted"] > 0
    assert 0.0 <= sp["acceptance_rate"] <= 1.0
    assert all("spec_acceptance_rate" in row
               for row in stats["per_replica"])
