"""Router overload-and-recovery tier — deadlines, bounded-queue load
shedding, retry backoff, brown-out, and replica recovery, all on the
deterministic tick clock (docs/serving.md §Overload & recovery).

The contract under test: every trace request reaches EXACTLY ONE terminal
outcome (completed | shed | deadline_missed), no duplicates or
resurrections across repeated kill->recover cycles, completed outputs
stay bit-exact vs an undisturbed single-engine run at temperature 0, and
the whole run — including shed/miss/retry counts — is run-to-run
deterministic per seed.

Run by the CI `router-chaos` job alongside tests/test_router_chaos.py.
"""

import jax
import pytest

from repro.configs.base import get_config, reduce_config
from repro.models.registry import build_model
from repro.serve.engine import ServeEngine
from repro.serve.router import (FaultEvent, FaultPlan, OverloadConfig,
                                Router)
from repro.serve.trace import TraceConfig, generate_trace

TRACE = TraceConfig(n_requests=10, arrival="poisson", rate_rps=40.0,
                    prompt_median=4, prompt_sigma=0.4, prompt_max=12,
                    out_median=6, out_sigma=0.5, out_max=10,
                    temperatures=(0.0,), vocab=128, seed=11)

# hotter mix for the overload scenarios: arrivals outpace 2x2 slots
HOT = TraceConfig(n_requests=14, arrival="bursty", rate_rps=48.0,
                  burst_factor=6.0, burst_every_s=0.25, burst_len_s=0.15,
                  prompt_median=4, prompt_sigma=0.4, prompt_max=12,
                  out_median=8, out_sigma=0.5, out_max=16,
                  temperatures=(0.0,), vocab=128, seed=7)


@pytest.fixture(scope="module")
def small():
    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2, d_model=64,
                        vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, params


def _router(small, tmp_path, **kw):
    cfg, params = small
    kw.setdefault("replicas", 2)
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("rng_seed", 0)
    kw.setdefault("heartbeat_dir", str(tmp_path))
    return Router(cfg, params, **kw)


def _assert_conserved(trace, out, stats):
    """Every request exactly one terminal outcome; outputs exist exactly
    for the completed ones, full-length, no duplicates."""
    rids = sorted(tr.request.rid for tr in trace.requests)
    assert sorted(stats["outcomes"]) == rids
    assert set(stats["outcomes"].values()) <= {
        "completed", "shed", "deadline_missed"}
    done = sorted(r for r, s in stats["outcomes"].items()
                  if s == "completed")
    assert sorted(out) == done
    assert stats["completed"] + stats["shed"] + stats["deadline_missed"] \
        == len(rids)
    by_rid = {tr.request.rid: tr.request for tr in trace.requests}
    for rid in done:
        assert len(out[rid]) == by_rid[rid].max_new_tokens


# ------------------------------------------------------------- fault plan

def test_events_at_same_tick_insertion_order():
    """Same-tick events apply in the order the plan author wrote them —
    kill-then-recover leaves the replica alive, recover-then-kill leaves
    it dead, and neither depends on list/dict accidents."""
    p = FaultPlan().kill(0, at_tick=5).recover(0, at_tick=5)
    assert [e.kind for e in p.events_at(5)] == ["kill", "recover"]
    q = FaultPlan().recover(0, at_tick=5).kill(0, at_tick=5)
    assert [e.kind for e in q.events_at(5)] == ["recover", "kill"]
    # pre-built event lists get sequenced on construction too
    r = FaultPlan([FaultEvent(tick=3, replica=1, kind="stall", duration=2),
                   FaultEvent(tick=3, replica=0, kind="kill")])
    assert [(e.kind, e.replica) for e in r.events_at(3)] \
        == [("stall", 1), ("kill", 0)]
    assert [e.seq for e in r.events_at(3)] == [0, 1]


def test_flap_builds_kill_recover_cycles():
    p = FaultPlan().flap(1, at_tick=6, down_ticks=4, times=2)
    kinds = [(e.tick, e.kind) for e in sorted(p.events, key=lambda e: e.seq)]
    assert kinds == [(6, "kill"), (10, "recover"),
                     (14, "kill"), (18, "recover")]
    assert p.has_recovery_after(10) and not p.has_recovery_after(18)
    with pytest.raises(ValueError):
        FaultPlan().flap(0, at_tick=0, down_ticks=0)
    with pytest.raises(ValueError):
        FaultPlan().flap(0, at_tick=0, down_ticks=4, times=2, period=3)


# --------------------------------------------------------------- deadlines

def test_deadlines_evict_and_are_terminal(small, tmp_path):
    """Tight heavy-tail deadlines under load: some requests miss, each
    missed request is terminal (evicted from queue or mid-flight), and
    the rest complete normally."""
    trace = generate_trace(TraceConfig(
        **{**HOT.__dict__, "deadline_median": 8, "deadline_sigma": 0.8,
           "deadline_max": 40}))
    assert any(tr.deadline_ticks is not None for tr in trace.requests)
    rt = _router(small, tmp_path)
    out, stats = rt.run(trace)
    _assert_conserved(trace, out, stats)
    assert stats["deadline_missed"] > 0 and stats["completed"] > 0
    assert stats["shed"] == 0                  # no queue bound configured
    assert stats["deadline_miss_rate"] == \
        stats["deadline_missed"] / HOT.n_requests


def test_no_deadline_trace_is_unchanged_per_seed():
    """The deadline knob draws LAST and only when enabled: disabled
    configs generate bit-identical traces to the pre-knob generator."""
    a = generate_trace(TRACE)
    b = generate_trace(TRACE)
    assert all(tr.deadline_ticks is None for tr in a.requests)
    assert [tr.t_arrival for tr in a.requests] \
        == [tr.t_arrival for tr in b.requests]
    assert [tr.request.max_new_tokens for tr in a.requests] \
        == [tr.request.max_new_tokens for tr in b.requests]


# -------------------------------------------------- shedding + retry + brownout

def test_bounded_queue_sheds_with_retry_backoff(small, tmp_path):
    """A full bounded queue sheds; shed requests re-enter via exponential
    backoff until the budget runs out, then are terminally shed."""
    trace = generate_trace(HOT)
    rt = _router(small, tmp_path, max_queue=2, retry_budget=1)
    out, stats = rt.run(trace)
    _assert_conserved(trace, out, stats)
    assert stats["shed"] > 0 and stats["completed"] > 0
    assert stats["retries"] > 0                # backoff path exercised
    # every admission rejection either scheduled a retry or was terminal
    assert stats["shed_events"] == stats["retries"] + stats["shed"]
    assert stats["shed_rate"] == stats["shed"] / HOT.n_requests


def test_retry_budget_zero_sheds_immediately(small, tmp_path):
    trace = generate_trace(HOT)
    rt = _router(small, tmp_path, max_queue=0, retry_budget=0)
    out, stats = rt.run(trace)
    _assert_conserved(trace, out, stats)
    assert out == {} and stats["shed"] == HOT.n_requests
    assert stats["retries"] == 0
    # zero-completed run: SLO summaries are well-defined zeros
    assert stats["p99_ttft_ticks"] == 0.0
    assert stats["p50_tpot_ticks"] == 0.0
    assert stats["goodput_toks"] == 0


def test_shed_policy_reject_oldest(small, tmp_path):
    """reject-oldest sheds the queue head to admit the newcomer; both
    policies conserve requests but pick deterministic, different
    victims."""
    trace = generate_trace(HOT)
    _, st_new = _router(small, tmp_path / "a", max_queue=1,
                        retry_budget=0).run(trace)
    _, st_old = _router(small, tmp_path / "b", max_queue=1,
                        retry_budget=0, shed_policy="reject-oldest"
                        ).run(trace)
    for st in (st_new, st_old):
        assert st["completed"] + st["shed"] == HOT.n_requests
    shed_new = {r for r, s in st_new["outcomes"].items() if s == "shed"}
    shed_old = {r for r, s in st_old["outcomes"].items() if s == "shed"}
    assert shed_new and shed_old and shed_new != shed_old
    with pytest.raises(ValueError, match="shed_policy"):
        Router(None, None, shed_policy="drop-random")


def test_brownout_trips_and_restores(small, tmp_path):
    """Sustained queue depth trips the brown-out (admissions shed while
    it holds), and draining to queue_low restores admissions — later
    arrivals complete."""
    trace = generate_trace(HOT)
    rt = _router(small, tmp_path, retry_budget=0,
                 overload=OverloadConfig(window_ticks=2, queue_high=1,
                                         queue_low=0))
    out, stats = rt.run(trace)
    _assert_conserved(trace, out, stats)
    assert stats["brownouts"] >= 1
    assert stats["brownout_ticks"] >= 1
    assert stats["shed"] > 0                   # brown-out actually shed
    assert stats["completed"] > 0              # ...and then restored


# ---------------------------------------------------------------- recovery

def test_recover_rejoins_dispatch_and_completes(small, tmp_path):
    """Kill -> fence -> recover: the replica rebuilds fresh engine state,
    beats again, rejoins least-loaded dispatch, and serves requests to
    completion — outputs bit-exact vs the undisturbed single engine."""
    cfg, params = small
    trace = generate_trace(TRACE)
    eng = ServeEngine(cfg, params, max_batch=4, cache_len=64, rng_seed=0)
    base = eng.run(trace.plain_requests())
    rt = _router(small, tmp_path, stale_after_ticks=2,
                 fault_plan=FaultPlan().kill(1, at_tick=3)
                                       .recover(1, at_tick=8))
    out, stats = rt.run(trace)
    _assert_conserved(trace, out, stats)
    assert stats["completed"] == TRACE.n_requests
    assert out == base
    assert stats["recoveries"] == 1 and stats["recovered"] == [1]
    assert stats["fenced"] == [1]
    # the kill lands at 3, the fence once the beat goes stale, the
    # recover at 8: the fence->recover gap is positive and recorded
    assert stats["recovery_ticks"] and stats["mean_recovery_ticks"] > 0
    rep1 = stats["per_replica"][1]
    assert rep1["recoveries"] == 1
    assert not rep1["killed"] and not rep1["fenced"]
    # the recovered replica actually served work after rejoining
    assert rep1["completed"] > 0 or rep1["prefills"] > 0


def test_repeated_flap_is_idempotent(small, tmp_path):
    """Two kill->recover cycles: fencing and recovery are idempotent, no
    request is dropped, duplicated, or resurrected, outputs stay
    bit-exact."""
    cfg, params = small
    trace = generate_trace(TRACE)
    eng = ServeEngine(cfg, params, max_batch=4, cache_len=64, rng_seed=0)
    base = eng.run(trace.plain_requests())
    rt = _router(small, tmp_path, stale_after_ticks=2,
                 fault_plan=FaultPlan().flap(1, at_tick=3, down_ticks=5,
                                             times=2))
    out, stats = rt.run(trace)
    _assert_conserved(trace, out, stats)
    assert stats["completed"] == TRACE.n_requests
    assert out == base
    assert stats["recoveries"] == 2
    assert stats["per_replica"][1]["recoveries"] == 2


def test_all_dead_waits_for_scheduled_recovery(small, tmp_path):
    """With every replica dead but a recovery scheduled, the router ticks
    toward it instead of raising — and still completes everything."""
    trace = generate_trace(TRACE)
    rt = _router(small, tmp_path, stale_after_ticks=1,
                 fault_plan=FaultPlan().kill(0, at_tick=1)
                                       .kill(1, at_tick=1)
                                       .recover(0, at_tick=6))
    out, stats = rt.run(trace)
    _assert_conserved(trace, out, stats)
    assert stats["completed"] == TRACE.n_requests
    assert stats["per_replica"][0]["completed"] == TRACE.n_requests


def test_all_dead_without_recovery_still_raises(small, tmp_path):
    trace = generate_trace(TRACE)
    rt = _router(small, tmp_path, stale_after_ticks=1,
                 fault_plan=FaultPlan().kill(0, at_tick=1)
                                       .kill(1, at_tick=1))
    with pytest.raises(RuntimeError, match="dead/fenced"):
        rt.run(trace)


# ------------------------------------------------------- acceptance chaos

def _chaos_router(small, hb_dir):
    return _router(small, hb_dir, stale_after_ticks=2, max_queue=3,
                   retry_budget=1,
                   fault_plan=FaultPlan().flap(1, at_tick=4, down_ticks=4,
                                               times=2))


def test_burst_plus_flap_conservation_acceptance(small, tmp_path):
    """The PR's acceptance scenario: a deadline-carrying burst trace
    through a bounded queue while replica 1 flaps twice. Every request
    reaches exactly one terminal outcome, nothing duplicates or
    resurrects across the kill->recover cycles, completed outputs are
    bit-exact vs the undisturbed single-engine baseline at temperature 0,
    and the entire run — outcomes, shed/miss/retry counts, ticks — is
    run-to-run deterministic per seed."""
    cfg, params = small
    trace = generate_trace(TraceConfig(
        **{**HOT.__dict__, "deadline_median": 20, "deadline_sigma": 0.8,
           "deadline_max": 80}))
    eng = ServeEngine(cfg, params, max_batch=4, cache_len=64, rng_seed=0)
    base = eng.run(trace.plain_requests())

    runs = []
    for i in range(2):
        rt = _chaos_router(small, tmp_path / f"hb{i}")
        runs.append(rt.run(trace))
    (out_a, st_a), (out_b, st_b) = runs

    _assert_conserved(trace, out_a, st_a)
    assert st_a["completed"] > 0
    assert st_a["recoveries"] == 2             # both flap cycles recovered
    for rid, toks in out_a.items():            # bit-exact completed set
        assert toks == base[rid], rid

    # run-to-run determinism, including every overload counter
    assert out_a == out_b
    assert st_a["outcomes"] == st_b["outcomes"]
    for k in ("ticks", "requeued", "wasted_toks", "decode_steps",
              "prefills", "goodput_toks", "shed", "deadline_missed",
              "shed_events", "retries", "recoveries", "recovery_ticks",
              "brownouts", "brownout_ticks", "p50_ttft_ticks",
              "p99_ttft_ticks", "p50_tpot_ticks", "p99_tpot_ticks",
              "max_queue_depth"):
        assert st_a[k] == st_b[k], k
