"""Property-based tests for the trace generator and router admission.

Via tests/_prop.py (real hypothesis when installed, seeded sampled
fallback otherwise). The invariants:

  * trace generation — arrival times strictly monotone, lengths inside
    the configured clips, rids unique and sequential, and the whole
    trace a pure function of its seed (per-seed determinism);
  * router admission — conservation: every request that enters leaves
    exactly once with exactly max_new_tokens tokens, across random
    replica counts and fault plans (no drop, no dup, killed replica or
    not).

Run by the CI `router-chaos` job alongside tests/test_router_chaos.py.
"""

import functools

import numpy as np
import pytest

from _prop import given, settings, st
from repro.serve.router import FaultPlan, Router
from repro.serve.trace import TraceConfig, generate_trace


# ------------------------------------------------------- trace generation

def _cfg(seed, arrival, n=12):
    return TraceConfig(n_requests=n, arrival=arrival, rate_rps=20.0,
                       burst_every_s=0.3, burst_len_s=0.1,
                       prompt_median=4, prompt_sigma=0.5, prompt_max=10,
                       out_median=4, out_sigma=0.6, out_max=8,
                       temperatures=(0.0, 0.7), vocab=64, seed=seed)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1 << 16),
       arrival=st.sampled_from(["poisson", "bursty"]))
def test_trace_invariants(seed, arrival):
    tr = generate_trace(_cfg(seed, arrival))
    times = [r.t_arrival for r in tr.requests]
    assert all(b > a for a, b in zip(times, times[1:]))   # strictly monotone
    assert times[0] > 0.0
    assert [r.request.rid for r in tr.requests] == list(range(12))
    for r in tr.requests:
        req = r.request
        assert 1 <= len(req.prompt) <= 10
        assert 1 <= req.max_new_tokens <= 8
        assert req.temperature in (0.0, 0.7)
        assert req.prompt.dtype == np.int32
        assert 0 <= int(req.prompt.min()) and int(req.prompt.max()) < 64
    if arrival == "poisson":
        assert tr.burst_windows == []
    else:
        for t0, t1 in tr.burst_windows:
            assert t1 - t0 == pytest.approx(0.1)
            assert t0 >= 0.3                  # first period stays calm


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1 << 16),
       arrival=st.sampled_from(["poisson", "bursty"]))
def test_trace_per_seed_determinism(seed, arrival):
    a = generate_trace(_cfg(seed, arrival))
    b = generate_trace(_cfg(seed, arrival))
    assert [r.t_arrival for r in a.requests] \
        == [r.t_arrival for r in b.requests]
    assert a.burst_windows == b.burst_windows
    for x, y in zip(a.requests, b.requests):
        assert x.request.max_new_tokens == y.request.max_new_tokens
        assert x.request.temperature == y.request.temperature
        assert np.array_equal(x.request.prompt, y.request.prompt)


def test_trace_different_seeds_differ():
    """Anti-test for the determinism property: the seed must actually
    steer the draw (guards a frozen-rng regression)."""
    a = generate_trace(_cfg(0, "poisson"))
    b = generate_trace(_cfg(1, "poisson"))
    assert [r.t_arrival for r in a.requests] \
        != [r.t_arrival for r in b.requests]


def test_trace_rejects_unknown_arrival():
    with pytest.raises(ValueError, match="unknown arrival"):
        generate_trace(TraceConfig(arrival="flash-crowd"))


def test_arrival_ticks_floor_quantization():
    tr = generate_trace(_cfg(3, "poisson"))
    ticks = tr.arrival_ticks(0.05)
    assert ticks == sorted(ticks)
    for k, r in zip(ticks, tr.requests):
        assert k * 0.05 <= r.t_arrival < (k + 1) * 0.05


# ------------------------------------------------------ router conservation

@functools.lru_cache(maxsize=1)
def _small_model():
    # not a fixture: @given-wrapped properties present a zero-arg
    # signature to pytest, so fixtures can't inject here
    import jax
    from repro.configs.base import get_config, reduce_config
    from repro.models.registry import build_model
    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2, d_model=64,
                        vocab=128)
    params = build_model(cfg).init_params(jax.random.PRNGKey(0))
    return cfg, params


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1 << 8),
       replicas=st.sampled_from([1, 2]),
       inject=st.booleans())
def test_router_conserves_requests(seed, replicas, inject):
    """Requests in == requests out, each rid exactly once at full length,
    for any seed x replica count x (fault | no fault). Kills only make
    sense with a survivor, so single-replica runs stay fault-free."""
    cfg, params = _small_model()
    trace = generate_trace(TraceConfig(
        n_requests=5, arrival="poisson", rate_rps=30.0,
        prompt_median=3, prompt_sigma=0.4, prompt_max=8,
        out_median=3, out_sigma=0.5, out_max=6,
        temperatures=(0.0,), vocab=128, seed=seed))
    plan = None
    if inject and replicas == 2:
        plan = FaultPlan().kill(1, at_tick=2)
    rt = Router(cfg, params, replicas=replicas, max_batch=2, cache_len=32,
                rng_seed=0, stale_after_ticks=2, fault_plan=plan)
    out, stats = rt.run(trace)
    assert sorted(out.keys()) == [tr.request.rid for tr in trace.requests]
    for tr in trace.requests:
        assert len(out[tr.request.rid]) == tr.request.max_new_tokens
    assert stats["completed"] == 5 and stats["n_requests"] == 5
    assert sum(r["completed"] for r in stats["per_replica"]) == 5
    # conservation of token accounting: goodput counts each request's
    # full output exactly once, waste only what a fenced replica lost
    assert stats["goodput_toks"] == sum(len(v) for v in out.values())
    if plan is None:
        assert stats["requeued"] == 0 and stats["wasted_toks"] == 0
