"""Slot-level continuous-batching engine tests (the serve smoke tier).

Covers the scheduler contract: finished slots refill from the queue
mid-flight (fewer decode steps than the old wave loop, with exact
per-request outputs vs the single-request path), done-row masking /
per-request sampling determinism, the stats schema, and the left-pad
prefill regression (pad tokens must not leak into positions/attention).
"""

from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduce_config
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def small():
    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2, d_model=64,
                        vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _wave_decode_steps(reqs, max_batch):
    """Decode steps the old wave loop would take: consecutive waves of
    max_batch requests, each running until its LONGEST request finishes
    (short requests' rows idle; the next wave can't start early)."""
    steps = 0
    for i in range(0, len(reqs), max_batch):
        steps += max(r.max_new_tokens for r in reqs[i:i + max_batch]) - 1
    return steps


def _slot_sim_steps(reqs, max_batch):
    """Pure-python simulation of the slot scheduler's global decode-step
    count (admit free slots FIFO before every step; a slot leaves when its
    request's last token is sampled)."""
    q = deque(r.max_new_tokens for r in reqs)
    slots = [None] * max_batch
    steps = 0
    while q or any(s is not None for s in slots):
        for i in range(max_batch):
            if slots[i] is None and q:
                rem = q.popleft() - 1          # first token from prefill
                slots[i] = rem if rem > 0 else None
        if not any(s is not None for s in slots):
            continue
        steps += 1
        slots = [None if s == 1 else (s - 1 if s is not None else None)
                 for s in slots]
    return steps


def test_slot_refill_beats_wave_and_matches_solo(small):
    """The acceptance workload: mixed max_new_tokens (4 and 64), more
    requests than slots. Slots refill mid-flight, so the engine finishes in
    fewer decode steps than the wave loop — and every request's tokens are
    exactly what the single-request path produces."""
    cfg, model, params = small
    mixed = [4, 64, 4, 64, 4, 4]
    reqs = [Request(rid=i, prompt=np.arange(4 + i) % 128, max_new_tokens=n)
            for i, n in enumerate(mixed)]
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=128)
    out, stats = eng.run(reqs, collect_stats=True)
    steps = stats["engine"]["decode_steps"]
    assert steps == _slot_sim_steps(reqs, 2)
    assert steps < _wave_decode_steps(reqs, 2)
    for r in reqs:
        solo = ServeEngine(cfg, params, max_batch=1, cache_len=128)
        s = solo.run([Request(rid=r.rid, prompt=r.prompt,
                              max_new_tokens=r.max_new_tokens)])
        assert s[r.rid] == out[r.rid], r.rid
        assert len(out[r.rid]) == r.max_new_tokens


def test_sampling_independent_of_batchmates(small):
    """Done-row masking + per-request fold_in keys: a sampled request's
    tokens do not depend on slot placement, batch-mates (including rows
    that finish early and would otherwise keep advancing a shared rng), or
    admission order."""
    cfg, model, params = small

    def tgt():
        return Request(rid=5, prompt=(np.arange(6) * 3) % 128,
                       max_new_tokens=8, temperature=0.7)

    mates = [Request(rid=1, prompt=np.arange(3) % 128, max_new_tokens=2),
             Request(rid=2, prompt=np.arange(9) % 128, max_new_tokens=20,
                     temperature=1.1)]
    a = ServeEngine(cfg, params, max_batch=3, cache_len=64,
                    rng_seed=1).run([tgt()] + mates)
    b = ServeEngine(cfg, params, max_batch=3, cache_len=64,
                    rng_seed=1).run(mates + [tgt()])
    c = ServeEngine(cfg, params, max_batch=1, cache_len=64,
                    rng_seed=1).run([tgt()])
    assert a[5] == b[5] == c[5]
    # distinct rids with the same prompt draw from distinct key streams
    d = ServeEngine(cfg, params, max_batch=2, cache_len=64, rng_seed=1).run(
        [tgt(), Request(rid=6, prompt=(np.arange(6) * 3) % 128,
                        max_new_tokens=8, temperature=0.7)])
    assert d[5] != d[6]


def test_stats_schema(small):
    cfg, model, params = small
    reqs = [Request(rid=i, prompt=np.arange(3 + i) % 128,
                    max_new_tokens=1 + 3 * i) for i in range(5)]
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64)
    out, stats = eng.run(reqs, collect_stats=True)
    e = stats["engine"]
    assert e["requests"] == e["prefills"] == 5
    assert e["new_tokens"] == sum(len(v) for v in out.values())
    assert 0.0 < e["occupancy"] <= 1.0
    assert e["tok_per_s"] > 0 and e["wall_s"] > 0
    assert eng.last_stats is e
    for r in reqs:
        st = stats["requests"][r.rid]
        assert st.new_tokens == r.max_new_tokens
        assert st.decode_steps == r.max_new_tokens - 1
        assert st.prompt_len == len(r.prompt)
        assert 0.0 <= st.queue_wait_s <= st.ttft_s <= st.total_s
        assert st.tok_per_s > 0


def test_max_new_tokens_one_and_zero(small):
    """A prefill-only request (max_new_tokens=1) frees its slot without
    ever riding a decode step, and a degenerate max_new_tokens=0 request
    completes empty instead of hanging the scheduler."""
    cfg, model, params = small
    reqs = [Request(rid=0, prompt=np.arange(4) % 128, max_new_tokens=1),
            Request(rid=1, prompt=np.arange(5) % 128, max_new_tokens=3),
            Request(rid=2, prompt=np.arange(4) % 128, max_new_tokens=0)]
    out, stats = ServeEngine(cfg, params, max_batch=1, cache_len=64).run(
        reqs, collect_stats=True)
    assert len(out[0]) == 1 and len(out[1]) == 3 and out[2] == []
    assert stats["requests"][0].decode_steps == 0
    assert stats["requests"][2].new_tokens == 0


def test_left_pad_prefill_matches_unpadded(small):
    """Left-pad regression: prefilling a left-padded prompt with pad_lens
    must reproduce the unpadded final-position logits exactly — pad tokens
    leak into neither RoPE positions nor the attention softmax."""
    cfg, model, params = small
    prompt = np.arange(7, dtype=np.int32) % 128
    l0, _ = model.prefill(params, {"tokens": jnp.asarray(prompt[None])})
    for pad in (1, 5):
        padded = np.concatenate([np.zeros(pad, np.int32), prompt])
        lp, _ = model.prefill(
            params, {"tokens": jnp.asarray(padded[None]),
                     "pad_lens": jnp.asarray(np.array([pad], np.int32))})
        np.testing.assert_allclose(np.asarray(lp), np.asarray(l0),
                                   rtol=0, atol=1e-5)


def test_left_pad_prefill_matches_unpadded_moe():
    """Same regression through the MoE stack: pad tokens must not claim
    expert capacity slots from real tokens (moe_ffn token_valid masking).
    Expert capacity itself is shape-derived (static shapes), so the sizes
    here are chosen so padded and unpadded T land on the same capacity
    (t=16 and t=19 with E=8, k=2, cf=1.25 both give C=5)."""
    cfg = reduce_config(get_config("deepseek-moe-16b"), layers=2, d_model=64,
                        vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    prompt = (np.arange(16, dtype=np.int32) * 5) % 128
    l0, _ = model.prefill(params, {"tokens": jnp.asarray(prompt[None])})
    padded = np.concatenate([np.zeros(3, np.int32), prompt])
    lp, _ = model.prefill(
        params, {"tokens": jnp.asarray(padded[None]),
                 "pad_lens": jnp.asarray(np.array([3], np.int32))})
    np.testing.assert_allclose(np.asarray(lp), np.asarray(l0),
                               rtol=0, atol=1e-5)


def test_slot_engine_other_families():
    """prefill_into_slot / per-row decode across the non-dense families:
    recurrent state (ssm), ring-buffer + conv state (hybrid), expert
    capacity dispatch (moe — served at exact prompt length so pads can't
    shift capacity), cross-attention cache rows (encdec) and prepended
    vis tokens (vlm) must all refill without disturbing batch-mates."""
    extras = {
        "whisper-small": lambda c: {
            "frames": jnp.zeros((1, c.enc_seq, c.d_model), jnp.bfloat16)},
        "internvl2-26b": lambda c: {
            "vis": jnp.zeros((1, c.n_vis_tokens, c.d_model), jnp.bfloat16)},
    }
    for arch in ("rwkv6-7b", "hymba-1.5b", "deepseek-moe-16b",
                 "whisper-small", "internvl2-26b"):
        cfg = reduce_config(get_config(arch), layers=2, d_model=64, vocab=128)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        mk_extra = extras.get(arch)

        def mk(rid, n, new):
            return Request(rid=rid, prompt=np.arange(n) % 128,
                           max_new_tokens=new,
                           extra=mk_extra(cfg) if mk_extra else None)

        reqs = [mk(i, 4 + i, 3 if i % 2 else 7) for i in range(3)]
        eng = ServeEngine(cfg, params, max_batch=2, cache_len=64)
        out = eng.run(reqs)
        for r in reqs:
            solo = ServeEngine(cfg, params, max_batch=1, cache_len=64)
            s = solo.run([mk(r.rid, len(r.prompt), r.max_new_tokens)])
            assert s[r.rid] == out[r.rid], (arch, r.rid)


def test_vlm_bucket_accounts_for_vis_tokens():
    """A vlm prompt near cache_len must not be bucketed past the room left
    after the prepended vis tokens (regression: bucket 64 + 8 vis lines
    into a 64-line slot blew up the cache write)."""
    cfg = reduce_config(get_config("internvl2-26b"), layers=2, d_model=64,
                        vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=1, cache_len=64)
    prompt = np.arange(50, dtype=np.int32) % 128   # 50 + 8 vis + 5 new <= 64
    out = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=5,
                           extra={"vis": jnp.zeros((1, cfg.n_vis_tokens,
                                                    cfg.d_model),
                                                   jnp.bfloat16)})])
    assert len(out[0]) == 5


def test_moe_slot_prefill_matches_exact_model():
    """MoE requests are served at exact prompt length (no shape bucket):
    the engine's first sampled token must equal greedy argmax of
    model.prefill on the raw prompt — pad tokens must not claim expert
    capacity (the bug this guards: bucketed right-pad shifting routing)."""
    cfg = reduce_config(get_config("deepseek-moe-16b"), layers=2, d_model=64,
                        vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompt = (np.arange(5, dtype=np.int32) * 7) % 128   # 5: not a bucket size
    logits, _ = model.prefill(params, {"tokens": jnp.asarray(prompt[None])})
    expect = int(jnp.argmax(logits.astype(jnp.float32).reshape(-1)))
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64)
    out = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=1)])
    assert out[0] == [expect]
