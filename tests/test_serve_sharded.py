"""Tensor-parallel ServeEngine over a repro.dist mesh.

Contract under test (docs/serving.md §Sharded serving):
  * serve_specs plans exact-TP — weights shard column-parallel only
    (output dims), the slot K/V cache shards head-wise, scheduler state
    replicates — so every cross-device combine is a concatenation, never
    a psum, and sharded serving is BIT-EXACT vs the single-device engine;
  * the FIFO slot scheduler is device-count-agnostic: the same workload
    produces identical tokens with no mesh, a 1-device mesh, and a forced
    8-device host mesh (subprocess tier, like tests/test_dist.py).
"""

import os
import subprocess
import sys
import textwrap
import types

import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduce_config
from repro.dist.sharding import ShardingPlan, serve_specs, spec_for
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fake_mesh(**axes):
    return types.SimpleNamespace(shape=dict(axes))


# --------------------------------------------------------- exact-TP specs

def test_exact_tp_shards_output_dims_only():
    """tp_out_dims_only: a weight may claim the model axis on its LAST dim
    only — column-parallel wq/wi shard, row-parallel wo/w_down replicate
    (their contraction dim must stay whole for the exact all-gather
    combine)."""
    p = ShardingPlan(mesh=_fake_mesh(model=8), tp_out_dims_only=True)
    # column-parallel: output features last -> sharded
    assert tuple(spec_for(p, ("layers", "d_model", "heads"),
                          (2, 256, 256))) == (None, None, "model")
    assert tuple(spec_for(p, ("layers", "d_model", "d_ff"),
                          (2, 256, 768))) == (None, None, "model")
    # row-parallel: the TP-eligible dim is the contraction, not the last
    # dim -> replicated (the plain plan would shard it)
    rp = spec_for(p, ("layers", "heads", "d_model"), (2, 256, 256))
    assert all(s is None for s in tuple(rp))
    loose = spec_for(ShardingPlan(mesh=_fake_mesh(model=8)),
                     ("layers", "heads", "d_model"), (2, 256, 256))
    assert tuple(loose)[1] == "model"
    # activations/caches are untouched by the restriction: the kv cache
    # still shards head-wise
    kv = spec_for(p, ("layers", "batch", "kv_seq", "kv_heads", None),
                  (2, 4, 64, 8, 32), is_param=False)
    assert tuple(kv)[3] == "model"


def test_serve_specs_structure_and_replication():
    """serve_specs mirrors the engine state: a NamedSharding per param and
    cache leaf, (B,) pos + logits replicated (host-side scheduler)."""
    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2, d_model=64,
                        vocab=128)
    mesh = jax.make_mesh((1,), ("model",))
    specs = serve_specs(cfg, mesh, max_batch=2, cache_len=32)
    assert specs.plan.tp_out_dims_only and specs.plan.dp_axes == ()
    model = build_model(cfg)
    ab = model.abstract_params()
    flat_p = jax.tree.leaves(specs.params)
    assert len(flat_p) == len(jax.tree.leaves(ab))
    assert tuple(specs.cache["pos"].spec) in ((), (None,))
    assert tuple(specs.replicated.spec) in ((), (None,))
    assert set(specs.cache) == {"k", "v", "pos"}


def test_one_device_mesh_bitexact_and_device_stats():
    """A mesh of 1 device must be a pure refactor: identical tokens to the
    mesh-less engine, plus the per-device accounting appearing in stats."""
    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2, d_model=64,
                        vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    def mk():
        return [Request(rid=i, prompt=np.arange(4 + i) % 128,
                        max_new_tokens=3 + 2 * i,
                        temperature=(0.7 if i == 1 else 0.0))
                for i in range(3)]

    ref = ServeEngine(cfg, params, max_batch=2, cache_len=64).run(mk())
    mesh = jax.make_mesh((1,), ("model",))
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64, mesh=mesh)
    out, stats = eng.run(mk(), collect_stats=True)
    assert out == ref
    e = stats["engine"]
    assert e["devices"] == 1 and len(e["per_device"]) == 1
    d = e["per_device"][0]
    assert d["params_bytes"] > 0 and d["cache_bytes"] > 0
    assert d["occupancy"] == e["occupancy"]
    assert eng.device_stats()[0]["params_bytes"] == d["params_bytes"]


# ------------------------------------------------- multi-device (subprocess)

def _run_sub(code: str):
    src = os.path.join(REPO_ROOT, "src")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=(src + os.pathsep + os.environ["PYTHONPATH"]
                           if os.environ.get("PYTHONPATH") else src))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=560,
                       cwd=REPO_ROOT, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_sharded_engine_bitexact_8dev_dense_and_moe():
    """The acceptance criterion: on a forced 8-device CPU mesh the sharded
    engine produces bit-exact tokens vs the single-device engine for dense
    and moe configs — with weights REALLY sharded (local shards smaller
    than the global leaf), greedy and temperature sampling mixed."""
    _run_sub("""
        import jax, numpy as np
        from repro.configs.base import get_config, reduce_config
        from repro.models.registry import build_model
        from repro.serve.engine import Request, ServeEngine
        for arch, seed in (("qwen2-1.5b", 0), ("deepseek-moe-16b", 1)):
            cfg = reduce_config(get_config(arch), layers=2, d_model=256,
                                vocab=128)
            model = build_model(cfg)
            params = model.init_params(jax.random.PRNGKey(seed))
            rng = np.random.default_rng(3)
            prompts = [rng.integers(0, 128, 4 + i % 5) for i in range(5)]
            def mk():
                return [Request(rid=i, prompt=prompts[i],
                                max_new_tokens=(3 if i % 2 else 9),
                                temperature=(0.7 if i == 1 else 0.0))
                        for i in range(5)]
            ref = ServeEngine(cfg, params, max_batch=2, cache_len=64).run(mk())
            mesh = jax.make_mesh((8,), ("model",))
            eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                              mesh=mesh)
            out = eng.run(mk())
            assert out == ref, (arch, out, ref)
            n_sharded = sum(
                1 for l in jax.tree.leaves(eng.params)
                if l.addressable_shards[0].data.size != l.size)
            assert n_sharded > 0, f"{arch}: nothing sharded"
            ds = eng.device_stats()
            assert len(ds) == 8
            assert ds[0]["params_bytes"] < sum(
                l.nbytes for l in jax.tree.leaves(eng.params))
            print(arch, "bit-exact,", n_sharded, "sharded leaves")
    """)


@pytest.mark.slow
def test_bench_serve_mesh_emits_per_device_rows(tmp_path):
    """`benchmarks/run.py --serve --mesh tp=8` (no pre-set XLA_FLAGS: the
    harness forces the device count itself) writes one serve_device_<i>
    artifact row per device with occupancy / tok_per_s metrics."""
    import json
    out = str(tmp_path / "BENCH_serve_tp8.json")
    src = os.path.join(REPO_ROOT, "src")
    env = dict(os.environ,
               PYTHONPATH=(src + os.pathsep + os.environ["PYTHONPATH"]
                           if os.environ.get("PYTHONPATH") else src))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-m", "benchmarks.run", "--serve",
                        "--mesh", "tp=8", "--json", out],
                       capture_output=True, text=True, timeout=560,
                       cwd=REPO_ROOT, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    art = json.load(open(out))
    rows = {row["name"]: row for row in art["rows"]}
    for i in range(8):
        m = rows[f"serve_device_{i}"]["metrics"]
        assert 0.0 < m["occupancy"] <= 1.0
        assert m["tok_per_s"] > 0
        assert m["params_mib"] > 0
    # uniform TP split: every device reports the same shard accounting
    sizes = {rows[f"serve_device_{i}"]["metrics"]["params_mib"]
             for i in range(8)}
    assert len(sizes) == 1
    # and the engine row is still there for the serve-smoke comparisons
    assert rows["serve_engine"]["metrics"]["occupancy"] > 0
