"""Serve stats math — the aggregation behind `benchmarks/run.py --serve`.

The engine's per-request stats were covered by test_serve.py's schema
test; the AGGREGATION (occupancy / tok_per_s / TTFT & queue-wait means)
was only exercised via the smoke job. These tests pin the formulas twice:
directly on `aggregate_engine_stats` with synthetic inputs (exact
arithmetic), and on a real engine run by recomputing every aggregate from
the per-request records it returns alongside.
"""

import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduce_config
from repro.models.registry import build_model
from repro.serve.engine import (Request, RequestStats, ServeEngine,
                                aggregate_engine_stats, percentile,
                                request_tpot_s)
from repro.serve.router import router_slo_summary


def _rs(rid, new_tokens, queue, ttft, steps, total):
    return RequestStats(rid=rid, prompt_len=4, new_tokens=new_tokens,
                        queue_wait_s=queue, ttft_s=ttft,
                        decode_steps=steps, total_s=total,
                        tok_per_s=new_tokens / max(total - queue, 1e-9))


# ------------------------------------------------------------- pure formulas

def test_aggregate_formulas_exact():
    per_req = {1: _rs(1, 8, 0.1, 0.3, 7, 1.0),
               2: _rs(2, 4, 0.5, 0.6, 3, 0.9)}
    e = aggregate_engine_stats(per_req, n_requests=2, n_steps=10,
                               n_prefills=2, slot_steps_active=10,
                               max_batch=2, wall_s=2.0)
    assert e["requests"] == 2 and e["prefills"] == 2
    assert e["new_tokens"] == 12
    assert e["decode_steps"] == 10
    assert e["occupancy"] == 10 / (10 * 2)
    assert e["tok_per_s"] == 12 / 2.0
    assert e["mean_queue_wait_s"] == pytest.approx((0.1 + 0.5) / 2)
    assert e["mean_ttft_s"] == pytest.approx((0.3 + 0.6) / 2)
    assert e["wall_s"] == 2.0


def test_aggregate_empty_and_prefill_only_edges():
    """No decode steps (all requests prefill-only) must not divide by
    zero: occupancy is vacuously 1.0; an empty run aggregates to zeros."""
    e = aggregate_engine_stats({}, n_requests=0, n_steps=0, n_prefills=0,
                               slot_steps_active=0, max_batch=4, wall_s=0.0)
    assert e["new_tokens"] == 0 and e["occupancy"] == 1.0
    assert e["mean_queue_wait_s"] == 0.0 and e["mean_ttft_s"] == 0.0
    assert e["tok_per_s"] == 0.0
    one = aggregate_engine_stats({7: _rs(7, 1, 0.0, 0.1, 0, 0.2)},
                                 n_requests=1, n_steps=0, n_prefills=1,
                                 slot_steps_active=0, max_batch=4,
                                 wall_s=0.5)
    assert one["occupancy"] == 1.0 and one["new_tokens"] == 1


# ------------------------------------------------------ percentile machinery

def test_percentile_edge_cases():
    """n=1 and all-equal samples degenerate to that value for every q;
    an empty sample is defined as 0.0; two-point samples interpolate
    linearly (numpy's default) — hand-computed below."""
    assert percentile([], 50) == 0.0 and percentile([], 99) == 0.0
    for q in (0, 50, 99, 100):
        assert percentile([0.7], q) == pytest.approx(0.7)
        assert percentile([0.4, 0.4, 0.4], q) == pytest.approx(0.4)
    # linear interpolation between sorted neighbours: p lands at
    # index q/100 * (n-1), so [0.3, 0.6] -> p50 = 0.45, p99 = 0.597
    assert percentile([0.3, 0.6], 50) == pytest.approx(0.45)
    assert percentile([0.3, 0.6], 99) == pytest.approx(0.597)
    # order must not matter
    assert percentile([0.6, 0.3], 99) == pytest.approx(0.597)


def test_request_tpot_defined_only_from_two_tokens():
    """TPOT needs an inter-token gap: new_tokens <= 1 has none (None);
    otherwise it is (total - ttft) / (new_tokens - 1)."""
    assert request_tpot_s(_rs(1, 0, 0.0, 0.0, 0, 0.0)) is None
    assert request_tpot_s(_rs(2, 1, 0.0, 0.2, 0, 0.3)) is None
    t = request_tpot_s(_rs(3, 5, 0.1, 0.3, 4, 1.1))
    assert t == pytest.approx((1.1 - 0.3) / 4)


def test_aggregate_percentiles_hand_computed_fixture():
    """The engine p50/p99 rows against a fixture computed by hand:
    TTFT samples exclude zero-token requests, TPOT samples need >= 2
    tokens, and a max_new_tokens==1 request contributes to TTFT only."""
    per_req = {
        1: _rs(1, 8, 0.1, 0.3, 7, 1.0),    # tpot = 0.7/7 = 0.1
        2: _rs(2, 4, 0.5, 0.6, 3, 0.9),    # tpot = 0.3/3 = 0.1
        3: _rs(3, 1, 0.0, 0.2, 0, 0.2),    # ttft sample only
        4: _rs(4, 0, 0.0, 0.0, 0, 0.0),    # excluded everywhere
    }
    e = aggregate_engine_stats(per_req, n_requests=4, n_steps=10,
                               n_prefills=4, slot_steps_active=10,
                               max_batch=2, wall_s=2.0)
    # ttfts = sorted([0.3, 0.6, 0.2]) = [0.2, 0.3, 0.6]
    assert e["p50_ttft_s"] == pytest.approx(0.3)
    assert e["p99_ttft_s"] == pytest.approx(0.3 + 0.98 * 0.3)  # 0.594
    # both tpot samples equal 0.1 -> every percentile is 0.1
    assert e["p50_tpot_s"] == pytest.approx(0.1)
    assert e["p99_tpot_s"] == pytest.approx(0.1)


def test_aggregate_percentiles_single_request():
    """n=1: every percentile is that request's own latency."""
    e = aggregate_engine_stats({9: _rs(9, 3, 0.0, 0.25, 2, 0.85)},
                               n_requests=1, n_steps=2, n_prefills=1,
                               slot_steps_active=2, max_batch=1, wall_s=1.0)
    assert e["p50_ttft_s"] == e["p99_ttft_s"] == pytest.approx(0.25)
    assert e["p50_tpot_s"] == e["p99_tpot_s"] == pytest.approx(0.3)


def test_aggregate_percentiles_no_qualifying_samples():
    """All requests zero-token (max_new_tokens<1 degenerates): no TTFT or
    TPOT samples, tails degrade to 0.0 rather than raising."""
    e = aggregate_engine_stats({1: _rs(1, 0, 0.0, 0.0, 0, 0.0)},
                               n_requests=1, n_steps=0, n_prefills=0,
                               slot_steps_active=0, max_batch=2, wall_s=0.1)
    assert e["p50_ttft_s"] == e["p99_ttft_s"] == 0.0
    assert e["p50_tpot_s"] == e["p99_tpot_s"] == 0.0


def test_router_slo_summary_hand_computed_fixture():
    """The router's SLO fold against hand-computed numbers, including the
    empty-sample degradations."""
    s = router_slo_summary(ttft_ticks=[0, 2], tpot_ticks=[1.0, 1.0],
                           ttft_s=[0.3, 0.6], tpot_s=[0.1, 0.1],
                           queue_depth_samples=[0, 1, 3])
    assert s["p50_ttft_ticks"] == pytest.approx(1.0)
    assert s["p99_ttft_ticks"] == pytest.approx(1.98)
    assert s["p50_tpot_ticks"] == s["p99_tpot_ticks"] == pytest.approx(1.0)
    assert s["p50_ttft_s"] == pytest.approx(0.45)
    assert s["p99_ttft_s"] == pytest.approx(0.597)
    assert s["mean_queue_depth"] == pytest.approx(4 / 3)
    # [0, 1, 3]: p99 at index 1.98 -> 1 + 0.98 * 2 = 2.96
    assert s["p99_queue_depth"] == pytest.approx(2.96)
    assert s["max_queue_depth"] == 3
    empty = router_slo_summary([], [], [], [], [])
    assert empty["p50_ttft_ticks"] == 0.0
    assert empty["mean_queue_depth"] == 0.0
    assert empty["max_queue_depth"] == 0


def test_router_slo_summary_zero_completed_run():
    """An all-shed / all-deadline-missed run completes ZERO requests:
    every latency list is empty while queue depths were still sampled.
    All percentiles must be well-defined zeros (no empty-percentile
    crash) and the depth stats still reflect the samples."""
    s = router_slo_summary([], [], [], [], [0, 2, 2, 1, 0])
    for k in ("p50_ttft_ticks", "p99_ttft_ticks", "p50_tpot_ticks",
              "p99_tpot_ticks", "p50_ttft_s", "p99_ttft_s",
              "p50_tpot_s", "p99_tpot_s"):
        assert s[k] == 0.0, k
    assert s["mean_queue_depth"] == pytest.approx(1.0)
    assert s["max_queue_depth"] == 2


def test_aggregate_engine_stats_zero_completed():
    """Submitted-but-never-finished work (everything evicted or shed):
    per_req is empty yet counters may be nonzero. Means and tails must be
    0.0, occupancy stays defined, and no division explodes — including
    the wall_s=0 edge."""
    e = aggregate_engine_stats({}, n_requests=4, n_steps=3, n_prefills=2,
                               slot_steps_active=5, max_batch=2,
                               wall_s=0.0)
    assert e["requests"] == 4 and e["new_tokens"] == 0
    assert e["p50_ttft_s"] == e["p99_ttft_s"] == 0.0
    assert e["p50_tpot_s"] == e["p99_tpot_s"] == 0.0
    assert e["mean_queue_wait_s"] == e["mean_ttft_s"] == 0.0
    assert e["occupancy"] == pytest.approx(5 / 6)
    assert e["tok_per_s"] == 0.0               # 0 tokens over ~0 wall


# ------------------------------------------------------- real-run identities

@pytest.fixture(scope="module")
def run_stats():
    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2, d_model=64,
                        vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=3, cache_len=64)
    reqs = [Request(rid=i, prompt=np.arange(3 + i) % 128,
                    max_new_tokens=(2 if i % 2 else 7)) for i in range(7)]
    out, stats = eng.run(reqs, collect_stats=True)
    return eng, out, stats


def test_engine_aggregates_match_per_request_records(run_stats):
    """Every engine aggregate must be recomputable from the per-request
    records: slot-steps = sum of request decode_steps, tokens = sum of
    new_tokens, means = arithmetic means, tok_per_s = tokens / wall."""
    eng, out, stats = run_stats
    per = stats["requests"].values()
    e = stats["engine"]
    assert e["new_tokens"] == sum(r.new_tokens for r in per) \
        == sum(len(v) for v in out.values())
    # each active slot-step belongs to exactly one request
    assert e["occupancy"] == pytest.approx(
        sum(r.decode_steps for r in per) / (e["decode_steps"]
                                            * eng.max_batch))
    assert e["tok_per_s"] == pytest.approx(e["new_tokens"] / e["wall_s"])
    assert e["mean_ttft_s"] == pytest.approx(
        float(np.mean([r.ttft_s for r in per])))
    assert e["mean_queue_wait_s"] == pytest.approx(
        float(np.mean([r.queue_wait_s for r in per])))
    assert "per_device" not in e       # mesh-less engine: no device rows


def test_per_request_throughput_consistent(run_stats):
    """tok_per_s of a request is its tokens over its in-slot time
    (total - queue wait), and the timing chain is ordered."""
    _, _, stats = run_stats
    for r in stats["requests"].values():
        assert 0.0 <= r.queue_wait_s <= r.ttft_s <= r.total_s
        assert r.tok_per_s == pytest.approx(
            r.new_tokens / max(r.total_s - r.queue_wait_s, 1e-9), rel=1e-6)


def test_serve_bench_row_parses(run_stats):
    """The --serve artifact row derived-string format round-trips through
    report.parse_derived with the gateable metric names intact."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.report import parse_derived
    eng, _, _ = run_stats
    e = eng.last_stats
    derived = (f"decode_steps={e['decode_steps']};prefills={e['prefills']};"
               f"new_tokens={e['new_tokens']};occupancy={e['occupancy']:.3f};"
               f"tok_per_s={e['tok_per_s']:.1f}")
    m = parse_derived(derived)
    assert m["decode_steps"] == e["decode_steps"]
    assert m["occupancy"] == pytest.approx(e["occupancy"], abs=5e-4)
    assert m["tok_per_s"] >= 0
