"""Differential acceptance tier for speculative decoding (serve/spec.py).

The load-bearing contract: at temperature 0, a spec-decode engine's output
is TOKEN-FOR-TOKEN identical to plain decoding for every draft/target pair
and every draft depth k — acceptance is argmax agreement, the correction
token is the plain argmax at the first mismatch, and the bonus token is
the plain argmax past a full accept, so the emitted chain IS the plain
greedy chain regardless of what the draft proposes. At temperature > 0 the
guarantee is distributional (rejection sampling), pinned here only at the
accounting level: accepted + rejected + bonus == tokens_emitted.

Run by the CI serve-smoke job next to the serve/kvcache tiers.
"""

import functools
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _prop import given, settings, st
from repro.configs.base import get_config, reduce_config
from repro.models.registry import build_model
from repro.serve import spec as spec_lib
from repro.serve.engine import Request, ServeEngine

VOCAB = 128


def _reduced(arch, *, layers=2, d_model=64, seed):
    cfg = reduce_config(get_config(arch), layers=layers, d_model=d_model,
                        vocab=VOCAB)
    params = build_model(cfg).init_params(jax.random.PRNGKey(seed))
    return cfg, params


@pytest.fixture(scope="module")
def qwen_pair():
    """The config zoo's qwen pair, reduced: qwen2_1_5b drafts for
    qwen2_5_32b. Different architectures AND different init seeds, so the
    draft genuinely disagrees with the target sometimes."""
    tgt = _reduced("qwen2.5-32b", seed=0)
    drf = _reduced("qwen2-1.5b", layers=1, seed=1)
    return tgt, drf


@pytest.fixture(scope="module")
def phi_pair():
    """phi4_mini_3_8b drafting for a larger dense phi-style target."""
    tgt = _reduced("phi4-mini-3.8b", seed=0)
    drf = _reduced("phi4-mini-3.8b", layers=1, d_model=32, seed=2)
    return tgt, drf


def _requests(n=4, max_new=8, temperature=0.0):
    rng = np.random.RandomState(3)
    return [Request(rid=i, prompt=rng.randint(0, VOCAB, size=3 + (i % 4)),
                    max_new_tokens=max_new, temperature=temperature)
            for i in range(n)]


# -------------------------------------------------------------- differential
# tier: spec-decode == plain decode, token for token, at temperature 0

@pytest.mark.parametrize("pair", ["qwen_pair", "phi_pair"])
@pytest.mark.parametrize("k", [1, 2, 4])
def test_spec_bit_exact_vs_plain_greedy(pair, k, request):
    (cfg, params), (dcfg, dparams) = request.getfixturevalue(pair)
    reqs = _requests()
    plain = ServeEngine(cfg, params, max_batch=2, cache_len=64, rng_seed=0)
    want = plain.run(list(reqs))
    spec = ServeEngine(cfg, params, max_batch=2, cache_len=64, rng_seed=0,
                       draft_cfg=dcfg, draft_params=dparams, spec_k=k)
    got = spec.run(list(reqs))
    assert got == want
    sp = spec.last_stats["spec"]
    assert sp["k"] == k
    assert sp["tokens_emitted"] > 0
    assert sp["accepted"] + sp["rejected"] + sp["bonus"] \
        == sp["tokens_emitted"]


def test_spec_self_draft_accepts_everything(qwen_pair):
    """Draft == target (self-speculation): every candidate must be
    accepted, every round emits k+1 tokens (until the budget caps it) —
    pins the accept loop's upper edge and the bonus-token path."""
    (cfg, params), _ = qwen_pair
    reqs = _requests(n=2, max_new=9)
    plain = ServeEngine(cfg, params, max_batch=2, cache_len=64, rng_seed=0)
    want = plain.run(list(reqs))
    spec = ServeEngine(cfg, params, max_batch=2, cache_len=64, rng_seed=0,
                       draft_cfg=cfg, draft_params=params, spec_k=2)
    got = spec.run(list(reqs))
    assert got == want
    sp = spec.last_stats["spec"]
    assert sp["rejected"] == 0                  # argmax always agrees
    assert sp["acceptance_rate"] == 1.0
    assert sp["accepted_tokens_per_step"] > 1.0


def test_spec_batch_mate_independence(qwen_pair):
    """A spec slot next to other slots (including a temperature slot)
    produces the same tokens as serving it alone — sampling is
    per-request and the verify's per-row masking leaks nothing."""
    (cfg, params), (dcfg, dparams) = qwen_pair
    rng = np.random.RandomState(5)
    reqs = [Request(rid=0, prompt=rng.randint(0, VOCAB, size=4),
                    max_new_tokens=8, temperature=0.0),
            Request(rid=1, prompt=rng.randint(0, VOCAB, size=6),
                    max_new_tokens=5, temperature=0.7),
            Request(rid=2, prompt=rng.randint(0, VOCAB, size=3),
                    max_new_tokens=7, temperature=0.0)]

    def spec_engine():
        return ServeEngine(cfg, params, max_batch=2, cache_len=64,
                           rng_seed=0, draft_cfg=dcfg,
                           draft_params=dparams, spec_k=2)

    batched = spec_engine().run(list(reqs))
    for r in reqs:
        solo = spec_engine().run([r])
        assert solo[r.rid] == batched[r.rid], r.rid


def test_spec_rejects_mesh_and_requires_draft(qwen_pair):
    (cfg, params), (dcfg, dparams) = qwen_pair
    with pytest.raises(ValueError, match="draft_cfg"):
        ServeEngine(cfg, params, spec_k=2)
    bad = reduce_config(get_config("qwen2-1.5b"), layers=1, d_model=32,
                        vocab=VOCAB + 1)
    bad_params = build_model(bad).init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="vocab"):
        ServeEngine(cfg, params, draft_cfg=bad, draft_params=bad_params,
                    spec_k=2)


def test_spec_composes_with_paged_cache(qwen_pair):
    """Spec + paged K/V: draft lines are slot-resident (never
    page-accounted), pages cover committed target lines only — outputs
    stay bit-exact and page conservation holds after the run."""
    (cfg, params), (dcfg, dparams) = qwen_pair
    reqs = _requests()
    plain = ServeEngine(cfg, params, max_batch=2, cache_len=64, rng_seed=0)
    want = plain.run(list(reqs))
    spec = ServeEngine(cfg, params, max_batch=2, cache_len=64, rng_seed=0,
                       kv_page_size=8, draft_cfg=dcfg,
                       draft_params=dparams, spec_k=2)
    got = spec.run(list(reqs))
    assert got == want
    spec.kv.check_conservation()
    assert spec.kv.pages_live == spec.kv._index_pages


# ----------------------------------------------------------------- property:
# acceptance accounting — accepted + rejected + bonus == tokens_emitted
# for random seeds x k x logits, and over whole engine traces

@settings(max_examples=12)
@given(seed=st.integers(0, 1 << 16), k=st.sampled_from([1, 2, 4]),
       temperature=st.sampled_from([0.0, 0.5, 1.0]))
def test_accept_accounting_identity(seed, k, temperature):
    rng = np.random.RandomState(seed)
    draft_toks = rng.randint(0, VOCAB, size=k)
    draft_logits = (rng.randn(k, VOCAB) * 2).astype(np.float32)
    target_logits = (rng.randn(k + 1, VOCAB) * 2).astype(np.float32)
    emitted, kinds = spec_lib.accept_tokens(
        draft_toks, draft_logits, target_logits, temperature=temperature,
        base_key=jax.random.PRNGKey(0), rid=seed % 7, n_gen=seed % 11)
    c = Counter(kinds)
    assert c["accepted"] + c["rejected"] + c["bonus"] == len(emitted)
    assert 1 <= len(emitted) <= k + 1
    # every untruncated round ends with exactly one terminal token —
    # either the correction at the first rejection or the bonus
    assert c["rejected"] + c["bonus"] == 1
    # accepted tokens are a prefix of the draft proposal
    assert emitted[:c["accepted"]] == list(draft_toks[:c["accepted"]])


@functools.lru_cache(maxsize=None)
def _prop_engines(k):
    """One (plain, spec) engine pair per k, shared across property
    examples so the compiled steps are reused (run() resets state)."""
    cfg, params = _reduced("qwen2-1.5b", seed=0)
    dcfg, dparams = _reduced("qwen2-1.5b", layers=1, seed=4)
    plain = ServeEngine(cfg, params, max_batch=2, cache_len=64, rng_seed=0)
    spec = ServeEngine(cfg, params, max_batch=2, cache_len=64, rng_seed=0,
                       draft_cfg=dcfg, draft_params=dparams, spec_k=k)
    return plain, spec


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1 << 10), k=st.sampled_from([1, 2, 4]),
       temperature=st.sampled_from([0.0, 0.8]))
def test_engine_accounting_over_random_traces(seed, k, temperature):
    rng = np.random.RandomState(seed)
    reqs = [Request(rid=i, prompt=rng.randint(0, VOCAB,
                                              size=int(rng.randint(2, 8))),
                    max_new_tokens=int(rng.randint(1, 9)),
                    temperature=temperature)
            for i in range(int(rng.randint(1, 4)))]
    plain, spec = _prop_engines(k)
    got = spec.run(list(reqs))
    sp = spec.last_stats["spec"]
    assert sp["accepted"] + sp["rejected"] + sp["bonus"] \
        == sp["tokens_emitted"]
    assert sum(len(v) for v in got.values()) \
        == sum(r.max_new_tokens for r in reqs)
    if temperature == 0.0:
        assert got == plain.run(list(reqs))


# ---------------------------------------------------------------- satellite:
# the decode-specialized kernel route: paged_decode "verify" vs the ref
# oracle at k > 1, over float and int8 pools and both q ranks, and the
# registry still audits clean with the multi-query canonical key censused

def _paged_problem(qlen, seed=0):
    b, h, kvh, page, npt, hd = 2, 4, 2, 8, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    qshape = (b, h, hd) if qlen == 1 else (b, qlen, h, hd)
    q = jax.random.normal(ks[0], qshape, jnp.bfloat16)
    kpool = jax.random.normal(ks[1], (b * npt, page, kvh, hd), jnp.bfloat16)
    vpool = jax.random.normal(ks[2], (b * npt, page, kvh, hd), jnp.bfloat16)
    table = jnp.arange(b * npt, dtype=jnp.int32).reshape(b, npt)
    cache_len = jnp.array([max(qlen, 7), npt * page], jnp.int32)
    return q, kpool, vpool, table, cache_len


@pytest.mark.parametrize("qlen", [1, 3, 5])
def test_verify_kernel_matches_ref_oracle(qlen):
    from repro.kernels.paged import paged as paged_lib
    q, kpool, vpool, table, cache_len = _paged_problem(qlen)
    ref = paged_lib.paged_decode_ref(q, kpool, vpool, table, cache_len)
    for ppb in (1, 2, 4):
        got = paged_lib.paged_decode_verify(
            q, kpool, vpool, table, cache_len, pages_per_block=ppb)
        assert got.shape == ref.shape and got.dtype == ref.dtype
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32),
            atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("qlen", [1, 4])
def test_verify_kernel_int8_pool_matches_dequant_ref(qlen):
    from repro.kernels.paged import paged as paged_lib
    q, kpool, vpool, table, cache_len = _paged_problem(qlen, seed=1)
    qk, kscale = paged_lib.quantize_pool(kpool)
    qv, vscale = paged_lib.quantize_pool(vpool)
    got = paged_lib.paged_decode_verify(
        q, qk, qv, table, cache_len, pages_per_block=2,
        kscale=kscale, vscale=vscale)
    # the oracle sees what the kernel sees: the dequantized pool
    deqk = (qk.astype(jnp.float32) * kscale[:, None, None, None])
    deqv = (qv.astype(jnp.float32) * vscale[:, None, None, None])
    ref = paged_lib.paged_decode_ref(
        q, deqk.astype(jnp.bfloat16), deqv.astype(jnp.bfloat16),
        table, cache_len)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        atol=2e-2, rtol=2e-2)
    with pytest.raises(ValueError, match="kscale"):
        paged_lib.paged_decode_verify(q, qk, qv, table, cache_len,
                                      pages_per_block=2)


def test_multi_query_attention_matches_sequential_static_cache():
    """The static-cache side of the verify route: decode_attention_multi
    over Q candidate lines equals Q sequential decode_attention calls —
    the identity that makes decode_verify bit-exact vs decode_step."""
    from repro.models import attention as attn_lib
    b, qn, h, hd, length = 2, 3, 4, 16, 24
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, qn, h, hd), jnp.bfloat16)
    kc = jax.random.normal(ks[1], (b, length, h, hd), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (b, length, h, hd), jnp.bfloat16)
    cache_len = jnp.array([qn + 5, qn + 9], jnp.int32)
    multi = attn_lib.decode_attention_multi(q, kc, vc, cache_len)
    for j in range(qn):
        one = attn_lib.decode_attention(
            q[:, j:j + 1], kc, vc, cache_len - (qn - 1 - j))[:, 0]
        np.testing.assert_array_equal(np.asarray(multi[:, j]),
                                      np.asarray(one))


def test_registry_routes_rank4_q_and_audits_clean():
    """Every paged_decode version accepts the multi-query problem (the
    census traces the full canonical x version cross product), blockwise
    results agree with ref, and the registry audits clean — KV001/VMEM001
    stay quiet with the qlen=4 canonical key in play."""
    from repro.analyze import audit_registry
    from repro.kernels import api
    from repro.kernels.paged.kernel_def import KERNEL, PagedKey

    assert "verify" in KERNEL.versions and "verify" in KERNEL.tunable
    keys = KERNEL.canonical_keys()
    assert any(k.qlen > 1 for k in keys)
    mq = next(k for k in keys if k.qlen > 1)
    # 7-part key_dims round-trips; 6-part stays back-compatible
    assert KERNEL.key_from_dims(mq.key_dims()) == mq
    assert KERNEL.key_from_dims("2x2x2x16x4x32") == \
        PagedKey(b=2, h=2, kvh=2, page=16, npt=4, hd=32)

    args, kw = KERNEL.make_example(mq)
    ref = KERNEL.run(*args, version="ref", config=None, interpret=True, **kw)
    assert ref.shape == (mq.b, mq.qlen, mq.h, mq.hd)
    for version in ("gather", "int8", "verify"):
        cfg = KERNEL.static_config(mq, version)
        got = KERNEL.run(*args, version=version, config=cfg,
                         interpret=True, **kw)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32),
            atol=5e-2, rtol=5e-2)

    report = audit_registry(["paged_decode"])
    n_keys = len(api.get_kernel("paged_decode").canonical_keys())
    assert len(report.censuses) == n_keys * len(KERNEL.versions)
    assert report.errors == [], [f.row() for f in report.errors]


# ---------------------------------------------------------------- satellite:
# evict_inflight mid-verify must roll the slot back to the last ACCEPTED
# token, not the speculated tip (regression for the fenced-replica path,
# where a round can die between verify and accept)

def test_evict_mid_verify_rolls_back_to_last_accepted(qwen_pair):
    (cfg, params), (dcfg, dparams) = qwen_pair
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64, rng_seed=0,
                      draft_cfg=dcfg, draft_params=dparams, spec_k=4)
    eng.reset()
    eng.submit(Request(rid=7, prompt=np.arange(5, dtype=np.int32),
                       max_new_tokens=8))
    eng.step()                                 # admission + one spec round
    s = eng._slots[0]
    assert s is not None and s.rid == 7
    committed = s.prompt_len + s.n_gen - 1
    assert int(np.asarray(eng._cache["pos"])[0]) == committed

    # drive the engine into the mid-verify state an interrupted round
    # leaves behind: verify launched (device pos at the speculated tip,
    # _spec_inflight armed) but the accept/rollback never ran
    active = jnp.asarray(np.array([st is not None for st in eng._slots]))
    eng._spec_inflight[0] = committed
    vtoks = np.zeros((eng.max_batch, eng.spec_k + 1), np.int32)
    vtoks[:, 0:1] = eng._cur
    _, eng._cache = eng._verify(eng.params, eng._cache,
                                jnp.asarray(vtoks), active)
    _, eng._draft_cache = eng._draft_decode(
        eng.draft_params, eng._draft_cache, jnp.asarray(eng._cur), active)
    assert int(np.asarray(eng._cache["pos"])[0]) \
        == committed + eng.spec_k + 1          # at the tip

    evicted, _ = eng.evict_inflight(rids={7})
    assert [r.rid for r in evicted] == [7]
    # the rollback: last accepted line, NOT the speculated tip
    assert int(np.asarray(eng._cache["pos"])[0]) == committed
    assert int(np.asarray(eng._draft_cache["pos"])[0]) == committed

    # and the evicted request re-serves bit-exact vs plain decode
    plain = ServeEngine(cfg, params, max_batch=2, cache_len=64, rng_seed=0)
    want = plain.run([Request(rid=7, prompt=np.arange(5, dtype=np.int32),
                              max_new_tokens=8)])
    eng.submit(evicted[0])
    while not eng.idle:
        eng.step()
    assert eng.outputs[7] == want[7]
