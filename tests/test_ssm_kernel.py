"""Selective-scan Pallas kernel: shape sweeps + hypothesis seeds vs the
models/mamba.ssm_scan oracle, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.kernels.ssm.ssm_scan import kernel_hbm_bytes, ssm_scan_pallas
from repro.models.mamba import ssm_scan


def _mk(b, t, c, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    x = jax.random.normal(ks[0], (b, t, c))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, c)) - 2)
    bm = jax.random.normal(ks[2], (b, t, n))
    cm = jax.random.normal(ks[3], (b, t, n))
    alog = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)
                   )[None].repeat(c, 0)
    d = jax.random.normal(ks[5], (c,))
    h0 = 0.1 * jax.random.normal(ks[6], (b, c, n))
    return x, dt, bm, cm, alog, d, h0


@pytest.mark.parametrize("b,t,c,n,blk", [
    (2, 64, 8, 4, 4),
    (1, 128, 16, 8, 8),
    (3, 32, 8, 16, 8),
])
def test_shape_sweep(b, t, c, n, blk):
    args = _mk(b, t, c, n)
    y1, h1 = ssm_scan(*args)
    y2, h2 = ssm_scan_pallas(*args, blk_c=blk, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_seeds(seed):
    args = _mk(2, 32, 4, 4, seed=seed)
    y1, h1 = ssm_scan(*args)
    y2, h2 = ssm_scan_pallas(*args, blk_c=4, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


def test_mamba_path_pallas_route_matches_chunked():
    """cfg.ssm_impl="pallas" routes the hybrid prefill scan through the
    kernel registry (ops.ssm_scan with an explicit shard-local SsmKey);
    output must match the chunked XLA path bit-for-bit here (1 device,
    same f32 math) and the tune cache must hold a key for the LOCAL
    channel count the call site derived."""
    import dataclasses

    from repro.configs.base import get_config, reduce_config
    from repro.models.registry import build_model
    cfg = reduce_config(get_config("hymba-1.5b"), layers=2, d_model=64,
                        vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jnp.asarray((np.arange(8) % 128)[None])
    l0, _ = model.prefill(params, {"tokens": toks})
    mp = build_model(dataclasses.replace(cfg, ssm_impl="pallas"))
    lp, _ = mp.prefill(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lp, dtype=np.float32),
                               np.asarray(l0, dtype=np.float32),
                               rtol=0, atol=1e-5)


def test_dispatch_problem_key_override_tunes_local_shard():
    """api.dispatch(problem_key=...) keys config resolution on the given
    (shard-local) problem instead of the global operand shapes — the
    contract the sharded ServeEngine's kernel call sites rely on."""
    from repro.kernels import api
    from repro.kernels.ssm.kernel_def import SsmKey
    from repro.tune import tuner
    args = _mk(1, 8, 16, 4)
    local = SsmKey(b=1, t=8, c=8, n=4)          # c/2: a 2-way TP shard
    y, hT = api.dispatch("ssm", *args, problem_key=local, interpret=True)
    yref, href = ssm_scan(*args)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               atol=1e-4, rtol=1e-4)
    # the tuned config came from the LOCAL key and tiles the local slab
    tc = tuner.tune_kernel("ssm", local)
    assert local.key_dims() in tc.key
    assert local.c % tc.config.blk_c == 0


def test_kernel_traffic_model_sane():
    # kernel I/O must be far below the chunked-XLA materialization:
    # ~6 (B,T,C,N) f32 arrays vs ~3 (B,T,C) + small
    b, t, c, n = 16, 4096, 6400, 16
    kernel = kernel_hbm_bytes(b, t, c, n)
    chunked = 6 * b * t * c * n * 4
    assert kernel < chunked / 10
