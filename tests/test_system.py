"""End-to-end behaviour tests: trainer loop with checkpoint/restart
(fault-tolerance contract), serving engine, and the GPP journey."""

import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduce_config
from repro.core.journey import OP_MIX, run_journey, sweep_blocks
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import TrainLoopConfig, Trainer


def _tiny_loop(tmp_path, total_steps, ckpt_every=4):
    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2, d_model=64,
                        vocab=128)
    loop = TrainLoopConfig(total_steps=total_steps, ckpt_every=ckpt_every,
                           log_every=100, ckpt_dir=str(tmp_path / "ckpt"),
                           seq_len=32, global_batch=4, peak_lr=1e-3)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return cfg, Trainer(cfg, loop, mesh)


def test_trainer_runs_and_loss_decreases(tmp_path):
    cfg, tr = _tiny_loop(tmp_path, total_steps=12, ckpt_every=50)
    out = tr.run(verbose=False)
    assert len(out["losses"]) == 12
    assert np.isfinite(out["losses"]).all()
    # synthetic uniform tokens: loss should approach log(vocab) from init
    assert out["losses"][-1] < out["losses"][0] + 0.5


def test_trainer_restart_idempotent(tmp_path):
    """Kill-restart contract: run 8 steps; separately run 4 steps (ckpt at
    4), 'crash', restart to 8. The post-restart losses must match the
    uninterrupted run exactly (step-keyed data + checkpointed state)."""
    cfg, tr_full = _tiny_loop(tmp_path / "a", total_steps=8, ckpt_every=100)
    full = tr_full.run(verbose=False)["losses"]

    cfg, tr1 = _tiny_loop(tmp_path / "b", total_steps=4, ckpt_every=4)
    tr1.run(verbose=False)
    cfg, tr2 = _tiny_loop(tmp_path / "b", total_steps=8, ckpt_every=4)
    resumed = tr2.run(verbose=False)["losses"]
    np.testing.assert_allclose(resumed, full[4:], rtol=2e-2, atol=2e-2)


def test_serve_engine_generates(tmp_path):
    cfg = reduce_config(get_config("qwen2-1.5b"), layers=2, d_model=64,
                        vocab=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=2)
    reqs = [Request(rid=i, prompt=np.arange(4 + i) % 128, max_new_tokens=5)
            for i in range(3)]
    out = eng.run(reqs)
    assert set(out) == {0, 1, 2}
    for rid, toks in out.items():
        assert len(toks) == 5
        assert all(0 <= t < 128 for t in toks)
    # greedy decoding is deterministic
    out2 = eng.run([Request(rid=9, prompt=np.arange(4) % 128,
                            max_new_tokens=5)])
    out3 = eng.run([Request(rid=9, prompt=np.arange(4) % 128,
                            max_new_tokens=5)])
    assert out2[9] == out3[9]


# ----------------------------------------------------------- journey system

def test_journey_trajectory():
    """The paper's Table-I arc, as system behaviour: every step validates
    against the oracle; v1 beats v0 on the compute term; v4 collapses the
    memory term; v6 regresses vs v5; v8 recovers to the best paper-step
    time; the beyond-paper v9 (fused accumulation) and v10 (autotuned)
    steps take the overall lead."""
    rows = run_journey("si214", measure_cpu=False, verbose=False)
    byv = {r.version: r for r in rows}
    for r in rows:
        assert r.rel_err < 1e-5, (r.version, r.rel_err)
    assert byv["v1"].report.compute_s < byv["v0"].report.compute_s * 0.95
    assert byv["v4"].report.memory_s < byv["v3"].report.memory_s * 0.1
    assert byv["v6"].report.modeled_step_s > byv["v5"].report.modeled_step_s
    paper = [v for v in ("v0", "v1", "v2", "v3", "v4", "v5", "v6", "v7",
                         "v8")]
    assert byv["v8"].report.modeled_step_s <= \
        min(byv[v].report.modeled_step_s for v in paper) * 1.001
    assert byv["v9"].report.modeled_step_s <= byv["v8"].report.modeled_step_s
    assert byv["v10"].report.modeled_step_s <= \
        byv["v9"].report.modeled_step_s * (1 + 1e-9)
    # headline claim shape: v8 throughput gain over v0 within [1.2x, 2.5x]
    gain = byv["v8"].modeled_tflops / byv["v0"].modeled_tflops
    assert 1.2 < gain < 2.5, gain
    assert byv["v10"].modeled_tflops >= byv["v8"].modeled_tflops


def test_journey_block_sweep_respects_vmem():
    rows = sweep_blocks("si214")
    assert rows, "sweep empty"
    from repro.core.hw import TPU_V5E
    for r in rows:
        assert r["vmem_mib"] * 2 ** 20 <= TPU_V5E.vmem_bytes
    # the chosen v8 config should be near the sweep optimum
    best = rows[0]["modeled_s"]
    from repro.core.journey import _model_report
    v8 = _model_report("v8", __import__(
        "repro.kernels.gpp.problem", fromlist=["SIZES"]).SIZES["si214"])
    assert v8.modeled_step_s <= best * 1.1


def test_op_mix_monotone():
    """Optimization steps never add passes: v0 >= v1 >= ... >= v10."""
    order = ["v0", "v1", "v2", "v3", "v4", "v5", "v6", "v7", "v8", "v9",
             "v10"]
    passes = [OP_MIX[v].passes for v in order]
    assert all(a >= b for a, b in zip(passes, passes[1:])), passes
