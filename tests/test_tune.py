"""repro.tune coverage: candidate-space feasibility invariants, the
model-then-measure tuner, the JSON cache round-trip, v10 dispatch through
ops.gpp, and the BENCH_*.json artifact + compare regression gate."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import vpu_model
from repro.core.hw import TPU_V5E
from repro.kernels.gpp import ops, pallas_gpp, problem, ref
from repro.tune import measure, space, tuner

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _rel(a, b):
    return float(np.max(np.abs(np.asarray(a) - b)) / np.max(np.abs(b)))


# ---------------------------------------------------------------------------
# candidate space
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("size_name", ["tiny", "bench", "si214", "si510"])
def test_candidates_feasible(size_name):
    """Every candidate exactly tiles every axis and fits the VMEM budget."""
    size = problem.SIZES[size_name]
    cands = space.candidates(size)
    assert cands, size_name
    for cfg in cands:
        assert size.ncouls % cfg.blk_ig == 0, cfg
        assert size.ngpown % cfg.blk_igp == 0, cfg
        assert size.nbands % cfg.blk_band == 0, cfg
        assert cfg.vmem_bytes(size.nw) <= TPU_V5E.vmem_bytes, cfg
        assert cfg.fused_acc


@settings(max_examples=12, deadline=None)
@given(nbands=st.sampled_from([8, 32, 96, 1024, 2560]),
       ngpown=st.sampled_from([8, 64, 128, 1024]),
       ncouls=st.sampled_from([64, 512, 8192, 20480]))
def test_candidates_feasible_property(nbands, ngpown, ncouls):
    size = problem.GppSize("prop", nbands=nbands, ngpown=ngpown,
                           ncouls=ncouls)
    for cfg in space.candidates(size):
        assert size.ncouls % cfg.blk_ig == 0
        assert size.ngpown % cfg.blk_igp == 0
        assert size.nbands % cfg.blk_band == 0
        assert cfg.vmem_bytes(size.nw) <= TPU_V5E.vmem_bytes


def test_rank_sorted_and_deterministic():
    ranked = tuner.rank(problem.SIZES["si214"])
    times = [t for _, t in ranked]
    assert times == sorted(times)
    assert ranked == tuner.rank(problem.SIZES["si214"])


# ---------------------------------------------------------------------------
# tuned-never-worse-than-v8 (in the shared analytic model)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(nbands=st.sampled_from([256, 1024, 2560]),
       ngpown=st.sampled_from([128, 1024, 2560]),
       ncouls=st.sampled_from([4096, 8192, 20480]))
def test_tuned_config_never_worse_than_v8_in_model(nbands, ngpown, ncouls):
    """For any size the static v8 config can run at all, the tuner's pick
    must model at least as fast (it minimizes the same model over a space
    that contains a fused config with v8's blocks)."""
    size = problem.GppSize("prop", nbands=nbands, ngpown=ngpown,
                           ncouls=ncouls)
    v8 = pallas_gpp.V8
    if (size.ncouls % v8.blk_ig or size.ngpown % v8.blk_igp
            or size.nbands % v8.blk_band):
        return                                   # v8 can't even run here
    v8_s = vpu_model.pallas_step_s(size, v8, vpu_model.OP_MIX["v8"])
    best_cfg, best_s = tuner.rank(size)[0]
    assert best_s <= v8_s * (1 + 1e-12), (best_cfg, best_s, v8_s)


# ---------------------------------------------------------------------------
# tune + cache round-trip
# ---------------------------------------------------------------------------

def test_tune_cache_round_trip(tmp_path, monkeypatch):
    cache = str(tmp_path / "tune")
    tuner.clear_memo()
    size = problem.TINY
    tc = tuner.tune(size, cache_dir=cache, measure_mode=False)
    path = os.path.join(cache, tuner.CACHE_FILE)
    assert os.path.exists(path)
    on_disk = json.load(open(path))
    assert tc.key in on_disk

    # a fresh process state must hit the disk cache, not re-tune
    tuner.clear_memo()
    monkeypatch.setattr(tuner, "rank",
                        lambda *a, **k: pytest.fail("cache missed"))
    tc2 = tuner.tune(size, cache_dir=cache, measure_mode=False)
    assert tc2.source == "cache"
    assert tc2.config == tc.config
    assert tc2.modeled_s == tc.modeled_s


def test_tune_measured_pass_and_memo(tmp_path):
    """The measurement pass really times the kernel (measured_s set) and
    the in-process memo serves repeat calls."""
    tuner.clear_memo()
    cache = str(tmp_path / "tune")
    tc = tuner.tune(problem.TINY, cache_dir=cache, measure_mode=True,
                    top_k=2, reps=1, warmup=1)
    assert tc.source == "measured"
    assert tc.measured_s is not None and tc.measured_s > 0
    assert tuner.tune(problem.TINY, cache_dir=cache) is tc   # memo hit


def test_time_config_honors_zero_warmup(monkeypatch):
    """An explicit warmup=0 means ZERO warmup calls (cold-start callers
    want the first timed call to include compile/trace cost); only
    negatives are clamped. The old max(warmup, 1) silently forced one."""
    import jax.numpy as jnp
    calls = []
    monkeypatch.setattr(measure.pallas_gpp, "gpp_pallas",
                        lambda inputs, cfg, interpret: (calls.append(1),
                                                        jnp.zeros(()))[1])
    measure.time_config({}, None, interpret=True, warmup=0, reps=2)
    assert len(calls) == 2
    calls.clear()
    measure.time_config({}, None, interpret=True, warmup=-3, reps=2)
    assert len(calls) == 2          # negative clamps to zero, not one
    calls.clear()
    measure.time_config({}, None, interpret=True, warmup=1, reps=2)
    assert len(calls) == 3


def test_corrupt_cache_is_ignored(tmp_path):
    cache = str(tmp_path / "tune")
    os.makedirs(cache)
    with open(os.path.join(cache, tuner.CACHE_FILE), "w") as fh:
        fh.write("{not json")
    tuner.clear_memo()
    tc = tuner.tune(problem.TINY, cache_dir=cache, measure_mode=False)
    assert tc.config.blk_ig > 0


# ---------------------------------------------------------------------------
# v9 / v10 numerics + dispatch
# ---------------------------------------------------------------------------

def test_v9_v10_match_oracle_at_tiny():
    """Acceptance: v9/v10 within 1e-5 of the complex128 oracle at TINY;
    v10 goes through the tuner cache."""
    tuner.clear_memo()
    inp = problem.make_inputs(problem.TINY)
    ar, xr = ref.ref_numpy(inp)
    for version in ("v9", "v10"):
        a, x = ops.gpp(inp, version=version)
        assert _rel(a, ar) < 1e-5, version
        assert _rel(x, xr) < 1e-5, version
    # the dispatch memoized a tuned config for (TINY, cpu, v10)
    key = tuner.cache_key(problem.TINY, "cpu", "v10")
    assert any(mk[1] == key for mk in tuner._MEMO)


def test_tuned_config_runs_fused():
    cfg = tuner.best_config(problem.TINY, measure_mode=False)
    assert cfg.fused_acc
    assert cfg.name == "v10"


# ---------------------------------------------------------------------------
# BENCH artifact + compare gate
# ---------------------------------------------------------------------------

def _artifact(rows):
    sys.path.insert(0, ROOT)
    from benchmarks import report
    return report.make_artifact(rows)


def test_artifact_schema_and_parse():
    sys.path.insert(0, ROOT)
    from benchmarks import report
    art = _artifact([{"name": "x", "us_per_call": 3.0,
                      "derived": "modeled_tflops=4.1;step_s=0.36;"
                                 "dominant=compute"}])
    assert art["schema"] == report.SCHEMA
    row = art["rows"][0]
    assert row["metrics"] == {"modeled_tflops": 4.1, "step_s": 0.36}


def test_compare_flags_synthetic_regression(tmp_path):
    """Acceptance: compare exits nonzero on a >10% synthetic regression."""
    sys.path.insert(0, ROOT)
    from benchmarks import report
    old = [{"name": "gpp_si214_v10", "us_per_call": None,
            "derived": "modeled_tflops=4.0;step_s=0.36"}]
    new = [{"name": "gpp_si214_v10", "us_per_call": None,
            "derived": "modeled_tflops=3.0;step_s=0.48"}]   # -25% / +33%
    p_old, p_new = str(tmp_path / "old.json"), str(tmp_path / "new.json")
    report.write_artifact(old, p_old)
    report.write_artifact(new, p_new)
    assert report.run_compare(p_old, p_new) == 1
    assert report.run_compare(p_old, p_new, warn_only=True) == 0
    assert report.run_compare(p_old, p_old) == 0
    # improvements alone never gate
    assert report.run_compare(p_new, p_old) == 0

    regs, imps, _ = report.compare(report.load_artifact(p_old),
                                   report.load_artifact(p_new))
    assert any("modeled_tflops" in r for r in regs)
    assert any("step_s" in r for r in regs)
    assert not imps


def test_compare_cli_exit_codes(tmp_path):
    """The CLI contract CI relies on (exit 1 = gate failure)."""
    sys.path.insert(0, ROOT)
    from benchmarks import report
    old = [{"name": "r", "us_per_call": None, "derived": "step_s=1.0"}]
    new = [{"name": "r", "us_per_call": None, "derived": "step_s=2.0"}]
    p_old, p_new = str(tmp_path / "o.json"), str(tmp_path / "n.json")
    report.write_artifact(old, p_old)
    report.write_artifact(new, p_new)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-m", "benchmarks.report",
                        "--compare", p_old, p_new], cwd=ROOT, env=env,
                       capture_output=True, text=True)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "REGRESSION" in r.stdout
    r = subprocess.run([sys.executable, "-m", "benchmarks.report",
                        "--compare", p_old, p_new, "--warn-only"],
                       cwd=ROOT, env=env, capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


def test_compare_rejects_malformed_provenance_legibly(tmp_path):
    """A hand-edited baseline whose kernel_config lost a provenance key
    (or isn't an object at all) must fail the gate with exit 2 and a
    clear message — not a traceback out of the churn formatter."""
    import json
    sys.path.insert(0, ROOT)
    from benchmarks import report
    good = [{"name": "r", "us_per_call": None, "derived": "step_s=1.0",
             "kernel_config": {"kernel": "gpp", "version": "v10",
                               "config": {"blk_ig": 512}, "source": "model"}}]
    p_new = str(tmp_path / "n.json")
    report.write_artifact(good, p_new)

    def _broken(fname, mutate):
        art = report.make_artifact(good)
        mutate(art["rows"][0])
        p = str(tmp_path / fname)
        json.dump(art, open(p, "w"))
        return p

    p_missing = _broken("missing.json",
                        lambda r: r["kernel_config"].pop("source"))
    assert report.run_compare(p_missing, p_new) == 2
    p_str = _broken("str.json", lambda r: r.update(kernel_config="gpp/v10"))
    assert report.run_compare(p_str, p_new) == 2
    with pytest.raises(report.ArtifactError, match="provenance"):
        report.validate_artifact(report.load_artifact(p_missing), p_missing)
    # the CLI surfaces it on stderr with exit 2 and no traceback
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-m", "benchmarks.report",
                        "--compare", p_missing, p_new], cwd=ROOT, env=env,
                       capture_output=True, text=True)
    assert r.returncode == 2, r.stdout + r.stderr
    assert "provenance" in r.stderr and "Traceback" not in r.stderr
    # a missing file is also a clean error, not a traceback
    assert report.run_compare(str(tmp_path / "nope.json"), p_new) == 2
