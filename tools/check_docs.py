#!/usr/bin/env python3
"""Docs checks for the CI `docs` job (stdlib only, no jax import).

    python tools/check_docs.py                   # link check
    python tools/check_docs.py --run-quickstart  # + run the README
                                                 #   quickstart verbatim

Link check: every relative markdown link in README.md and docs/*.md must
resolve to an existing file (and, for `file.md#anchor` / `#anchor`
links, to a heading that slugifies to the anchor). External http(s)
links are not fetched.

Quickstart check: extracts the first fenced ```bash block under the
README's "## Quickstart" heading and runs it verbatim from the repo
root — the README must never document a command that doesn't work.
"""

from __future__ import annotations

import argparse
import glob
import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — excluding images; target split on '#'
_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def doc_files() -> list:
    files = [os.path.join(ROOT, "README.md")]
    files += sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop non-word chars except
    spaces/hyphens, spaces -> hyphens. (Approximate but covers our
    headings, including the `§`-prefixed ones.)"""
    h = heading.strip().lower()
    h = re.sub(r"[`*_]", "", h)
    h = re.sub(r"[^\w\- ]", "", h, flags=re.UNICODE)
    return h.replace(" ", "-")


def anchors_in(path: str) -> set:
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    return {github_slug(m.group(1)) for m in _HEADING.finditer(text)}


def check_links(files=None) -> list:
    """Returns a list of 'file: broken link' error strings (empty = ok)."""
    errors = []
    for path in files or doc_files():
        rel = os.path.relpath(path, ROOT)
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            if target:
                dest = os.path.normpath(
                    os.path.join(os.path.dirname(path), target))
                if not os.path.exists(dest):
                    errors.append(f"{rel}: broken link -> {m.group(1)}")
                    continue
            else:
                dest = path                      # same-file #anchor
            if frag is not None and dest.endswith(".md"):
                if github_slug(frag) not in anchors_in(dest):
                    errors.append(f"{rel}: broken anchor -> {m.group(1)}")
    return errors


def quickstart_block(readme=None) -> str:
    """The first fenced bash block under '## Quickstart' in the README."""
    path = readme or os.path.join(ROOT, "README.md")
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    m = re.search(r"^##\s+Quickstart.*?```bash\n(.*?)```", text,
                  re.DOTALL | re.MULTILINE)
    if not m:
        raise SystemExit("README.md has no ## Quickstart ```bash block")
    return m.group(1).strip()


def run_quickstart() -> int:
    cmd = quickstart_block()
    print(f"$ {cmd}")
    return subprocess.run(cmd, shell=True, cwd=ROOT).returncode


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--run-quickstart", action="store_true",
                    help="also execute the README quickstart block")
    args = ap.parse_args()
    errors = check_links()
    for e in errors:
        print(f"error: {e}", file=sys.stderr)
    if errors:
        return 1
    print(f"docs: {len(doc_files())} files, all relative links resolve")
    if args.run_quickstart:
        rc = run_quickstart()
        if rc:
            print(f"error: quickstart exited {rc}", file=sys.stderr)
            return rc
        print("docs: README quickstart ran clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
